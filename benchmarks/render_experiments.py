"""Render the §Dry-run / §Roofline tables of EXPERIMENTS.md from
runs/dryrun/results.jsonl.

  PYTHONPATH=src python -m benchmarks.render_experiments > /tmp/tables.md
"""
import json
import sys
from collections import defaultdict

ARCH_ORDER = ["internvl2-26b", "h2o-danube-3-4b", "whisper-small",
              "nemotron-4-15b", "deepseek-v3-671b", "stablelm-1.6b",
              "deepseek-v2-lite-16b", "jamba-v0.1-52b", "qwen3-1.7b",
              "xlstm-350m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path="runs/dryrun/results.jsonl"):
    best = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except Exception:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("mesh"),
                   r.get("multi_pod", False), r.get("overdecompose", 1),
                   r.get("remat_policy", "full"),
                   r.get("cache_gather", False))
            best[key] = r  # later lines win (reruns supersede)
    return best


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.1f}"


def main():
    recs = load()
    print("### Roofline table (single-pod, 256 chips, baseline configs)\n")
    print("| arch | shape | mesh | factors (d,x,y,z) | compute_t (s) | "
          "memory_t (s) | collective_t (s) | dominant | useful | "
          "mem GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("baseline-1d", "tensor4d"):
                r = recs.get((arch, shape, mesh, False, 1, "full", False))
                if r is None:
                    continue
                if "error" in r:
                    print(f"| {arch} | {shape} | {mesh} | - | ERROR | | | "
                          f"| | {r['error'][:60]} |")
                    continue
                ro = r["roofline"]
                fa = r["factors"]
                fs = f"({fa['g_data']},{fa['g_x']},{fa['g_y']},{fa['g_z']})"
                mem = r.get("memory", {}).get("total_per_device_bytes")
                print(f"| {arch} | {shape} | {mesh} | {fs} "
                      f"| {ro['compute_t']:.3f} | {ro['memory_t']:.3f} "
                      f"| {ro['collective_t']:.3f} | {ro['dominant']} "
                      f"| {ro['useful_ratio']:.2f} | {fmt_bytes(mem)} |")
    print()
    print("### Multi-pod pass (2 x 16 x 16 = 512 chips)\n")
    print("| arch | shape | mesh | compiled | collective GB/dev |")
    print("|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("baseline-1d", "tensor4d"):
                r = recs.get((arch, shape, mesh, True, 1, "full", False))
                if r is None:
                    continue
                ok = "error" not in r
                coll = (r["roofline"]["collective_bytes"] / 1e9
                        if ok else None)
                print(f"| {arch} | {shape} | {mesh} | "
                      f"{'yes' if ok else 'FAILED'} | "
                      f"{coll:.2f} |" if ok else
                      f"| {arch} | {shape} | {mesh} | FAILED | - |")
    print()


if __name__ == "__main__":
    main()
