"""Benchmark harness — one function per paper table/figure + the roofline
summary. Prints ``name,us_per_call,derived`` CSV (us_per_call is the
measured/metric value; ``derived`` carries the figure-specific payload).

  PYTHONPATH=src python -m benchmarks.run [--only fig5,fig8,...]
"""
import argparse
import json
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")


def roofline_summary():
    """§Roofline table digest from the dry-run records."""
    path = "runs/dryrun/results.jsonl"
    rows = []
    if not os.path.exists(path):
        return [("roofline/missing", 0.0, "run launch.dryrun first")]
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except Exception:
                continue
            if "roofline" not in r or r.get("multi_pod"):
                continue
            if r.get("overdecompose", 1) != 1:
                continue
            ro = r["roofline"]
            t = max(ro["compute_t"], ro["memory_t"], ro["collective_t"])
            rows.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                         t * 1e6,
                         f"dom={ro['dominant']} "
                         f"ct={ro['compute_t']:.3f} "
                         f"mt={ro['memory_t']:.3f} "
                         f"lt={ro['collective_t']:.3f} "
                         f"useful={ro['useful_ratio']:.2f}"))
    return rows or [("roofline/empty", 0.0, "no records")]


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="Benchmark harness: one function per paper "
                    "table/figure + the roofline summary (CSV output).")
    ap.add_argument("--only", default="",
                    help="comma-separated suite subset (default: all)")
    ap.add_argument("--calib", default="",
                    help="hardware calibration profile (path or 'auto'; "
                         "benchmarks.calibrate) pricing fig5_measured's "
                         "predicted ranking / rank correlation")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    from benchmarks import measured, paper_tables, serving
    suites = {
        "fig5": paper_tables.fig5_sweep,
        "fig7": paper_tables.fig7_unet_weak_scaling,
        "fig8": paper_tables.fig8_weak_scaling,
        "table5": paper_tables.table5_cai3d,
        "eq12": paper_tables.eq11_asymptote,
        "fig5_measured": lambda: measured.fig5_measured(
            calib=args.calib or None),
        "fig6": measured.fig6_validation,
        "overdecomp": measured.overdecomposition_overlap,
        "overlap": measured.overlap_collectives,
        "dp_sync": measured.dp_sync,
        "ring_attention": measured.ring_attention,
        "expert_a2a": measured.expert_a2a,
        "kernels": measured.kernel_micro,
        "serving": lambda: serving.suite(calib=args.calib or ""),
        "roofline": roofline_summary,
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if name not in only:
            continue
        try:
            for row in fn():
                label, val, derived = row
                print(f"{label},{val:.2f},{derived}", flush=True)
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
