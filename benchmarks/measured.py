"""Measured (wall-clock) benchmarks on the host CPU mesh.

These run real jitted steps on 8 host devices — small models, honest
timings. They mirror the paper's *measured* panels at laptop scale:
fig5_measured sweeps decompositions of the same model (the optimum should
track the comm model's prediction directionally), fig6_validation trains
the same model/data under two decompositions and checks the loss curves
coincide, and kernel_micro times the Pallas kernels (interpret mode)
against their jnp oracles.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _train_setup(arch, mesh_shape, *, steps, B, S, overdecompose=1,
                 seed=0, overlap=None, gradsync=None, names=None):
    from repro.configs import get_config
    from repro.core.gradsync import GradSyncConfig
    from repro.core.overlap import OverlapConfig
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import mesh as LM
    from repro.launch import steps as ST
    from repro.optim.adamw import AdamWConfig, init_state

    # a 5th entry opens the context-parallel seq axis (bind_4d maps it);
    # pass ``names`` explicitly to bind other axes (e.g. "expert")
    if names is None:
        names = ("data", "x", "y", "z", "seq")[:len(mesh_shape)]
    mesh = LM.make_smoke_mesh(mesh_shape, names)
    axes = LM.bind_4d(mesh)
    cfg = get_config(arch).reduced()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(seed),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    opts = ST.TrainOptions(overdecompose=overdecompose, dtype=jnp.float32,
                           overlap=overlap or OverlapConfig(),
                           gradsync=gradsync or GradSyncConfig())
    tools = None
    if opts.gradsync.state_sharded:
        tools = ST.make_gradsync_tools(cfg, mesh, axes, opts)
        state = tools.init(params)
        if opts.gradsync.zero3:
            # params become the permanent 1/G_data shard tree
            params = tools.shard_params(params)
    else:
        state = init_state(params)
    fn, _, _ = ST.make_train_step(
        cfg, mesh, axes, AdamWConfig(lr=1e-3, warmup_steps=2,
                                     total_steps=steps), opts)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    # seq-mapped meshes consume the striped token layout (same global
    # batch, rearranged — the LM loss is permutation-invariant)
    batch = ST.stripe_batch(batch, axes)
    return cfg, fn, params, state, batch, tools


def fig5_measured(steps: int = 6, calib: str = None
                  ) -> List[Tuple[str, float, str]]:
    """Iteration time for the same model under different decompositions of
    8 devices (the paper's Fig. 5 methodology at CPU scale), plus the
    comm model's predicted ranking over the same candidates — the
    validation loop for ``optimize_decomposition(objective='time')``
    being the default factor chooser under ``--overlap``.

    ``calib`` (``--calib`` on benchmarks.run / benchmarks.calibrate
    --validate) prices the prediction with a measured
    :class:`~repro.core.calibrate.CalibrationProfile` instead of the
    TPU_V5E guesses and the report includes the Spearman rank
    correlation of predicted vs measured step times over the
    **decomposition x token-scale grid** — the number that says whether
    the analytical model is a measured tuner or a plausible heuristic on
    this backend. The grid spans sequence lengths as well as
    decompositions because the two validate different fitted constants
    (flops/β vs γ/α) — and because host-CPU wall clock cannot resolve
    near-tied decompositions (the per-decomposition correlation at the
    base scale is reported separately, with that caveat)."""
    from repro.configs import get_config
    from repro.core import calibrate as CB
    from repro.core import comm_model as CM

    shapes = [("gdata4_gy2", (4, 1, 2, 1)),
              ("gdata2_gx2_gy2", (2, 2, 2, 1)),
              ("gdata2_gy4", (2, 1, 4, 1)),
              ("gdata2_gy2_gz2", (2, 1, 2, 2)),
              ("gdata1_gy4_gz2", (1, 1, 4, 2)),
              # context-parallel points: the 5th factor shards the
              # sequence (striped ring attention, comm_model ring_exchange)
              ("gdata2_gy2_gseq2", (2, 1, 2, 1, 2)),
              ("gdata1_gy2_gseq4", (1, 1, 2, 1, 4))]
    # every decomposition must factor the host devices exactly —
    # make_mesh rejects a mesh smaller than the device count
    shapes = [(n, s) for n, s in shapes
              if int(np.prod(s)) == jax.device_count()]
    if not shapes:
        return [("fig5_measured/skipped", 0.0,
                 f"needs 8 devices, have {jax.device_count()}")]
    seqs = (64, 128, 256)
    rows = []
    # set every (decomposition, seq) config up front, then time them in
    # interleaved rounds (min over rounds): host-load drift during the
    # sweep would otherwise correlate with whichever config ran under it
    runs = {}
    for name, shape in shapes:
        for S in seqs:
            cfg, fn, params, state, batch, _ = _train_setup(
                "stablelm-1.6b", shape, steps=steps, B=8, S=S)
            params, state, m = fn(params, state, batch)  # compile+warmup
            runs[(name, S)] = [fn, params, state, batch, m]
    results = {key: float("inf") for key in runs}
    for _ in range(3):
        for key, r in runs.items():
            fn, params, state, batch, m = r
            t0 = time.time()
            for _ in range(steps):
                params, state, m = fn(params, state, batch)
            jax.block_until_ready(m["loss"])
            results[key] = min(results[key],
                               (time.time() - t0) / steps * 1e6)
            r[:] = [fn, params, state, batch, m]
    for (name, S), us in results.items():
        rows.append((f"fig5_measured/{name}_s{S}", us,
                     f"loss={float(runs[(name, S)][4]['loss']):.3f}"))
    base = {name: results[(name, seqs[0])] for name, _ in shapes}
    best = min(base, key=base.get)
    rows.append(("fig5_measured/best", base[best],
                 f"config={best} (S={seqs[0]})"))
    # predicted grid (α-β-γ time model, calibrated when a profile is
    # given); wire bytes priced at the measured program's dtype (fp32) —
    # the profile's bytes_per_elem describes the production bf16 model
    hw = dataclasses.replace(CB.resolve_hw(calib), bytes_per_elem=4.0)
    layers = list(get_config("stablelm-1.6b").reduced().comm_layers())
    pred = {(name, S): CM.predict_step_time(
        layers, 8 * S, CM.Decomposition(*shape), hw).total
        for name, shape in shapes for S in seqs}
    pbase = {name: pred[(name, seqs[0])] for name, _ in shapes}
    pbest = min(pbase, key=pbase.get)
    rows.append(("fig5_measured/predicted_best", pbase[pbest] * 1e6,
                 f"config={pbest} measured_best={best} "
                 f"agree={pbest == best}"))
    keys = [(name, S) for name, _ in shapes for S in seqs]
    rho = CB.spearman([results[k] for k in keys],
                      [pred[k] for k in keys])
    rows.append(("fig5_measured/rank_correlation", rho,
                 f"calib={calib or 'none'} n={len(keys)} "
                 f"spearman(predicted, measured) over decomposition x "
                 f"seq grid"))
    names = [n for n, _ in shapes]
    rho_d = CB.spearman([base[n] for n in names],
                        [pbase[n] for n in names])
    rows.append(("fig5_measured/rank_correlation_decomp", rho_d,
                 f"decompositions only at S={seqs[0]} (n={len(names)}; "
                 f"near-tied on CPU hosts — noisy by construction)"))
    return rows


def fig6_validation(steps: int = 40) -> List[Tuple[str, float, str]]:
    """Paper Fig. 6: parallelization must not change statistical
    efficiency — identical data under the 4D mesh vs the Megatron point
    must give (numerically) the same loss curve."""
    curves = {}
    for name, shape in [("tensor4d", (2, 2, 2, 1)),
                        ("megatron1d", (2, 1, 4, 1))]:
        cfg, fn, params, state, batch, _ = _train_setup(
            "qwen3-1.7b", shape, steps=steps, B=8, S=64)
        losses = []
        for _ in range(steps):
            params, state, m = fn(params, state, batch)
            losses.append(float(m["loss"]))
        curves[name] = losses
    gap = max(abs(a - b) for a, b in zip(curves["tensor4d"],
                                         curves["megatron1d"]))
    assert gap < 2e-3, f"loss curves diverged: {gap}"
    return [("fig6/final_loss_tensor4d", curves["tensor4d"][-1],
             f"first={curves['tensor4d'][0]:.4f}"),
            ("fig6/final_loss_megatron", curves["megatron1d"][-1],
             f"max_curve_gap={gap:.2e}")]


def overdecomposition_overlap(steps: int = 6) -> List[Tuple[str, float, str]]:
    """Paper §4.2: overdecomposition must not change results; on real TPUs
    it overlaps comm/compute (we verify equivalence + report timing)."""
    rows = []
    for od in (1, 2):
        cfg, fn, params, state, batch, _ = _train_setup(
            "stablelm-1.6b", (2, 2, 2, 1), steps=steps, B=8, S=64,
            overdecompose=od)
        params, state, m = fn(params, state, batch)
        t0 = time.time()
        for _ in range(steps):
            params, state, m = fn(params, state, batch)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / steps * 1e6
        rows.append((f"overdecomp/od{od}", us,
                     f"loss={float(m['loss']):.4f}"))
    return rows


def overlap_collectives(steps: int = 4) -> List[Tuple[str, float, str]]:
    """Ring-decomposed collective matmuls, before/after on the dry-run HLO
    (paper §4: overlap collectives with computation *inside* each layer).

    Lowers the same train step with the blocking schedule, the overlapped
    z-axis weight schedule (ring_z), and additionally the x/y activation
    all-reduce rings (ring_xy == OverlapConfig.all_on()), then reports:
    collective op counts (ring_z must replace the monolithic weight
    all-gather / reduce-scatter with collective-permute chains; ring_xy
    must additionally replace matmul all-reduces with permute chains),
    the overlap-aware exposed-communication estimate (must fall),
    wall-clock per step, and the loss gap after a few real steps (must be
    ~fp32-accum noise). Each config is compiled ONCE via
    ``lower().compile()``; the same executable serves the HLO stats and
    the timing loop. Per-mode optimized HLO is dumped to
    ``runs/bench_hlo/`` so CI can archive the before/after programs."""
    import os

    from repro.core.overlap import OverlapConfig
    from repro.launch import roofline as RL

    # the 8-device mesh exercises x, y and z rings at once; 4 host
    # devices keep y (activation) and z (weight) rings
    shape = (1, 2, 2, 2) if jax.device_count() >= 8 else (1, 1, 2, 2)
    hlo_dir = os.path.join("runs", "bench_hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    rows = []
    losses = {}
    counts = {}
    modes = [
        ("blocking", None),
        ("ring_z", OverlapConfig(matmul=True, batched_matmul=True,
                                 tied_logits=True)),
        ("ring_xy", OverlapConfig.all_on()),
        ("ring_c2", OverlapConfig.all_on(z_chunks=2, ar_chunks=2)),
    ]
    for name, ov in modes:
        cfg, fn, params, state, batch, _ = _train_setup(
            "stablelm-1.6b", shape, steps=steps, B=8, S=64, overlap=ov)
        compiled = fn.lower(params, state, batch).compile()
        hlo = compiled.as_text()
        with open(os.path.join(hlo_dir, f"overlap_{name}.hlo.txt"),
                  "w") as f:
            f.write(hlo)
        stats = RL.parse_collectives(hlo)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        est = RL.step_time_estimate(float(cost.get("flops", 0.0)),
                                    stats.bytes_by_kind)
        params, state, m = compiled(params, state, batch)  # warmup
        t0 = time.time()
        for _ in range(steps):
            params, state, m = compiled(params, state, batch)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / steps * 1e6
        losses[name] = float(m["loss"])
        c = counts[name] = stats.counts
        rows.append((
            f"overlap/{name}", us,
            f"ar={c.get('all-reduce', 0)} ag={c.get('all-gather', 0)} "
            f"rs={c.get('reduce-scatter', 0)} "
            f"cp={c.get('collective-permute', 0)} "
            f"exposed_us={est.exposed_comm * 1e6:.1f} "
            f"hidden_us={est.hidden_comm * 1e6:.1f} "
            f"loss={losses[name]:.4f}"))
    # the x/y mode must convert matmul all-reduces into permute chains
    # (norm/softmax scalar psums legitimately stay blocking)
    assert (counts["ring_xy"].get("all-reduce", 0)
            < counts["blocking"].get("all-reduce", 0)), counts
    assert (counts["ring_xy"].get("collective-permute", 0)
            > counts["ring_z"].get("collective-permute", 0)), counts
    gap = max(abs(losses[k] - losses["blocking"]) for k in losses)
    assert gap < 1e-3, f"overlapped schedule changed the loss: {gap}"
    rows.append(("overlap/loss_gap", gap, "ring vs blocking, fp32"))
    return rows


def dp_sync(steps: int = 4) -> List[Tuple[str, float, str]]:
    """Data-parallel gradient sync, before/after on the train-step HLO
    (core/gradsync.py): blocking per-leaf psum vs bucketed reduce-scatter
    rings vs ZeRO-1 (sharded AdamW + param all-gather) vs ZeRO-3
    (param-shard streaming, with and without prefetch).

    Each mode is compiled ONCE via ``lower().compile()``; the same
    executable serves the HLO stats and the timing loop, and its
    optimized HLO lands in ``runs/bench_hlo/dp_sync_<mode>.hlo.txt`` for
    the CI artifact. Asserts the subsystem's contract: under the ring
    modes the gradient path has NO data-axis all-reduce left above
    scalar size (the DP sync lowers to collective-permute chains — the
    scalar grad-norm/metrics psums legitimately stay blocking); under
    the zero3 modes NO full-parameter all-gather survives outside the
    streamed per-layer window (every data-axis gather/permute buffer is
    bounded by the largest single gathered unit of the leaf plan, far
    below the total param bytes); and the loss gap vs blocking is
    ~fp32-reassociation noise."""
    import os

    from repro.core.gradsync import GradSyncConfig
    from repro.launch import roofline as RL

    # dp=4 makes the data axis's replica-group size unambiguous against
    # the tensor axes (y=2 when the host has 8 devices)
    shape = (4, 1, 2, 1) if jax.device_count() >= 8 else (4, 1, 1, 1)
    dp = shape[0]
    hlo_dir = os.path.join("runs", "bench_hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    modes = [
        ("blocking", None),
        ("bucketed_ring", GradSyncConfig(bucketed=True, bucket_mb=0.25)),
        ("zero", GradSyncConfig(zero=True, bucket_mb=0.25)),
        ("zero3", GradSyncConfig(zero3=True, bucket_mb=0.25)),
        ("zero3_prefetch", GradSyncConfig(zero3=True, prefetch=True,
                                          bucket_mb=0.25)),
    ]
    rows, losses, counts, big_dp_ar = [], {}, {}, {}
    for name, gs in modes:
        cfg, fn, params, state, batch, tools = _train_setup(
            "stablelm-1.6b", shape, steps=steps, B=8, S=64,
            overdecompose=2, gradsync=gs)
        compiled = fn.lower(params, state, batch).compile()
        hlo = compiled.as_text()
        with open(os.path.join(hlo_dir, f"dp_sync_{name}.hlo.txt"),
                  "w") as f:
            f.write(hlo)
        ops = RL.parse_collective_ops(hlo)
        c = counts[name] = {}
        for op in ops:
            c[op.kind] = c.get(op.kind, 0) + 1
        big_dp_ar[name] = sum(1 for op in ops if op.kind == "all-reduce"
                              and op.group_size == dp
                              and op.raw_bytes > 2048)
        extra = ""
        if gs is not None and gs.zero3:
            # the streamed-window contract: the largest data-axis gather
            # (or ring hop) buffer must stay within one gathered unit of
            # the leaf plan — no monolithic full-parameter all-gather
            plan = tools.plan
            unit = max(b.padded * jnp.dtype(b.dtype).itemsize
                       for b in plan.buckets)
            total_pb = sum(b.padded * b.stack
                           * jnp.dtype(b.dtype).itemsize
                           for b in plan.buckets)
            assert unit < total_pb / 2, (unit, total_pb)  # bound is real
            offenders = [op for op in ops
                         if op.kind in ("all-gather", "collective-permute")
                         and op.raw_bytes > unit]
            assert not offenders, \
                (f"{name}: param gathers above the per-layer streaming "
                 f"window (unit={unit}B): "
                 f"{[(o.kind, o.raw_bytes) for o in offenders[:5]]}")
            extra = (f" max_gather_B<= {unit} "
                     f"(total_param_B={total_pb})")
        stats = RL.parse_collectives(hlo)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        est = RL.step_time_estimate(float(cost.get("flops", 0.0)),
                                    stats.bytes_by_kind)
        params, state, m = compiled(params, state, batch)  # warmup
        t0 = time.time()
        for _ in range(steps):
            params, state, m = compiled(params, state, batch)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / steps * 1e6
        losses[name] = float(m["loss"])
        rows.append((
            f"dp_sync/{name}", us,
            f"ar={c.get('all-reduce', 0)} dp_ar_big={big_dp_ar[name]} "
            f"rs={c.get('reduce-scatter', 0)} "
            f"cp={c.get('collective-permute', 0)} "
            f"exposed_us={est.exposed_comm * 1e6:.1f} "
            f"hidden_us={est.hidden_comm * 1e6:.1f} "
            f"loss={losses[name]:.4f}{extra}"))
    assert big_dp_ar["blocking"] > 0, big_dp_ar  # baseline sanity
    for name in ("bucketed_ring", "zero", "zero3", "zero3_prefetch"):
        assert big_dp_ar[name] == 0, \
            f"{name}: DP gradient all-reduces survived: {big_dp_ar}"
        assert (counts[name].get("collective-permute", 0)
                > counts["blocking"].get("collective-permute", 0)), counts
    gap = max(abs(losses[k] - losses["blocking"]) for k in losses)
    assert gap < 1e-3, f"bucketed DP sync changed the loss: {gap}"
    rows.append(("dp_sync/loss_gap", gap,
                 "ring/zero/zero3 vs blocking, fp32"))
    return rows


def ring_attention(steps: int = 4) -> List[Tuple[str, float, str]]:
    """Context-parallel ring attention, before/after on the train-step HLO
    (layers/attention.py seq_attn over the 5th mesh axis).

    Three configs of the same model/data on 8 host devices: no seq axis
    (baseline), g_seq=4 with the blocking KV all-gather, and g_seq=4 with
    the ring schedule (``OverlapConfig(ring_attention=True)`` — per-hop KV
    blocks circulate via collective-permute while each hop's partial
    attention accumulates the online softmax). Each config is compiled
    ONCE via ``lower().compile()``; its optimized HLO lands in
    ``runs/bench_hlo/ring_attention_<mode>.hlo.txt`` for the CI artifact.
    Asserts the contract: the ring mode has NO seq-axis all-gather above
    scalar size (no rank ever materializes the full sequence — the KV
    exchange lowers to permute chains), and the loss gap vs the unsharded
    baseline is ~fp32-reassociation noise (striping only rearranges
    tokens; the LM loss is permutation-invariant)."""
    import os

    from repro.core.overlap import OverlapConfig
    from repro.launch import roofline as RL

    if jax.device_count() < 8:
        return [("ring_attention/skipped", 0.0,
                 f"needs 8 devices, have {jax.device_count()}")]
    pseq = 4
    hlo_dir = os.path.join("runs", "bench_hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    # seq=4 against y=2 keeps the seq axis's replica-group size
    # unambiguous in the HLO (dp=x=z=1)
    modes = [
        ("noseq", (1, 2, 2, 2), None),
        ("blocking", (1, 1, 2, 1, pseq), None),
        ("ring", (1, 1, 2, 1, pseq), OverlapConfig(ring_attention=True)),
    ]
    rows, losses, counts, big_seq_ag = [], {}, {}, {}
    for name, shape, ov in modes:
        cfg, fn, params, state, batch, _ = _train_setup(
            "stablelm-1.6b", shape, steps=steps, B=8, S=64, overlap=ov)
        compiled = fn.lower(params, state, batch).compile()
        hlo = compiled.as_text()
        with open(os.path.join(hlo_dir, f"ring_attention_{name}.hlo.txt"),
                  "w") as f:
            f.write(hlo)
        ops = RL.parse_collective_ops(hlo)
        c = counts[name] = {}
        for op in ops:
            c[op.kind] = c.get(op.kind, 0) + 1
        big_seq_ag[name] = sum(1 for op in ops if op.kind == "all-gather"
                               and op.group_size == pseq
                               and op.raw_bytes > 2048)
        stats = RL.parse_collectives(hlo)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        est = RL.step_time_estimate(float(cost.get("flops", 0.0)),
                                    stats.bytes_by_kind)
        params, state, m = compiled(params, state, batch)  # warmup
        t0 = time.time()
        for _ in range(steps):
            params, state, m = compiled(params, state, batch)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / steps * 1e6
        losses[name] = float(m["loss"])
        rows.append((
            f"ring_attention/{name}", us,
            f"ar={c.get('all-reduce', 0)} ag={c.get('all-gather', 0)} "
            f"seq_ag_big={big_seq_ag[name]} "
            f"cp={c.get('collective-permute', 0)} "
            f"exposed_us={est.exposed_comm * 1e6:.1f} "
            f"hidden_us={est.hidden_comm * 1e6:.1f} "
            f"loss={losses[name]:.4f}"))
    # blocking gathers the full KV sequence; the ring must not
    assert big_seq_ag["blocking"] > 0, big_seq_ag
    assert big_seq_ag["ring"] == 0, \
        f"ring mode gathered the full sequence: {big_seq_ag}"
    assert (counts["ring"].get("collective-permute", 0)
            > counts["blocking"].get("collective-permute", 0)), counts
    gap = max(abs(losses[k] - losses["noseq"]) for k in losses)
    assert gap < 1e-3, f"seq sharding changed the loss: {gap}"
    rows.append(("ring_attention/loss_gap", gap,
                 "blocking/ring g_seq=4 vs unsharded, fp32"))
    return rows


def expert_a2a(steps: int = 4) -> List[Tuple[str, float, str]]:
    """Expert-parallel MoE dispatch, before/after on the train-step HLO
    (layers/moe.py over the 6th mesh axis, core/collective_matmul.py
    ring_a2a_expert).

    Three configs of the same MoE model/data on 8 host devices: no
    expert axis (the extra factor spent on g_data instead — the expert
    axis at g_expert=1 is a second batch axis, so the baseline sees the
    identical token shards), g_expert=2 with the blocking
    ``lax.all_to_all`` dispatch/combine, and g_expert=2 with the ring
    schedule (``OverlapConfig(expert_a2a=True)`` — per-destination
    capacity blocks hop via collective-permute, each hop's expert FFN
    runs while later blocks are still in flight). Each config is
    compiled ONCE via ``lower().compile()``; its optimized HLO lands in
    ``runs/bench_hlo/expert_a2a_<mode>.hlo.txt`` for the CI artifact.
    Asserts the contract: the ring mode has NO all-to-all above scalar
    size (the dispatch lowers to permute chains), and the loss gap vs
    the no-expert-axis baseline is ~fp32-reassociation noise (the ring
    round trip is algebraically the blocking a2a pair)."""
    import os

    from repro.core.overlap import OverlapConfig
    from repro.launch import roofline as RL

    if jax.device_count() < 8:
        return [("expert_a2a/skipped", 0.0,
                 f"needs 8 devices, have {jax.device_count()}")]
    pex = 2
    hlo_dir = os.path.join("runs", "bench_hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    # expert=2 against data=1 keeps the batch shards identical to the
    # noexp baseline (batch axes = data + z + expert in both)
    enames = ("data", "x", "y", "z", "expert")
    modes = [
        ("noexp", (2, 2, 2, 1), None, None),
        ("blocking", (1, 2, 2, 1, pex), None, enames),
        ("ring", (1, 2, 2, 1, pex),
         OverlapConfig(expert_a2a=True), enames),
    ]
    rows, losses, counts, big_a2a = [], {}, {}, {}
    for name, shape, ov, names in modes:
        cfg, fn, params, state, batch, _ = _train_setup(
            "deepseek-v2-lite-16b", shape, steps=steps, B=8, S=64,
            overlap=ov, names=names)
        compiled = fn.lower(params, state, batch).compile()
        hlo = compiled.as_text()
        with open(os.path.join(hlo_dir, f"expert_a2a_{name}.hlo.txt"),
                  "w") as f:
            f.write(hlo)
        ops = RL.parse_collective_ops(hlo)
        c = counts[name] = {}
        for op in ops:
            c[op.kind] = c.get(op.kind, 0) + 1
        big_a2a[name] = sum(1 for op in ops if op.kind == "all-to-all"
                            and op.raw_bytes > 2048)
        stats = RL.parse_collectives(hlo)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        est = RL.step_time_estimate(float(cost.get("flops", 0.0)),
                                    stats.bytes_by_kind)
        params, state, m = compiled(params, state, batch)  # warmup
        t0 = time.time()
        for _ in range(steps):
            params, state, m = compiled(params, state, batch)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / steps * 1e6
        losses[name] = float(m["loss"])
        rows.append((
            f"expert_a2a/{name}", us,
            f"a2a={c.get('all-to-all', 0)} a2a_big={big_a2a[name]} "
            f"ar={c.get('all-reduce', 0)} "
            f"cp={c.get('collective-permute', 0)} "
            f"exposed_us={est.exposed_comm * 1e6:.1f} "
            f"hidden_us={est.hidden_comm * 1e6:.1f} "
            f"loss={losses[name]:.4f}"))
    # blocking dispatches via all-to-all; the ring must not
    assert big_a2a["blocking"] > 0, big_a2a
    assert big_a2a["ring"] == 0, \
        f"ring mode still lowered to all-to-all: {big_a2a}"
    assert (counts["ring"].get("collective-permute", 0)
            > counts["blocking"].get("collective-permute", 0)), counts
    gap = max(abs(losses[k] - losses["noexp"]) for k in losses)
    assert gap < 1e-3, f"expert sharding changed the loss: {gap}"
    rows.append(("expert_a2a/loss_gap", gap,
                 "blocking/ring g_expert=2 vs no expert axis, fp32"))
    return rows


def kernel_micro() -> List[Tuple[str, float, str]]:
    """Pallas kernels (interpret mode — correctness execution on CPU, the
    BlockSpec tiling is the TPU artifact) vs their jnp oracles."""
    from repro.kernels import ops, ref
    rows = []

    def time_fn(fn, *args, reps=3):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.time() - t0) / reps * 1e6

    a = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    rows.append(("kernel/block_matmul_interp",
                 time_fn(lambda x, y: ops.matmul(x, y, bm=128, bn=128,
                                                 bk=128), a, b),
                 "256x256x256"))
    rows.append(("kernel/matmul_xla", time_fn(
        jax.jit(ref.block_matmul_ref), a, b), "256x256x256"))

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 64))
    rows.append(("kernel/flash_attn_interp",
                 time_fn(lambda *t: ops.flash_attention(*t, bq=128, bk=128),
                         q, k, v), "T=S=256 h=4/2"))
    rows.append(("kernel/attn_ref_xla", time_fn(
        jax.jit(ref.flash_attention_ref), q, k, v), "T=S=256"))
    return rows
