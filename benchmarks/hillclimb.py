"""§Perf hillclimb driver: run dry-run variants of the three selected
(arch x shape) pairs and log hypothesis -> change -> before/after.

  PYTHONPATH=src python -m benchmarks.hillclimb --pair nemotron-4-15b:train_4k \
      --variant baseline --variant od2 ...

Variants (each an explicit, named change against the pair's baseline):
  paper1d      Megatron 1D point on the mandated mesh (paper baseline)
  tensor4d     comm-model-optimal factors (the paper's technique)
  od2          + overdecomposition=2 (paper §4.2)
  dots         + remat policy "dots" (save matmul outputs; beyond-paper)
  cacheag      + cached weight gather (1 AG_z instead of 2; beyond-paper)
  zero         + ZeRO-1 DP sync (bucketed grad rings, sharded AdamW)
  zero3        + ZeRO-3 param-shard streaming (per-layer JIT gathers)
  zero3_prefetch   zero3 with next-layer prefetch/retention
  seqring      + context parallelism (--seq-parallel: g_seq chosen by the
               model, striped ring attention over the seq mesh axis)
  seqring4     seqring with g_seq pinned to 4
  expertring   + expert parallelism (--expert-parallel: g_expert chosen
               by the model, ring-decomposed MoE a2a over the expert
               mesh axis; MoE archs only)
  expertring4  expertring with g_expert pinned to 4
  dsv3         deepseek-v3-shaped: expertring + overdecomposition=2
               (the production MoE recipe — pair it with an MoE arch,
               e.g. --pair deepseek-v3-671b:train_4k)
  factors=a,b,c,d[,s[,e]]   explicit decomposition override (5th value
               opens the seq axis, 6th the expert axis)
Results append runs/perf/hillclimb.jsonl (per-rank param+optimizer
bytes land next to the step-time roofline in every record).
"""
import argparse
import json
import os


def run_variant(arch, shape, variant, out, probe=True, calib=""):
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from repro.launch import dryrun as DR
    kw = dict(probe=probe, calib=calib)
    mesh = "tensor4d"
    if variant == "paper1d":
        mesh = "baseline-1d"
    elif variant == "tensor4d":
        pass
    elif variant == "od2":
        kw["overdecompose"] = 2
    elif variant == "dots":
        kw["remat_policy"] = "dots"
    elif variant == "cacheag":
        kw["cache_gather"] = True
    elif variant == "zero":
        # ZeRO-sharded DP sync (core/gradsync.py): bucketed ring
        # reduce-scatter + data-sharded AdamW state
        kw["zero"] = True
    elif variant == "zero3":
        # ZeRO-3 (core/gradsync.py): params live as 1/G_data shards,
        # per-layer working copies streamed through the layer scan
        kw["zero3"] = True
    elif variant == "zero3_prefetch":
        kw["zero3"] = True
        kw["zero3_prefetch"] = True
    elif variant == "od2+zero":
        kw["overdecompose"] = 2
        kw["zero"] = True
    elif variant == "od2+zero3":
        kw["overdecompose"] = 2
        kw["zero3"] = True
    elif variant == "od2+dots":
        kw["overdecompose"] = 2
        kw["remat_policy"] = "dots"
    elif variant == "dots+cacheag":
        kw["remat_policy"] = "dots"
        kw["cache_gather"] = True
    elif variant == "seqring":
        # context parallelism: striped ring attention over the 5th mesh
        # factor, g_seq chosen jointly by the communication model
        kw["seq_parallel"] = True
        kw["overlap"] = True     # ring (not blocking-gather) KV schedule
    elif variant.startswith("seqring"):
        kw["seq_parallel"] = True
        kw["overlap"] = True
        kw["g_seq"] = int(variant[len("seqring"):])
    elif variant == "expertring":
        # expert parallelism: ring-decomposed MoE dispatch/combine over
        # the 6th mesh factor, g_expert chosen jointly by the model
        kw["expert_parallel"] = True
        kw["overlap"] = True     # ring (not blocking) a2a schedule
    elif variant.startswith("expertring"):
        kw["expert_parallel"] = True
        kw["overlap"] = True
        kw["g_expert"] = int(variant[len("expertring"):])
    elif variant == "dsv3":
        # the deepseek-v3-shaped production recipe: expert-parallel ring
        # a2a + overdecomposition (pair with an MoE arch)
        kw["expert_parallel"] = True
        kw["overlap"] = True
        kw["overdecompose"] = 2
    elif variant.startswith("factors="):
        f = tuple(int(v) for v in variant.split("=")[1].split(","))
        assert len(f) in (4, 5, 6), "factors=a,b,c,d[,s[,e]]"
        kw["factors"] = f
        if len(f) > 4 and f[4] > 1:
            kw["seq_parallel"] = True
        if len(f) > 5 and f[5] > 1:
            kw["expert_parallel"] = True
    else:
        raise ValueError(variant)
    rec, _ = DR.lower_one(arch, shape, mesh, **kw)
    rec["variant"] = variant
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    r = rec["roofline"]
    print(f"{arch} {shape} {variant}: ct={r['compute_t']:.3f} "
          f"mt={r['memory_t']:.3f} lt={r['collective_t']:.3f} "
          f"dom={r['dominant']} useful={r['useful_ratio']:.2f} "
          f"mem={rec['memory'].get('total_per_device_bytes', 0)/1e9:.1f}GB "
          f"param+opt/rank="
          f"{rec['memory'].get('param_opt_bytes_per_rank', 0)/1e9:.2f}GB",
          flush=True)
    return rec


def build_parser():
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.hillclimb",
        description="Dry-run named perf variants of an (arch x shape) "
                    "pair and log before/after roofline records.")
    ap.add_argument("--pair", required=True, help="arch:shape")
    ap.add_argument("--variant", action="append", required=True,
                    help="named variant to run (repeatable; see the "
                         "module docstring for the variant catalog)")
    ap.add_argument("--out", default="runs/perf/hillclimb.jsonl",
                    help="JSONL log of before/after roofline records")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the depth-probe lowerings (CI smoke: the "
                         "compile proof + memory accounting only)")
    ap.add_argument("--calib", default="",
                    help="hardware calibration profile (path or 'auto'; "
                         "benchmarks.calibrate) pricing each variant's "
                         "factor chooser and step-time estimate")
    return ap


def main():
    args = build_parser().parse_args()
    arch, shape = args.pair.split(":")
    for v in args.variant:
        try:
            run_variant(arch, shape, v, args.out, probe=not args.no_probe,
                        calib=args.calib)
        except Exception as e:
            print(f"{arch} {shape} {v}: FAILED {type(e).__name__}: {e}",
                  flush=True)


if __name__ == "__main__":
    main()
