"""§Perf hillclimb driver: run dry-run variants of the three selected
(arch x shape) pairs and log hypothesis -> change -> before/after.

  PYTHONPATH=src python -m benchmarks.hillclimb --pair nemotron-4-15b:train_4k \
      --variant baseline --variant od2 ...

Variants (each an explicit, named change against the pair's baseline):
  paper1d      Megatron 1D point on the mandated mesh (paper baseline)
  tensor4d     comm-model-optimal factors (the paper's technique)
  od2          + overdecomposition=2 (paper §4.2)
  dots         + remat policy "dots" (save matmul outputs; beyond-paper)
  cacheag      + cached weight gather (1 AG_z instead of 2; beyond-paper)
  factors=a,b,c,d   explicit decomposition override
Results append runs/perf/hillclimb.jsonl.
"""
import argparse
import json
import os


def run_variant(arch, shape, variant, out):
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from repro.launch import dryrun as DR
    kw = dict(probe=True)
    mesh = "tensor4d"
    if variant == "paper1d":
        mesh = "baseline-1d"
    elif variant == "tensor4d":
        pass
    elif variant == "od2":
        kw["overdecompose"] = 2
    elif variant == "dots":
        kw["remat_policy"] = "dots"
    elif variant == "cacheag":
        kw["cache_gather"] = True
    elif variant == "zero":
        # ZeRO-sharded DP sync (core/gradsync.py): bucketed ring
        # reduce-scatter + data-sharded AdamW state
        kw["zero"] = True
    elif variant == "od2+zero":
        kw["overdecompose"] = 2
        kw["zero"] = True
    elif variant == "od2+dots":
        kw["overdecompose"] = 2
        kw["remat_policy"] = "dots"
    elif variant == "dots+cacheag":
        kw["remat_policy"] = "dots"
        kw["cache_gather"] = True
    elif variant.startswith("factors="):
        kw["factors"] = tuple(int(v) for v in
                              variant.split("=")[1].split(","))
    else:
        raise ValueError(variant)
    rec, _ = DR.lower_one(arch, shape, mesh, **kw)
    rec["variant"] = variant
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    r = rec["roofline"]
    print(f"{arch} {shape} {variant}: ct={r['compute_t']:.3f} "
          f"mt={r['memory_t']:.3f} lt={r['collective_t']:.3f} "
          f"dom={r['dominant']} useful={r['useful_ratio']:.2f} "
          f"mem={rec['memory'].get('total_per_device_bytes', 0)/1e9:.1f}GB",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, help="arch:shape")
    ap.add_argument("--variant", action="append", required=True)
    ap.add_argument("--out", default="runs/perf/hillclimb.jsonl")
    args = ap.parse_args()
    arch, shape = args.pair.split(":")
    for v in args.variant:
        try:
            run_variant(arch, shape, v, args.out)
        except Exception as e:
            print(f"{arch} {shape} {v}: FAILED {type(e).__name__}: {e}",
                  flush=True)


if __name__ == "__main__":
    main()
