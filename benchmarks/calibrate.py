"""Hardware calibration harness: measure, fit, persist, validate.

Times the real collective/GEMM primitives on the live backend
(``repro.core.calibrate``), least-squares-fits α/β per mesh-axis class
plus the GEMM rate and the overlap/cross-step efficiencies, and persists
a ``CalibrationProfile`` JSON that every ``--calib <path|auto>`` CLI flag
(dryrun / train / hillclimb / benchmarks.run) loads back into the
analytic model's ``HardwareParams``.

  # full sweep, saved to runs/calib/<backend>.json:
  PYTHONPATH=src python -m benchmarks.calibrate

  # CI smoke (fewer sizes/reps):
  PYTHONPATH=src python -m benchmarks.calibrate --quick

  # fit + measured validation grid (predicted-vs-measured rank
  # correlation over the fig5 decomposition grid):
  PYTHONPATH=src python -m benchmarks.calibrate --validate
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.calibrate",
        description="Measure collective α/β, GEMM rate and overlap "
                    "efficiencies on the live backend; fit and persist a "
                    "CalibrationProfile for the --calib flags.")
    ap.add_argument("--out", default="",
                    help="profile path (default runs/calib/<backend>.json)")
    ap.add_argument("--mesh", default="",
                    help="g_data,g_x,g_y,g_z over host devices "
                         "(default: auto-factor the device count)")
    ap.add_argument("--sizes", default="4096,16384,65536,262144",
                    help="message-size sweep in buffer elements")
    ap.add_argument("--reps", type=int, default=5,
                    help="timing repetitions per point (min is kept)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: first 3 sizes, fewer reps")
    ap.add_argument("--no-samples", action="store_true",
                    help="omit the raw timing samples from the JSON")
    ap.add_argument("--validate", action="store_true",
                    help="after fitting, run the measured fig5 "
                         "decomposition grid and report the predicted-"
                         "vs-measured step-time rank correlation")
    ap.add_argument("--steps", type=int, default=6,
                    help="train steps per timing round in --validate")
    return ap


def main():
    args = build_parser().parse_args()
    import dataclasses

    from repro.core import calibrate as CB
    from repro.launch import mesh as LM

    mesh = None
    if args.mesh:
        shape = tuple(int(v) for v in args.mesh.split(","))
        mesh = LM.make_smoke_mesh(shape, ("data", "x", "y", "z"))
    sizes = tuple(int(v) for v in args.sizes.split(","))
    prof = CB.run_calibration(mesh=mesh, sizes=sizes, reps=args.reps,
                              quick=args.quick)
    if args.no_samples:
        prof = dataclasses.replace(prof, samples=())
    out = args.out or CB.default_path(prof.backend)
    prof.save(out)

    print(f"backend={prof.backend} devices={prof.n_devices} "
          f"mesh={prof.mesh_shape}")
    print(f"alpha={prof.alpha:.3e} s/hop  gamma={prof.gamma:.3e} s/call  "
          f"link_bw={prof.link_bw:.3e} B/s  "
          f"flops={prof.flops:.3e} FLOP/s  (fit r2={prof.fit_r2:.3f})")
    for f in prof.axis_fits:
        print(f"  axis {f.axis} (p={f.p}): alpha={f.alpha:.3e} "
              f"gamma={f.gamma:.3e} bw={f.link_bw:.3e} r2={f.r2:.3f} "
              f"n={f.n_samples}")
    print(f"overlap_efficiency={prof.overlap_efficiency:.3f} "
          f"z_claims_first={prof.z_claims_first} "
          f"cross_step_efficiency={prof.cross_step_efficiency:.3f}")
    for k, v in sorted(prof.probes.items()):
        print(f"  probe {k}={v:.6g}")
    print("saved", out)

    if args.validate:
        from benchmarks import measured
        print("name,us_per_call,derived")
        for label, val, derived in measured.fig5_measured(
                steps=args.steps, calib=out):
            print(f"{label},{val:.2f},{derived}", flush=True)


if __name__ == "__main__":
    main()
