"""Open-loop serving benchmark: continuous batching vs fixed batching.

One synthetic open-loop workload (Poisson arrivals, uniform prompts,
seeded per-request generation lengths) is served twice at every mesh:

  * ``continuous`` — the paged engine (launch/serving): chunked prefill
    rides the decode step, requests admit/evict every iteration;
  * ``fixed`` — the head-of-line baseline: requests are batched in
    arrival order, each batch prefills together and decodes in lockstep
    until its LONGEST member finishes (finished slots burn compute).

Both paths sample greedy argmax over the full padded vocab, so the
generated ids must match request-for-request — the paged-vs-dense token
parity assert. Continuous must win on tokens/s at the same mesh (it
reclaims the idle decode slots and the head-of-line wait); the run fails
loudly if it does not.

``serve_capacity`` (core/comm_model.py) predicts tokens/s per mesh from
the α-β-γ constants; the report ends with the Spearman rank correlation
of predicted vs measured throughput over the mesh sweep.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m benchmarks.serving

Writes ``runs/perf/serving.csv`` (one row per mesh x mode) and prints
the same rows as ``name,us_per_call,derived`` CSV for benchmarks.run.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

# mesh sweep: every candidate must factor the host devices exactly and
# keep g_seq == 1 (serving is gated to non-seq-sharded meshes)
MESHES = [("gdata2_gx2_gy2", (2, 2, 2, 1)),
          ("gdata1_gx2_gy2_gz2", (1, 2, 2, 2)),
          ("gdata4_gy2", (4, 1, 2, 1))]


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.serving",
        description="Open-loop serving benchmark: continuous batching "
                    "(paged KV) vs the fixed-batch head-of-line "
                    "baseline, same workload, same meshes, plus the "
                    "serve_capacity predicted-vs-measured rank check.")
    ap.add_argument("--arch", default="qwen3-1.7b",
                    help="architecture name (attention-only decoder)")
    ap.add_argument("--requests", type=int, default=16,
                    help="synthetic requests (rounded up to a multiple "
                         "of --slots)")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="open-loop Poisson arrival rate in requests/s")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="prompt length in tokens (uniform — the dense "
                         "baseline needs a rectangular prefill)")
    ap.add_argument("--gen-min", type=int, default=4,
                    help="per-request generation length lower bound")
    ap.add_argument("--gen-max", type=int, default=32,
                    help="per-request generation length upper bound")
    ap.add_argument("--slots", type=int, default=8,
                    help="concurrent slots / fixed batch width")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (continuous mode)")
    ap.add_argument("--pages", type=int, default=48,
                    help="physical KV pages per batch shard (incl. the "
                         "reserved null page)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk rows per mixed step")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed")
    ap.add_argument("--calib", default="",
                    help="hardware calibration profile (path or 'auto'; "
                         "benchmarks.calibrate) pricing the "
                         "serve_capacity predictions")
    ap.add_argument("--telemetry", action="store_true",
                    help="per-step scheduler counters as JSONL under "
                         "runs/telemetry/serving-<mesh>.jsonl; the JSONL "
                         "summary carries the engine's tokens/s so it "
                         "agrees with --out by construction")
    ap.add_argument("--out", default="runs/perf/serving.csv",
                    help="per-mesh results CSV path")
    return ap


def _workload(args, vocab: int) -> list:
    """Seeded open-loop request list (shared by both serving modes)."""
    from repro.launch.serving import Request
    n = -(-args.requests // args.slots) * args.slots
    rng = np.random.RandomState(args.seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / args.rate))
        reqs.append(Request(
            rid=i,
            prompt=rng.randint(1, vocab,
                               size=(args.prompt_len,)).astype(np.int32),
            max_new=int(rng.randint(args.gen_min, args.gen_max + 1)),
            arrival=t))
    return reqs


def _fresh(reqs: list) -> list:
    """Per-mode copies — the scheduler mutates request state in place."""
    import copy
    out = []
    for r in reqs:
        c = copy.copy(r)
        c.generated, c.pages = [], []
        c.state, c.slot, c.pos = "queued", -1, 0
        c.t_first = c.t_done = -1.0
        c.preemptions, c.admit_seq = 0, -1
        out.append(c)
    return out


def _setup_model(arch: str, shape):
    from repro.configs import get_config
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import mesh as LM
    from repro.launch import steps as ST

    mesh = LM.make_smoke_mesh(shape, ("data", "x", "y", "z"))
    axes = LM.bind_4d(mesh)
    cfg = get_config(arch).reduced()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    return cfg, mesh, axes, params


def run_fixed_baseline(cfg, mesh, axes, params, reqs, args):
    """Head-of-line fixed batching: arrival-order batches of ``slots``
    prefill together, then decode lockstep until the longest member is
    done. Fills each request's ``generated``/timing fields; returns a
    ServeStats like the engine's."""
    from repro.launch import steps as ST
    from repro.launch.serving.engine import ServeStats, percentiles

    B, L = args.slots, args.prompt_len
    S_max = L + max(r.max_new for r in reqs)
    pre_build, _ = ST.make_prefill_step(cfg, mesh, axes, dtype=jnp.float32)
    pre_fn, _, ct = pre_build(B, L, S_max)
    dec_build, _ = ST.make_decode_step(cfg, mesh, axes, dtype=jnp.float32)
    dec_fn, _ = dec_build(B, S_max)

    def one_batch(batch_reqs, caches, t0):
        # head-of-line: the batch launches only once EVERY member arrived
        wait = max(r.arrival for r in batch_reqs) - (time.time() - t0)
        if wait > 0:
            time.sleep(wait)
        toks = jnp.asarray(np.stack([r.prompt for r in batch_reqs]),
                           jnp.int32)
        logits, caches = pre_fn(params, caches, {"tokens": toks})
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        ids = np.asarray(tok)
        now = time.time() - t0
        for i, r in enumerate(batch_reqs):
            r.generated.append(int(ids[i]))
            r.t_first = now
            if r.max_new == 1:
                r.t_done = now
        gen_max = max(r.max_new for r in batch_reqs)
        tok = tok[:, None]
        for step in range(gen_max - 1):
            logits, caches = dec_fn(params, caches, tok,
                                    jnp.int32(L + step))
            tok = jnp.argmax(logits[:, 0, :], axis=-1).astype(
                jnp.int32)[:, None]
            ids = np.asarray(tok)[:, 0]
            now = time.time() - t0
            for i, r in enumerate(batch_reqs):
                if len(r.generated) < r.max_new:
                    r.generated.append(int(ids[i]))
                    if len(r.generated) == r.max_new:
                        r.t_done = now
        return caches

    # warmup: compile both programs outside the timed window
    warm = ST.zeros_caches(mesh, ct)
    wt = jnp.zeros((B, L), jnp.int32)
    wl, warm = pre_fn(params, warm, {"tokens": wt})
    wl, warm = dec_fn(params, warm,
                      jnp.zeros((B, 1), jnp.int32), jnp.int32(L))
    jax.block_until_ready(wl)
    del warm

    t0 = time.time()
    n_steps = 0
    for k in range(0, len(reqs), B):
        caches = ST.zeros_caches(mesh, ct)
        batch_reqs = reqs[k:k + B]
        caches = one_batch(batch_reqs, caches, t0)
        n_steps += max(r.max_new for r in batch_reqs)
        del caches
    wall = time.time() - t0
    total_new = sum(len(r.generated) for r in reqs)
    l50, l99 = percentiles([(r.t_done - r.arrival) * 1e3 for r in reqs])
    f50, f99 = percentiles([(r.t_first - r.arrival) * 1e3 for r in reqs])
    return ServeStats(n_requests=len(reqs), total_new_tokens=total_new,
                      wall_s=wall, latency_p50_ms=l50, latency_p99_ms=l99,
                      ttft_p50_ms=f50, ttft_p99_ms=f99, n_steps=n_steps,
                      n_preemptions=0)


def run_continuous(cfg, mesh, axes, params, reqs, args, mesh_name=""):
    from repro.launch.serving import PagedEngine, ServeConfig
    scfg = ServeConfig(slots=args.slots, page_size=args.page_size,
                       pages_per_shard=args.pages, chunk=args.chunk)
    engine = PagedEngine(cfg, mesh, axes, params, scfg,
                         dtype=jnp.float32)
    engine.warmup()
    telem = None
    if getattr(args, "telemetry", False):
        from repro.core import comm_model as CM
        from repro.launch import telemetry as TL
        telem = TL.Telemetry(
            f"serving-{mesh_name or 'mesh'}",
            flops_per_token=CM.model_flops_per_token(cfg, "serve"),
            peak_flops_per_device=CM.TPU_V5E.flops,
            n_devices=int(mesh.devices.size), verbose=False,
            meta={"arch": cfg.name, "mesh": mesh_name,
                  "slots": args.slots, "pages": args.pages,
                  "rate": args.rate})
    stats = engine.run(reqs, telemetry=telem)
    if telem is not None:
        # the CSV row and the JSONL summary must quote the SAME number:
        # both take tokens/s from the engine's open-loop wall clock
        telem.close(extra={
            "tok_s": stats.tokens_per_s, "wall_s": stats.wall_s,
            "steps": stats.n_steps, "tokens": stats.total_new_tokens,
            "preemptions": stats.n_preemptions,
            "ttft_p50_ms": stats.ttft_p50_ms,
            "ttft_p99_ms": stats.ttft_p99_ms})
    for alloc in engine.sched.allocators:
        alloc.check()
        assert alloc.n_used == 0, "pages leaked after drain"
    return stats


def _predicted_tokens_per_s(cfg, shape, args, calib: str):
    from repro.core import calibrate as CB
    from repro.core import comm_model as CM
    hw = dataclasses.replace(CB.resolve_hw(calib or None),
                             bytes_per_elem=4.0)
    layers = list(cfg.comm_layers())
    # steady-state decode: batch = slots, context = mean tokens resident
    context = args.prompt_len + (args.gen_min + args.gen_max) / 2.0
    cap = CM.serve_capacity(layers, args.slots,
                            CM.Decomposition(*shape[:4]), hw,
                            context=context)
    return cap.tokens_per_s, cap.step_latency_ms


def suite(calib: str = "", args=None) -> List[Tuple[str, float, str]]:
    """benchmarks.run entry: serve the workload at every mesh that fits
    the host devices, both modes, assert continuous > fixed and token
    parity, report measured + predicted rows and the Spearman rank."""
    from repro.core import calibrate as CB

    if args is None:
        args = build_parser().parse_args([])
    meshes = [(n, s) for n, s in MESHES
              if int(np.prod(s)) == jax.device_count()
              and args.slots % (s[0] * s[3]) == 0]
    if not meshes:
        return [("serving/skipped", 0.0,
                 f"no candidate mesh factors {jax.device_count()} "
                 f"devices")]

    rows, csv_rows = [], []
    measured, predicted = [], []
    for name, shape in meshes:
        cfg, mesh, axes, params = _setup_model(args.arch, shape)
        base = _workload(args, cfg.vocab_size)
        fixed_reqs = _fresh(base)
        cont_reqs = _fresh(base)
        fx = run_fixed_baseline(cfg, mesh, axes, params, fixed_reqs, args)
        ct = run_continuous(cfg, mesh, axes, params, cont_reqs, args,
                            mesh_name=name)

        # paged-vs-dense token parity: greedy ids must agree per request
        for rf, rc in zip(fixed_reqs, cont_reqs):
            assert rf.generated == rc.generated, (
                f"token parity broke at {name} rid={rf.rid}: "
                f"dense={rf.generated} paged={rc.generated}")
        # the tentpole claim: continuous batching strictly beats the
        # head-of-line baseline at the same mesh
        assert ct.tokens_per_s > fx.tokens_per_s, (
            f"continuous ({ct.tokens_per_s:.1f} tok/s) did not beat "
            f"fixed ({fx.tokens_per_s:.1f} tok/s) at {name}")

        pred_tps, pred_ms = _predicted_tokens_per_s(
            cfg, shape, args, calib)
        measured.append(ct.tokens_per_s)
        predicted.append(pred_tps)
        for mode, st in (("fixed", fx), ("continuous", ct)):
            rows.append((f"serving/{name}/{mode}", st.tokens_per_s,
                         f"tok/s lat_p50={st.latency_p50_ms:.1f}ms "
                         f"p99={st.latency_p99_ms:.1f}ms "
                         f"ttft_p50={st.ttft_p50_ms:.1f}ms "
                         f"preempt={st.n_preemptions}"))
            csv_rows.append(
                (name, mode, st.tokens_per_s, st.latency_p50_ms,
                 st.latency_p99_ms, st.ttft_p50_ms, st.ttft_p99_ms,
                 st.n_preemptions,
                 pred_tps if mode == "continuous" else ""))
        rows.append((f"serving/{name}/speedup",
                     ct.tokens_per_s / fx.tokens_per_s,
                     f"continuous/fixed tokens-per-s ratio"))
        rows.append((f"serving/{name}/predicted", pred_tps,
                     f"serve_capacity tok/s step={pred_ms:.3f}ms "
                     f"calib={calib or 'none'}"))

    if len(meshes) >= 2:
        rho = CB.spearman(measured, predicted)
        rows.append(("serving/rank_correlation", rho,
                     f"spearman(measured, predicted) tokens/s over "
                     f"{len(meshes)} meshes calib={calib or 'none'} "
                     f"(host-CPU caveat: per-step dispatch dominates "
                     f"at smoke scale and is unpriced by the model — "
                     f"see EXPERIMENTS.md #serving)"))

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("mesh,mode,tokens_per_s,latency_p50_ms,latency_p99_ms,"
                "ttft_p50_ms,ttft_p99_ms,n_preemptions,"
                "predicted_tokens_per_s\n")
        for r in csv_rows:
            f.write(",".join(str(x) for x in r) + "\n")
    rows.append(("serving/csv", float(len(csv_rows)),
                 f"rows written to {args.out}"))
    return rows


def main() -> None:
    args = build_parser().parse_args()
    print("name,us_per_call,derived")
    for label, val, derived in suite(calib=args.calib, args=args):
        print(f"{label},{val:.2f},{derived}", flush=True)


if __name__ == "__main__":
    main()
