"""Analytic reproductions of the paper's tables/figures from the
communication model (the quantities the paper profiles are collective
*volumes*, which the model predicts exactly; wall-clock panels are
hardware-bound and are covered by the measured sweep in fig5_measured)."""
from __future__ import annotations

import math
from typing import List, Tuple

from repro.core import comm_model as CM

GB = 1 << 30


def fig5_sweep() -> List[Tuple[str, float, str]]:
    """Paper Fig. 5: GPT-9B on 16 GPUs, iteration-volume for each
    (G_data, G_c) config; the model must place the optimum at
    G_data=2, G_c≈4.89 -> 4."""
    H, L = 6144, 24              # ~9B-ish GPT
    tokens = 64 * 2048           # paper: batch 64, seq 2048
    layers = CM.transformer_layers(H, n_layers=L)
    rows = []
    best = (None, float("inf"))
    for g_data in (1, 2):
        gt = 16 // g_data
        for gy in (1, 2, 4, 8, 16):
            if gt % gy:
                continue
            d = CM.Decomposition(g_data, gt // gy, gy, 1)
            v = CM.model_volume(layers, tokens, d) * 2 / GB  # bf16 GB
            rows.append((f"fig5/gdata{g_data}_gc{gy}", v,
                         f"volume_GB={v:.1f}"))
            if v < best[1]:
                best = (d, v)
    pred = CM.paper_optimal_gc(16 // 2)
    rows.append((f"fig5/optimum", best[1],
                 f"best={best[0]} paper_pred_gc={pred:.2f}"))
    assert best[0].g_data == 2 and best[0].g_y in (2, 4), best
    return rows


def fig8_weak_scaling() -> List[Tuple[str, float, str]]:
    """Paper Fig. 8 (right): GPT weak scaling 32->256 GPUs; Tensor4D's
    per-GPU volume flattens (Eq. 12) while Megatron grows ~sqrt(G)
    (Eq. 13)."""
    ladder = [  # (name, hidden, layers, g_tensor, gpus) — paper Table 3
        ("gpt5b", 4096, 24, 4, 32),
        ("gpt10b", 5760, 24, 8, 64),
        ("gpt20b", 8192, 24, 16, 128),
        ("gpt40b", 11520, 24, 32, 256),
    ]
    tokens = 1024 * 2048
    rows = []
    for name, H, L, gt, g in ladder:
        layers = CM.transformer_layers(H, n_layers=L)
        # the paper's algorithm (2D tensor grid, z=1) — Eq. 12 regime
        t3d = CM.optimize_decomposition(
            layers, tokens, g, CM.Constraints(min_tensor=gt, max_y=64,
                                              z_divides=(1,)),
            top_k=1)[0]
        # the 4D generalization (z free): weight AG/RS traffic grows with
        # params in weak scaling, so z helps less here than in Fig. 5
        t4d = CM.optimize_decomposition(
            layers, tokens, g, CM.Constraints(min_tensor=gt, max_y=64),
            top_k=1)[0]
        mega = CM.model_volume(layers, tokens,
                               CM.megatron_decomposition(g, gt))
        o3 = t3d[1] * 2 / GB
        o4 = t4d[1] * 2 / GB
        mg = mega * 2 / GB
        rows.append((f"fig8/{name}_tensor3d", o3, f"{t3d[0]} GB={o3:.1f}"))
        rows.append((f"fig8/{name}_tensor4d", o4, f"{t4d[0]} GB={o4:.1f}"))
        rows.append((f"fig8/{name}_megatron", mg,
                     f"GB={mg:.1f} reduction_vs_3d="
                     f"{100 * (1 - o3 / mg):.0f}%"))
    # Eq. 12/13 asymptotics: paper curves — 3d roughly flat, megatron ~sqrt(G)
    o = [r[1] for r in rows if r[0].endswith("tensor3d")]
    m = [r[1] for r in rows if r[0].endswith("megatron")]
    assert m[-1] / m[0] > 1.5, "megatron volume should grow with G"
    assert o[-1] / o[0] < m[-1] / m[0], "tensor3d should grow slower"
    return rows


def unet_comm_layers(channels: int, levels: int = 4,
                     res_blocks: int = 3) -> List[CM.LayerShape]:
    """Eq. 8's layer list for the paper's U-Net: per level, res blocks of
    a normal (cin->cout) + transposed (cout->cout) conv pair; tokens per
    level shrink 4x with each downsample (tokens_scale)."""
    out = []
    cin = channels
    for lv in range(levels):
        cout = channels * (2 ** lv)
        scale = 0.25 ** lv
        for b in range(res_blocks):
            out.append(CM.LayerShape(cin, cout, tokens_scale=scale))
            out.append(CM.LayerShape(cout, cout, transposed=True,
                                     tokens_scale=scale))
            cin = cout
    return out


def fig7_unet_weak_scaling() -> List[Tuple[str, float, str]]:
    """Paper Fig. 7 (right): U-Net weak scaling 32->256 GPUs (Table 2
    ladder: channels x sqrt(2) per doubling), per-GPU comm volume,
    Tensor3D vs Megatron. The paper measures 53-80% reductions."""
    ladder = [("unet3.5b", 2048, 4, 32), ("unet7.5b", 3072, 8, 64),
              ("unet14b", 4096, 16, 128), ("unet28b", 5760, 32, 256)]
    tokens = 2048 * 16 * 16   # batch 2048 images x (128/8)^2 latent pixels
    rows = []
    for name, ch, gt, g in ladder:
        layers = unet_comm_layers(ch)
        t3d = CM.optimize_decomposition(
            layers, tokens, g, CM.Constraints(min_tensor=gt, max_y=64,
                                              z_divides=(1,)), top_k=1)[0]
        mega = CM.model_volume(layers, tokens,
                               CM.megatron_decomposition(g, gt))
        o3 = t3d[1] * 2 / GB
        mg = mega * 2 / GB
        rows.append((f"fig7/{name}_tensor3d", o3, f"{t3d[0]} GB={o3:.1f}"))
        rows.append((f"fig7/{name}_megatron", mg,
                     f"GB={mg:.1f} reduction={100 * (1 - o3 / mg):.0f}%"))
    red_last = 1 - rows[-2][1] / rows[-1][1]
    assert red_last > 0.4, rows[-2:]  # paper: up to 80% at 256 GPUs
    return rows


def table5_cai3d() -> List[Tuple[str, float, str]]:
    """Paper Table 5: GPT-10B on 64 GPUs, Tensor4D vs Colossal-AI-3D.
    CAI-3D uses the symmetric cube (4,4,4) on the tensor group (here the
    whole 64 since its G_data folds in); we model both."""
    H, L = 5760, 24
    tokens = 1024 * 2048
    layers = CM.transformer_layers(H, n_layers=L)
    best = CM.optimize_decomposition(
        layers, tokens, 64, CM.Constraints(min_tensor=8), top_k=1)[0]
    ours = best[1] * 2 / GB
    cai = CM.cai3d_decomposition(64, 64)
    v_cai = CM.model_volume(layers, tokens, cai) * 2 / GB
    red = 100 * (1 - ours / v_cai)
    return [
        ("table5/gpt10b_tensor4d", ours, f"{best[0]} GB={ours:.1f}"),
        ("table5/gpt10b_cai3d", v_cai,
         f"{cai} GB={v_cai:.1f} reduction={red:.0f}% (paper: 70%)"),
    ]


def eq11_asymptote() -> List[Tuple[str, float, str]]:
    """Eq. 12: Tensor4D per-GPU volume tends to a constant in weak
    scaling; report the fitted alpha0."""
    tokens = 1024 * 2048
    vols = []
    for g, gt in [(32, 4), (64, 8), (128, 16), (256, 32), (512, 64)]:
        H = int(4096 * math.sqrt(g / 32))
        H -= H % 64
        layers = CM.transformer_layers(H, n_layers=24)
        d = CM.optimize_decomposition(
            layers, tokens, g, CM.Constraints(min_tensor=gt, max_y=64,
                                              z_divides=(1,)),
            top_k=1)[0]
        vols.append(d[1] * 2 / GB)
    slope_last = (vols[-1] - vols[-2]) / vols[-2]
    return [("eq12/alpha0_GB", vols[-1],
             f"ladder={['%.1f' % v for v in vols]} "
             f"last_rel_slope={slope_last:.3f}")]
