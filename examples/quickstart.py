"""Quickstart: init a small 4D-parallel model on 8 host devices, take a few
training steps, then decode a few tokens — the whole public API in ~60
lines.

  PYTHONPATH=src python examples/quickstart.py [arch]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.partition import spec_tree_to_pspecs
from repro.data.synthetic import DataConfig, SyntheticText, make_batch
from repro.launch import mesh as LM
from repro.launch import steps as ST
from repro.optim.adamw import AdamWConfig, init_state

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-1.7b"

# 1. a 4D mesh: (data=2, x=2, y=2, z=1) over 8 host devices
mesh = LM.make_smoke_mesh((2, 2, 2, 1))
axes = LM.bind_4d(mesh)

# 2. the reduced (smoke) member of the architecture family
cfg = get_config(arch).reduced()
params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                              dtype=jnp.float32)
params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
state = init_state(params)
print(f"{cfg.name}: {sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))/1e6:.1f}M params"
      f" on mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

# 3. train a few steps on deterministic synthetic data
step_fn, _, _ = ST.make_train_step(
    cfg, mesh, axes, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30),
    ST.TrainOptions(overdecompose=2, dtype=jnp.float32))
data = SyntheticText(DataConfig(cfg.vocab_size, 64, 8))
for step in range(20):
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, step, data).items()}
    params, state, m = step_fn(params, state, batch)
    if step % 5 == 0:
        print(f"step {step:3d}  loss {float(m['loss']):.4f}")

# 4. greedy-decode a few tokens with the KV cache
build, _ = ST.make_decode_step(cfg, mesh, axes, dtype=jnp.float32)
decode, cache_tree = build(2, 32)
caches = ST.zeros_caches(mesh, cache_tree)
tok = jnp.zeros((2, 1), jnp.int32)
out = []
for pos in range(8):
    logits, caches = decode(params, caches, tok, jnp.int32(pos))
    tok = jnp.argmax(logits[:, 0, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    out.append(int(tok[0, 0]))
print("decoded ids:", out)
print("QUICKSTART OK")
