"""Communication-model planner (paper §5 as a tool): given an architecture
and a chip count, rank 4D decompositions by modeled per-chip volume.

  PYTHONPATH=src python examples/comm_planner.py --arch jamba-v0.1-52b \
      --chips 256 --batch 256 --seq 4096
"""
import argparse

from repro.configs import get_config
from repro.core import comm_model as CM

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gpt-paper-20b")
ap.add_argument("--chips", type=int, default=256)
ap.add_argument("--batch", type=int, default=256)
ap.add_argument("--seq", type=int, default=4096)
ap.add_argument("--top", type=int, default=10)
args = ap.parse_args()

cfg = get_config(args.arch)
cons = cfg.tp_constraints(args.batch)
tokens = args.batch * args.seq
ranked = CM.optimize_decomposition(list(cfg.comm_layers()), tokens,
                                   args.chips, cons, top_k=args.top)
print(f"{args.arch} on {args.chips} chips, {tokens/1e6:.1f}M tokens/step")
print(f"{'rank':>4} {'g_data':>6} {'g_x':>4} {'g_y':>4} {'g_z':>4} "
      f"{'GB/chip':>9} {'vs megatron@same_gt':>19}")
for i, (d, v) in enumerate(ranked):
    gb = v * 2 / (1 << 30)
    mega = CM.megatron_decomposition(args.chips, max(d.g_tensor, 1))
    v_mega = CM.model_volume(list(cfg.comm_layers()), tokens, mega)
    print(f"{i:>4} {d.g_data:>6} {d.g_x:>4} {d.g_y:>4} {d.g_z:>4} "
          f"{gb:>9.1f} {100 * (1 - v / v_mega):>18.0f}%")
