"""End-to-end training driver example (deliverable b): trains a ~100M-class
member of any assigned architecture family for a few hundred steps.

On real hardware:
  python examples/train_end_to_end.py --arch qwen3-1.7b --preset 100m \
      --steps 300 --batch 16 --seq 256

This CPU container defaults to a few-million-param variant so a few hundred
steps finish in minutes (the driver code path is identical — only the
config preset differs).
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "qwen3-1.7b", "--preset", "smoke",
                     "--steps", "200", "--batch", "8", "--seq", "128",
                     "--log-every", "25",
                     "--log-file", "runs/examples/train_qwen3.json",
                     "--ckpt", "runs/examples/qwen3_smoke.npz"]
    main()
