"""Batched serving example (prefill + decode with KV caches)."""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "deepseek-v2-lite-16b", "--batch", "4",
                     "--prompt-len", "16", "--gen", "8"]
    main()
