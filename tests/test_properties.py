"""Hypothesis property tests on system invariants.

The back half is the comm-model degeneracy suite: every extension of
the model (5th/6th mesh factor, α-β-γ time, bucketed/ZeRO gradient
sync, overlap claim order) must reduce EXACTLY to the model it grew out
of at its identity point — randomized over shapes and decompositions so
the guarantees in comm_model.py's docstring are properties, not three
hand-picked examples.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (not in image)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import comm_model as CM
from repro.core.gradsync import GradSyncConfig
from repro.core.overlap import OverlapConfig
from repro.data.synthetic import DataConfig, SyntheticText

SETTINGS = dict(max_examples=25, deadline=None)

FACTOR = st.sampled_from([1, 2, 4])
LAYER_KN = st.sampled_from([16, 64, 256])


def _factor_triples(g):
    out = []
    for a in range(1, g + 1):
        if g % a:
            continue
        for b in range(1, g // a + 1):
            if (g // a) % b:
                continue
            out.append((a, b, g // (a * b)))
    return out


@given(st.sampled_from([16, 32, 64, 128, 256]),
       st.integers(6, 12), st.integers(8, 14))
@settings(**SETTINGS)
def test_comm_volume_nonnegative_and_bounded(g, logh, logtok):
    """V >= the AM-GM lower bound of Eq. 5 for every decomposition."""
    H, tokens = 1 << logh, 1 << logtok
    layers = CM.transformer_layers(H)
    for gx, gy, rest in _factor_triples(g)[:12]:
        if rest < 1:
            continue
        d = CM.Decomposition(rest, gx, gy, 1)
        v = CM.model_volume(layers, tokens, d,
                            include_data_parallel=False)
        assert v >= -1e-6
        # per-layer Eq. 5 bound (n=3H,k=H layer):
        lb = 2 * tokens / g * (2 * math.sqrt(3 * H * H * gx * gy)
                               - 4 * H)
        assert v >= lb - 1e-6


@given(st.integers(4, 10), st.integers(4, 10), st.integers(1, 4),
       st.integers(1, 4), st.integers(1, 4))
@settings(**SETTINGS)
def test_transpose_swap_symmetry(logk, logn, gx, gy, gz):
    """A transposed (k,n) layer has the volume of a normal layer with
    x/y swapped (paper §4.1 Table 1 rule)."""
    k, n = 1 << logk, 1 << logn
    tokens = 4096
    d = CM.Decomposition(2, gx, gy, gz)
    d_sw = CM.Decomposition(2, gy, gx, gz)
    a = CM.layer_volume(CM.LayerShape(k, n, transposed=True), tokens, d)
    b = CM.layer_volume(CM.LayerShape(k, n, transposed=False), tokens, d_sw)
    # weight z-terms depend only on gx*gy; activation terms swap
    assert abs(a - b) / max(a, 1e-9) < 1e-9


@given(st.integers(0, 10000))
@settings(max_examples=10, deadline=None)
def test_data_pipeline_deterministic(step):
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=3)
    a = SyntheticText(cfg).batch(step)
    b = SyntheticText(cfg).batch(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    assert a["tokens"].max() < 1000 and a["tokens"].min() >= 0
    # labels are next-token shifted
    full_a = SyntheticText(cfg).batch(step)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


@given(st.integers(1, 3), st.integers(1, 3), st.integers(2, 6),
       st.booleans(), st.sampled_from([0, 24]))
@settings(max_examples=15, deadline=None)
def test_chunked_attention_matches_dense(nkv, group, logt, causal, window):
    """Online-softmax chunked attention == dense attention (any shape)."""
    from repro.layers.attention import attn_core, attn_core_chunked
    T = 1 << logt
    hq = nkv * group
    q = jax.random.normal(jax.random.PRNGKey(0), (1, T, hq, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, T, nkv, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, T, nkv, 16))
    a = attn_core(q, k, v, causal=causal, window=window,
                  chunked_threshold=1 << 20)
    b = attn_core_chunked(q, k, v, causal=causal, window=window,
                          bq=16, bk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=1e-5)


@given(st.integers(2, 5), st.integers(3, 6))
@settings(max_examples=10, deadline=None)
def test_rope_is_rotation(logt, logd):
    """RoPE preserves norms and relative-position inner products."""
    from repro.layers.rotary import apply_rope
    T, d = 1 << logt, 1 << logd
    x = jax.random.normal(jax.random.PRNGKey(0), (1, T, 2, d))
    pos = jnp.broadcast_to(jnp.arange(T), (1, T))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4, atol=1e-5)
    # shift both q and k by the same offset -> same scores
    y2 = apply_rope(x, pos + 7, 10000.0)
    s1 = np.einsum("btHd,bsHd->bHts", np.asarray(y), np.asarray(y))
    s2 = np.einsum("btHd,bsHd->bHts", np.asarray(y2), np.asarray(y2))
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-4)


@given(st.integers(1, 64))
@settings(max_examples=10, deadline=None)
def test_lr_schedule_bounds(step):
    from repro.optim.adamw import AdamWConfig, lr_at
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=64)
    lr = float(lr_at(cfg, jnp.int32(step)))
    assert 0.0 <= lr <= cfg.lr * (1 + 1e-5)


def test_decomposition_enumeration_is_complete():
    cons = CM.Constraints()
    ds = list(CM.enumerate_decompositions(16, cons))
    # number of ordered factorizations of 16 into 4 factors
    assert len(ds) == len({(d.g_data, d.g_x, d.g_y, d.g_z) for d in ds})
    assert all(d.g == 16 for d in ds)
    assert len(ds) == 35  # C(4+4-1, 3) compositions of 2^4 exponents


# ---------------------------------------------------------------------- #
# comm-model degeneracy suite: each model extension at its identity
# point reproduces the model it grew from, for random shapes/decomps
# ---------------------------------------------------------------------- #

def _marked_layers(k, n, kvw, a2aw):
    """A transformer-ish block with seq AND expert markers set."""
    return [
        CM.LayerShape(k, 3 * n, kv_ring_width=float(kvw)),
        CM.LayerShape(n, k, transposed=True),
        CM.LayerShape(k, 2 * n, expert=True, a2a_width=float(a2aw)),
        CM.LayerShape(2 * n, k, transposed=True, expert=True),
    ]


def _strip(layers, *, seq=False, expert=False):
    out = []
    for ls in layers:
        if seq:
            ls = dataclasses.replace(ls, kv_ring_width=0.0)
        if expert:
            ls = dataclasses.replace(ls, expert=False, a2a_width=0.0)
        out.append(ls)
    return out


@given(LAYER_KN, LAYER_KN, st.sampled_from([8, 32]), FACTOR, FACTOR,
       FACTOR, FACTOR)
@settings(**SETTINGS)
def test_seq_identity_degenerates_to_4tuple(k, n, kvw, gd, gx, gy, gz):
    """g_seq = 1: the KV-ring markers and the seq factor are inert —
    the 5-tuple model IS the 4-tuple model, bitwise."""
    layers = _marked_layers(k, n, kvw, 0)
    stripped = _strip(layers, seq=True)
    d = CM.Decomposition(gd, gx, gy, gz)            # g_seq defaults to 1
    assert (CM.model_volume(layers, 4096, d)
            == CM.model_volume(stripped, 4096, d))
    for ov in (None, OverlapConfig(ring_attention=True)):
        assert (CM.predict_step_time(layers, 4096, d, overlap=ov)
                == CM.predict_step_time(stripped, 4096, d, overlap=ov))


@given(LAYER_KN, LAYER_KN, st.sampled_from([8, 32]), FACTOR, FACTOR,
       FACTOR, st.sampled_from([1, 2]))
@settings(**SETTINGS)
def test_expert_identity_degenerates_to_5tuple(k, n, a2aw, gd, gx, gy,
                                               gseq):
    """g_expert = 1: the expert-bank/a2a markers and the expert factor
    are inert — the 6-tuple model IS the 5-tuple model, bitwise."""
    layers = _marked_layers(k, n, 16, a2aw)
    stripped = _strip(layers, expert=True)
    d = CM.Decomposition(gd, gx, gy, 1, gseq)       # g_expert defaults to 1
    assert (CM.model_volume(layers, 4096, d)
            == CM.model_volume(stripped, 4096, d))
    for ov in (None, OverlapConfig(expert_a2a=True)):
        assert (CM.predict_step_time(layers, 4096, d, overlap=ov)
                == CM.predict_step_time(stripped, 4096, d, overlap=ov))


@given(LAYER_KN, LAYER_KN, FACTOR, FACTOR, FACTOR, FACTOR,
       st.sampled_from([1, 2]), st.sampled_from([1, 2]))
@settings(**SETTINGS)
def test_alpha_gamma_free_time_degenerates_to_volume(k, n, gd, gx, gy,
                                                     gz, gseq, gex):
    """α = γ = 0 with no overlap: the exposed-communication term of the
    time model equals the volume model exactly, for EVERY factor mix —
    including the seq-ring and expert-a2a classes."""
    layers = _marked_layers(k, n, 16, 8)
    d = CM.Decomposition(gd, gx, gy, gz, gseq, gex)
    hw = CM.HardwareParams(alpha=0.0, gamma=0.0)
    t = CM.predict_step_time(layers, 4096, d, hw)
    expect = (CM.model_volume(layers, 4096, d)
              * hw.bytes_per_elem / hw.link_bw)
    assert t.hidden_comm == 0.0
    assert abs(t.exposed_comm - expect) <= 1e-9 * max(expect, 1e-30)


@given(st.sampled_from([2, 4, 8]),
       st.sampled_from([1024.0, 65536.0, 1.5e6]))
@settings(**SETTINGS)
def test_zero3_one_microbatch_floor_is_allreduce(p, buf):
    """The sharded sync schedules bottom out at the blocking volume:
    one microbatch of ZeRO-3-with-prefetch (AG + RS) — and of
    bucketed/ZeRO-1 (RS + AG) — moves exactly the all-reduce bytes."""
    ar = CM.allreduce_volume(p, buf)
    z3 = GradSyncConfig(zero3=True, prefetch=True)
    assert CM.dp_sync_volume(p, buf, z3, 1) == ar
    z1 = GradSyncConfig(bucketed=True)
    assert CM.dp_sync_volume(p, buf, z1, 1) == ar
    # and the floor is a floor: more microbatches never move less
    assert CM.dp_sync_volume(p, buf, z3, 3) >= ar
    assert CM.dp_sync_volume(p, buf, GradSyncConfig(zero3=True), 1) >= ar


@given(FACTOR, FACTOR, FACTOR, st.sampled_from([1, 2]),
       st.sampled_from([1, 2]), st.booleans())
@settings(**SETTINGS)
def test_overlap_claim_order_conserves_comm_time(gx, gy, gz, gseq, gex,
                                                 zfirst):
    """The overlap window only MOVES time from exposed to hidden: under
    any claim order (z_claims_first both ways) and any ring-knob combo,
    exposed + hidden is the blocking exposed time, and compute is
    untouched. (cache_weight_gather is excluded — it really drops an
    AG_z and is modeled as a volume change.)"""
    layers = _marked_layers(64, 256, 16, 8)
    d = CM.Decomposition(2, gx, gy, gz, gseq, gex)
    hw = dataclasses.replace(CM.TPU_V5E, z_claims_first=zfirst)
    base = CM.predict_step_time(layers, 4096, d, hw)
    assert base.hidden_comm == 0.0
    combos = [OverlapConfig(matmul=True),
              OverlapConfig(all_reduce=True),
              OverlapConfig(ring_attention=True, expert_a2a=True),
              OverlapConfig(matmul=True, all_reduce=True,
                            ring_attention=True, expert_a2a=True)]
    for ov in combos:
        t = CM.predict_step_time(layers, 4096, d, hw, overlap=ov)
        assert t.compute == base.compute
        total = t.exposed_comm + t.hidden_comm
        assert abs(total - base.exposed_comm) \
            <= 1e-9 * max(base.exposed_comm, 1e-30), (ov, d)
