"""Test fixtures: a small host-device mesh for sharding tests.

The CI matrix runs the suite at 4 AND 8 host devices (set via XLA_FLAGS;
8 is the default for local runs — the 512-device production mesh is only
ever created by launch/dryrun.py, never here). ``N_DEVICES`` below is the
single knob tests key off: the shared fixtures shrink their meshes to
fit, parametrized shape lists filter through ``fitting_shapes``, and
tests with a single hard-coded mesh branch on ``N_DEVICES`` inline.
"""
import math
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

N_DEVICES = jax.device_count()


def fitting_shapes(shapes):
    """Filter 4D mesh shapes to those the host's device count can hold."""
    return [s for s in shapes if math.prod(s) <= N_DEVICES]


@pytest.fixture(scope="session")
def mesh4():
    from repro.launch import mesh as LM
    return LM.make_smoke_mesh((2, 2, 2, 1) if N_DEVICES >= 8
                              else (1, 2, 2, 1))


@pytest.fixture(scope="session")
def axes4(mesh4):
    from repro.launch import mesh as LM
    return LM.bind_4d(mesh4)


@pytest.fixture(scope="session")
def meshz():
    from repro.launch import mesh as LM
    return LM.make_smoke_mesh((1, 2, 2, 2) if N_DEVICES >= 8
                              else (1, 1, 2, 2))


@pytest.fixture(scope="session")
def axesz(meshz):
    from repro.launch import mesh as LM
    return LM.bind_4d(meshz)


def train_smoke(arch: str, mesh, axes, *, steps=3, B=8, S=32,
                overdecompose=2, check_decreases=True):
    """Shared harness: a few real optimizer steps on the reduced config."""
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import steps as ST
    from repro.optim.adamw import AdamWConfig, init_state

    cfg = get_config(arch).reduced()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    state = init_state(params)
    step_fn, _, _ = ST.make_train_step(
        cfg, mesh, axes, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50),
        ST.TrainOptions(overdecompose=overdecompose, dtype=jnp.float32))
    rng = np.random.RandomState(0)
    batch = {"tokens": jax.numpy.asarray(
        rng.randint(0, cfg.vocab_size, (B, S)), jax.numpy.int32),
        "labels": jax.numpy.asarray(
        rng.randint(0, cfg.vocab_size, (B, S)), jax.numpy.int32)}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jax.numpy.asarray(
            rng.randn(B, cfg.encoder.n_ctx, cfg.encoder.input_dim),
            jax.numpy.float32)
    if cfg.arch_type == "audio":
        batch["frames"] = jax.numpy.asarray(
            rng.randn(B, cfg.encoder.n_ctx, cfg.d_model), jax.numpy.float32)
    losses = []
    for _ in range(steps):
        params, state, metrics = step_fn(params, state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1]), f"{arch}: non-finite loss"
    if check_decreases and steps >= 3:
        assert losses[-1] < losses[0], f"{arch}: loss did not decrease"
    return cfg, losses
