"""The paper's communication model (§5): closed forms, optimal
decompositions, and the Megatron/CAI-3D special cases."""
import math

import pytest

from repro.core import comm_model as CM


def test_allreduce_lower_bound():
    assert CM.allreduce_volume(1, 100) == 0
    assert CM.allreduce_volume(2, 100) == 100
    assert abs(CM.allreduce_volume(4, 100) - 150) < 1e-9


def test_transformer_volume_matches_eq6():
    """Summing the 4 per-layer volumes (Table 1) must equal Eq. 6."""
    H, tokens, g = 1024, 8192, 64
    layers = CM.transformer_layers(H)
    for gx, gy in [(1, 4), (2, 2), (4, 4), (8, 2)]:
        g_data = g // (gx * gy)
        d = CM.Decomposition(g_data, gx, gy, 1)
        v = CM.model_volume(layers, tokens, d, include_data_parallel=False)
        want = CM.paper_transformer_volume(tokens, H, g, gx, gy)
        assert abs(v - want) / max(want, 1) < 1e-9, (gx, gy, v, want)


def test_optimal_gc_near_sqrt3gt():
    """The optimizer's choice must track Eq. 7 (G_c = sqrt(3 G_tensor))
    for a pure transformer when g_data is fixed."""
    H, tokens = 4096, 1 << 20
    layers = CM.transformer_layers(H, n_layers=24)
    g, g_tensor = 256, 16
    # Eq. 7 is the 2D (G_z = 1) closed form, so pin z = 1 here. (With z
    # free the optimizer prefers depth-sharding — the 4D paper's point —
    # which test_4d_beats_1d_at_scale covers.)
    cons = CM.Constraints(min_tensor=g_tensor, z_divides=(1,))
    best = CM.optimize_decomposition(
        layers, tokens, g, cons, top_k=8, include_data_parallel=False)
    cands = [d for d, v in best if d.g_tensor == g_tensor]
    assert cands, best
    gy = cands[0].g_y
    assert gy in (4, 8), gy  # nearest powers of 2 around sqrt(3*16)=6.93


def test_gdata_monotonicity():
    """Eq. 5: larger G_data (smaller G_tensor) => less volume."""
    H, tokens, g = 2048, 1 << 18, 128
    layers = CM.transformer_layers(H)
    vols = []
    for g_data in (2, 4, 8, 16, 32):
        best = CM.optimize_decomposition(
            layers, tokens, g,
            CM.Constraints(min_tensor=g // g_data), top_k=1,
            include_data_parallel=False)
        vols.append(best[0][1])
    assert all(a >= b for a, b in zip(vols, vols[1:])), vols


def test_megatron_is_gc_equals_gtensor():
    d = CM.megatron_decomposition(256, 16)
    assert (d.g_data, d.g_x, d.g_y, d.g_z) == (16, 1, 16, 1)
    # the text: Megatron == our algorithm at the 1D degenerate point; its
    # modeled volume matches Eq. 13's shape: V ~ 8BH/G*(G_tensor-1)
    H, tokens = 1024, 8192
    layers = CM.transformer_layers(H)   # one transformer block
    v = CM.model_volume(layers, tokens, d, include_data_parallel=False)
    want = 8 * tokens * H / 256 * (16 - 1)
    assert abs(v - want) / want < 1e-9


def test_cai3d_requires_cube():
    assert CM.cai3d_decomposition(256, 16) is None
    d = CM.cai3d_decomposition(512, 64)
    assert d and (d.g_x, d.g_y, d.g_z) == (4, 4, 4)


def test_4d_beats_1d_at_scale():
    """The 4D optimum should strictly beat the Megatron point for a large
    transformer on 256 GPUs (the paper's headline claim)."""
    H, tokens = 8192, 1 << 21
    layers = CM.transformer_layers(H, n_layers=24)
    mega = CM.model_volume(layers, tokens,
                           CM.megatron_decomposition(256, 16))
    best = CM.optimize_decomposition(
        layers, tokens, 256, CM.Constraints(min_tensor=16), top_k=1)
    assert best[0][1] < mega * 0.8, (best[0], mega)


def test_arch_comm_layers_cover_all():
    from repro.configs import ASSIGNED, get_config
    for arch in ASSIGNED:
        cfg = get_config(arch)
        layers = cfg.comm_layers()
        assert layers, arch
        d = CM.Decomposition(4, 4, 4, 4)
        v = CM.model_volume(list(layers), 1 << 16, d)
        assert v > 0, arch
