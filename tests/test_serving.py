"""Serving stack: paged KV cache, continuous-batching scheduler, engine.

The load-bearing guarantees (docs/serving.md):

  * paged decode is TOKEN-identical to dense prefill+decode (greedy ids
    match; logits agree to fp tolerance — online softmax reassociates);
  * chunked prefill is BITWISE identical to one-shot prefill (the paged
    core reduces over the fixed gathered length in one fp32 softmax);
  * the page allocator holds conservation/no-alias invariants under
    admit/evict churn, and preemption-by-recompute never corrupts
    output tokens;
  * serving gates raise actionable errors (seq-parallel meshes,
    non-attention mixers) instead of silently wrong results.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import N_DEVICES

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

ARCH = "h2o-danube-3-4b"   # llama-style all-attention GQA decoder


# ---------------------------------------------------------------------- #
# kernel level: paged flash attention vs the jnp paged core
# ---------------------------------------------------------------------- #

def test_paged_kernel_matches_core():
    from repro.kernels import ops
    from repro.layers.attention import paged_attn_core

    rng = np.random.RandomState(0)
    R, T, Hq, Hkv, D = 3, 4, 4, 2, 8
    page, n_pages_tab, P = 4, 3, 16
    q = jnp.asarray(rng.randn(R, T, Hq, D), jnp.float32)
    kp = jnp.asarray(rng.randn(P, page, Hkv, D), jnp.float32)
    vp = jnp.asarray(rng.randn(P, page, Hkv, D), jnp.float32)
    table = jnp.asarray(rng.randint(1, P, (R, n_pages_tab)), jnp.int32)
    q_pos = jnp.asarray(rng.randint(0, page * n_pages_tab, (R, T)),
                        jnp.int32)
    q_len = jnp.asarray([T, 2, 0], jnp.int32)

    out_k = ops.flash_attention_paged(q, kp, vp, table, q_pos, q_len)
    # core consumes the gathered pages: (R, S, Hkv, D)
    kc = kp[table].reshape(R, -1, Hkv, D)
    vc = vp[table].reshape(R, -1, Hkv, D)
    out_c = paged_attn_core(q.transpose(0, 1, 2, 3), kc, vc,
                            q_pos=q_pos, q_len=q_len)
    rows = np.arange(T)[None, :] < np.asarray(q_len)[:, None]
    np.testing.assert_allclose(np.asarray(out_k)[rows],
                               np.asarray(out_c)[rows],
                               atol=1e-5, rtol=1e-5)


def test_paged_core_chunk_invariance_is_bitwise():
    """Any chunking of the query rows reduces the same fixed-length score
    vector per row -> identical fp ops -> bitwise-equal outputs."""
    from repro.layers.attention import paged_attn_core

    rng = np.random.RandomState(1)
    R, T, Hq, Hkv, D, S = 2, 8, 4, 2, 8, 16
    q = jnp.asarray(rng.randn(R, T, Hq, D), jnp.float32)
    k = jnp.asarray(rng.randn(R, S, Hkv, D), jnp.float32)
    v = jnp.asarray(rng.randn(R, S, Hkv, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (R, T))
    full = paged_attn_core(q, k, v, q_pos=pos,
                           q_len=jnp.full((R,), T, jnp.int32))
    for c0, c1 in ((0, 3), (3, T)):
        part = paged_attn_core(
            q[:, c0:c1], k, v, q_pos=pos[:, c0:c1],
            q_len=jnp.full((R,), c1 - c0, jnp.int32))
        assert np.array_equal(np.asarray(part),
                              np.asarray(full)[:, c0:c1])


# ---------------------------------------------------------------------- #
# allocator invariants
# ---------------------------------------------------------------------- #

def test_page_allocator_invariants_and_errors():
    from repro.launch.serving import PageAllocator

    with pytest.raises(ValueError):
        PageAllocator(1)            # no allocatable page beside the null

    a = PageAllocator(8)
    assert a.n_free == 7
    got = [a.alloc() for _ in range(7)]
    assert 0 not in got and sorted(got) == list(range(1, 8))
    assert a.alloc() is None        # exhausted -> None, never an exception
    a.check()
    a.free(got[:3])
    a.check()
    with pytest.raises(ValueError):
        a.free([got[0]])            # double free
    with pytest.raises(ValueError):
        a.free([0])                 # the null page is never allocated
    a.free(got[3:])
    a.check()
    assert a.n_used == 0 and a.n_free == 7


def test_page_allocator_churn():
    from repro.launch.serving import PageAllocator

    rng = np.random.RandomState(2)
    a = PageAllocator(32)
    held = []
    for _ in range(500):
        if held and rng.rand() < 0.45:
            k = rng.randint(1, len(held) + 1)
            batch = [held.pop() for _ in range(k)]
            a.free(batch)
        else:
            p = a.alloc()
            if p is not None:
                held.append(p)
        a.check()
        assert a.n_used == len(held)
    a.free(held)
    a.check()
    assert a.n_used == 0


# ---------------------------------------------------------------------- #
# full-stack parity: paged vs dense, chunked vs one-shot
# ---------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def served_model(mesh4, axes4):
    from repro.configs import get_config
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import steps as ST

    cfg = get_config(ARCH).reduced()
    params, specs = ST.init_model(cfg, axes4, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh4, params,
                                spec_tree_to_pspecs(specs))
    return cfg, params


def _dense_greedy(cfg, mesh, axes, params, prompts, gen):
    """Reference ids: rectangular prefill + lockstep dense decode."""
    from repro.launch import steps as ST
    B, L = prompts.shape
    S_max = L + gen
    pre_build, _ = ST.make_prefill_step(cfg, mesh, axes,
                                        dtype=jnp.float32)
    pre_fn, _, ct = pre_build(B, L, S_max)
    dec_build, _ = ST.make_decode_step(cfg, mesh, axes,
                                       dtype=jnp.float32)
    dec_fn, _ = dec_build(B, S_max)
    caches = ST.zeros_caches(mesh, ct)
    logits, caches = pre_fn(params, caches, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    ids = [np.asarray(tok)]
    for i in range(gen - 1):
        logits, caches = dec_fn(params, caches, tok[:, None],
                                jnp.int32(L + i))
        tok = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        ids.append(np.asarray(tok))
    return np.stack(ids, axis=1)


def _paged_setup(cfg, mesh, axes, *, slots, page_size, max_pages):
    from repro.launch import steps as ST
    shards = axes.batch_shards
    pages_per_shard = 1 + (slots // shards) * max_pages
    build, _ = ST.make_paged_step(cfg, mesh, axes, dtype=jnp.float32)
    step_fn, ct = build(shards * pages_per_shard, page_size)
    pools = ST.zeros_caches(mesh, ct)
    # deterministic striped tables: slot r owns max_pages consecutive
    # shard-local pages starting after the null page
    slots_per_shard = slots // shards
    table = np.zeros((slots, max_pages), np.int32)
    for r in range(slots):
        for p in range(max_pages):
            table[r, p] = 1 + (r % slots_per_shard) * max_pages + p
    return step_fn, pools, jnp.asarray(table)


def _paged_greedy(cfg, mesh, axes, params, prompts, gen, *,
                  chunk, page_size):
    slots, L = prompts.shape
    max_pages = -(-(L + gen) // page_size)
    step_fn, pools, table = _paged_setup(
        cfg, mesh, axes, slots=slots, page_size=page_size,
        max_pages=max_pages)
    ids = []
    # chunked prefill
    pos = 0
    while pos < L:
        cl = min(chunk, L - pos)
        tokens = np.zeros((slots, chunk), np.int32)
        tokens[:, :cl] = prompts[:, pos:pos + cl]
        positions = pos + np.arange(chunk, dtype=np.int32)[None, :]
        q_len = np.full((slots,), cl, np.int32)
        logits, pools = step_fn(params, pools, jnp.asarray(tokens),
                                jnp.asarray(np.broadcast_to(
                                    positions, (slots, chunk))),
                                jnp.asarray(q_len), table)
        pos += cl
    tok = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
    ids.append(tok)
    # decode
    for i in range(gen - 1):
        positions = np.full((slots, 1), L + i, np.int32)
        logits, pools = step_fn(params, pools,
                                jnp.asarray(tok[:, None]),
                                jnp.asarray(positions),
                                jnp.asarray(np.ones((slots,), np.int32)),
                                table)
        tok = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        ids.append(tok)
    return np.stack(ids, axis=1), logits


def test_paged_decode_token_parity_with_dense(mesh4, axes4, served_model):
    cfg, params = served_model
    B, L, GEN = 4, 8, 6
    rng = np.random.RandomState(3)
    prompts = jnp.asarray(rng.randint(1, cfg.vocab_size, (B, L)),
                          jnp.int32)
    dense = _dense_greedy(cfg, mesh4, axes4, params, prompts, GEN)
    paged, _ = _paged_greedy(cfg, mesh4, axes4, params,
                             np.asarray(prompts), GEN,
                             chunk=4, page_size=4)
    assert np.array_equal(dense, paged), (dense, paged)


def test_chunked_prefill_bitwise_equals_oneshot(mesh4, axes4,
                                                served_model):
    cfg, params = served_model
    B, L, GEN = 4, 8, 2
    rng = np.random.RandomState(4)
    prompts = rng.randint(1, cfg.vocab_size, (B, L)).astype(np.int32)
    _, logits_chunked = _paged_greedy(cfg, mesh4, axes4, params, prompts,
                                      GEN, chunk=4, page_size=4)
    _, logits_oneshot = _paged_greedy(cfg, mesh4, axes4, params, prompts,
                                      GEN, chunk=L, page_size=4)
    assert np.array_equal(np.asarray(logits_chunked),
                          np.asarray(logits_oneshot))


# ---------------------------------------------------------------------- #
# scheduler + engine
# ---------------------------------------------------------------------- #

def _virtual_clock():
    """Deterministic time source: each call advances 1 ms."""
    state = {"t": 0.0}

    def tick():
        state["t"] += 1e-3
        return state["t"]
    return tick


@pytest.fixture(scope="module")
def engine_factory(mesh4, axes4, served_model):
    from repro.launch.serving import PagedEngine, ServeConfig
    cfg, params = served_model

    def make(**kw):
        scfg = ServeConfig(**kw)
        return PagedEngine(cfg, mesh4, axes4, params, scfg,
                           dtype=jnp.float32), cfg
    return make


def test_engine_closed_loop_completion(engine_factory):
    from repro.launch.serving import Request
    engine, cfg = engine_factory(slots=8, page_size=4,
                                 pages_per_shard=24, chunk=8)
    engine.warmup()
    rng = np.random.RandomState(5)
    reqs = [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       size=(6,)).astype(np.int32),
                    max_new=int(rng.randint(2, 7)),
                    arrival=0.002 * i)
            for i in range(12)]
    stats = engine.run(reqs, time_fn=_virtual_clock())
    assert stats.n_requests == 12
    for r in reqs:
        assert r.state == "done"
        assert len(r.generated) == r.max_new
        assert r.t_done >= r.t_first >= 0
    assert stats.total_new_tokens == sum(r.max_new for r in reqs)
    assert np.isfinite([stats.latency_p50_ms, stats.latency_p99_ms,
                        stats.ttft_p50_ms, stats.ttft_p99_ms]).all()
    for a in engine.sched.allocators:
        a.check()
        assert a.n_used == 0, "pages leaked after drain"


def test_engine_preemption_churn_keeps_tokens_correct(engine_factory,
                                                      mesh4, axes4,
                                                      served_model):
    """A page pool too small for the offered load forces recompute
    preemptions; generated ids must still match the dense reference."""
    from repro.launch.serving import Request
    cfg, params = served_model
    # 7 allocatable pages/shard, page 4 -> at most ~2 requests resident
    engine, _ = engine_factory(slots=8, page_size=4,
                               pages_per_shard=8, chunk=8)
    engine.warmup()
    rng = np.random.RandomState(6)
    L, GEN = 6, 4
    prompts = rng.randint(1, cfg.vocab_size, (8, L)).astype(np.int32)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=GEN)
            for i in range(8)]
    stats = engine.run(reqs, time_fn=_virtual_clock())
    assert stats.n_preemptions > 0, "pool was sized to force preemption"
    dense = _dense_greedy(cfg, mesh4, axes4, params,
                          jnp.asarray(prompts), GEN)
    for i, r in enumerate(reqs):
        assert r.generated == list(dense[i]), (
            f"rid={i} preemptions={r.preemptions}")
    for a in engine.sched.allocators:
        a.check()
        assert a.n_used == 0


def test_scheduler_rejects_oversized_request():
    from repro.launch.serving import PageAllocator, Request, Scheduler
    s = Scheduler(n_slots=2, page_size=4, max_pages=3,
                  allocators=[PageAllocator(4)])
    with pytest.raises(ValueError):
        s.submit(Request(rid=0, prompt=np.zeros((10,), np.int32),
                         max_new=8))     # 18 > 3*4


# ---------------------------------------------------------------------- #
# capacity model + gates
# ---------------------------------------------------------------------- #

def test_serve_capacity_sanity():
    from repro.configs import get_config
    from repro.core import comm_model as CM

    layers = list(get_config(ARCH).reduced().comm_layers())
    d = CM.Decomposition(2, 2, 2, 1)
    cap = CM.serve_capacity(layers, 8, d, context=128)
    assert cap.tokens_per_s > 0 and cap.step_latency_ms > 0
    # more resident context -> more KV bytes to stream -> slower step
    cap_long = CM.serve_capacity(layers, 8, d, context=4096)
    assert cap_long.step.total > cap.step.total
    # degeneracy: the serving-only mem_bw field must not perturb the
    # training-path hardware defaults
    assert CM.HardwareParams().mem_bw == CM.TPU_V5E.mem_bw


def test_paged_cache_specs_gate_non_attention():
    from repro.configs import get_config
    from repro.launch import mesh as LM
    from repro.models import decoder as D

    mesh = LM.make_smoke_mesh((1, 2, 2, 1) if N_DEVICES < 8
                              else (2, 2, 2, 1))
    axes = LM.bind_4d(mesh)
    cfg = get_config("jamba-v0.1-52b").reduced()   # mamba mixers
    with pytest.raises(NotImplementedError, match="--mode fixed"):
        D.decoder_paged_cache_specs(cfg, axes, 16, 4)


@pytest.mark.skipif(N_DEVICES < 8, reason="needs a g_seq > 1 mesh")
def test_serving_gseq_gate_is_actionable():
    from repro.configs import get_config
    from repro.launch import mesh as LM
    from repro.models import decoder as D

    mesh = LM.make_smoke_mesh((1, 2, 2, 1, 2),
                              ("data", "x", "y", "z", "seq"))
    axes = LM.bind_4d(mesh)
    cfg = get_config(ARCH).reduced()
    with pytest.raises(NotImplementedError, match="g_seq == 1"):
        D.decoder_hidden({}, cfg, axes,
                         np.zeros((1, 1), np.int32), mode="paged")


def test_engine_rejects_unshardable_slots(mesh4, axes4, served_model):
    from repro.launch.serving import PagedEngine, ServeConfig
    cfg, params = served_model
    if axes4.batch_shards == 1:
        pytest.skip("needs > 1 batch shard to misalign slots")
    with pytest.raises(ValueError, match="multiple of the batch"):
        PagedEngine(cfg, mesh4, axes4, params,
                    ServeConfig(slots=axes4.batch_shards + 1))
