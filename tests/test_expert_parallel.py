"""Expert parallelism: the 6th mesh axis (g_expert) end to end.

Covers the degeneracy discipline (g_expert = 1 reduces the 6-tuple
comm model AND the layer path bitwise to the 5-axis code), the
all_to_all collective class geometry, the six-way decomposition search,
the mesh/lifecycle plumbing, the capacity-based MoE dispatch across the
expert axis (blocking ``lax.all_to_all`` and the ring-decomposed
``collective_matmul.ring_a2a_expert``), routing parity across
decompositions, and the spec-aware expert-axis gradient sync.

Runs at 4 AND 8 host devices (the CI matrix); device-hungry cases
branch on ``N_DEVICES``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES
from repro.configs import get_config
from repro.core import collective_matmul as CMM
from repro.core import comm_model as CM
from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.compat import shard_map
from repro.core.gradsync import GradSyncConfig
from repro.core.overlap import OverlapConfig
from repro.core.partition import ParamSpec, expert_reduce_grads, spec_names
from repro.launch import mesh as LM

EXPERT_NAMES = ("data", "x", "y", "z", "expert")


# ---------------------------------------------------------------------- #
# comm model: the all_to_all class and the g_expert = 1 degeneracy
# ---------------------------------------------------------------------- #

def test_decomposition_six_tuple_defaults():
    d = CM.Decomposition(2, 2, 2, 1)
    assert d.g_expert == 1 and d.g_seq == 1
    assert d.g == 8 and d.g_tensor == 4
    d6 = CM.Decomposition(2, 2, 2, 1, 1, 2)
    assert d6.g == 16            # expert joins the device budget...
    assert d6.g_tensor == 4      # ...but not the tensor (memory) floor


def test_all_to_all_volume_and_time_geometry():
    assert CM.all_to_all_volume(1, 4096.0) == 0.0
    assert CM.all_to_all_volume(4, 4096.0) == 3.0 / 4.0 * 4096.0
    hw = CM.HardwareParams(alpha=1e-6, gamma=2e-6, link_bw=1e9,
                           bytes_per_elem=4.0)
    p, buf = 4, 4096.0
    t = CM.collective_time("all_to_all", p, buf, hw)
    expect = (hw.gamma + hw.alpha * (p - 1)
              + CM.all_to_all_volume(p, buf) * hw.bytes_per_elem
              / hw.link_bw)
    assert t == expect
    assert CM.collective_time("all_to_all", 1, buf, hw) == 0.0
    with pytest.raises(ValueError):
        CM.collective_time("gossip", 4, buf, hw)


def test_expert_identity_markers_are_inert():
    """At g_expert = 1 the MoE markers (expert=True, a2a_width) change
    NOTHING — the 6-tuple model is the 5-tuple model bitwise."""
    marked = [CM.LayerShape(64, 256, expert=True, a2a_width=16.0),
              CM.LayerShape(256, 64, transposed=True, expert=True),
              CM.LayerShape(64, 192, kv_ring_width=32.0)]
    plain = [dataclasses.replace(ls, expert=False, a2a_width=0.0)
             for ls in marked]
    for d in (CM.Decomposition(2, 2, 2, 1),
              CM.Decomposition(1, 2, 2, 2, 2),
              CM.Decomposition(4, 1, 2, 1, 1, 1)):
        assert (CM.model_volume(marked, 4096, d)
                == CM.model_volume(plain, 4096, d))
        for ov in (None, OverlapConfig(expert_a2a=True),
                   OverlapConfig.all_on()):
            tm = CM.predict_step_time(marked, 4096, d, overlap=ov)
            tp_ = CM.predict_step_time(plain, 4096, d, overlap=ov)
            assert tm == tp_


def test_layer_volume_expert_a2a_term():
    """Hand-check: an isolated expert axis pays exactly 4 all_to_all
    passes of the dispatch buffer and nothing else."""
    ls = CM.LayerShape(8, 8, expert=True, a2a_width=16.0)
    d = CM.Decomposition(1, 1, 1, 1, 1, 4)
    v = CM.layer_volume(ls, 64, d, include_data_parallel=False)
    m_local = 64 / 4                       # tokens / g_expert
    assert v == 4.0 * CM.all_to_all_volume(4, m_local * 16.0)


def test_expert_bank_weight_sharding_and_grad_sync():
    """The expert bank co-shards over g_expert (weight buffers shrink);
    dense params replicate and pay an expert-axis grad all-reduce."""
    d = CM.Decomposition(1, 1, 1, 1, 1, 4)
    dense = CM.LayerShape(64, 128)
    bank = CM.LayerShape(64, 128, expert=True)
    g_dense = CM.layer_geometry(dense, 64, d)
    g_bank = CM.layer_geometry(bank, 64, d)
    assert g_bank.w_full_per_xy == g_dense.w_full_per_xy / 4
    assert g_bank.dp_buf == g_dense.dp_buf / 4
    # dense: the only nonzero term is the expert-axis grad all-reduce
    assert (CM.layer_volume(dense, 64, d)
            == CM.allreduce_volume(4, 64 * 128))
    # bank: grads already live on their own expert shard — no sync at all
    assert CM.layer_volume(bank, 64, d) == 0.0


def test_enumeration_expert_gated_and_divisibility():
    default = list(CM.enumerate_decompositions(16))
    assert len(default) == 35                     # the 5-tuple pin holds
    assert all(d.g_expert == 1 for d in default)
    c = CM.Constraints(max_expert=4, expert_divides=(8,), global_batch=8)
    opened = list(CM.enumerate_decompositions(16, c))
    assert {d.g_expert for d in opened} >= {1, 2, 4}
    for d in opened:
        assert d.g == 16
        assert d.g_expert <= 4 and 8 % d.g_expert == 0
        assert 8 % (d.g_data * d.g_z * d.g_expert) == 0


@pytest.mark.parametrize("objective", ["volume", "time"])
def test_optimizer_picks_expert_on_moe_heavy_profile(objective):
    """A constructed profile where every classic axis is expensive (big
    expert-bank weights, few tokens) and the a2a is cheap: the six-way
    search must spend the whole budget on g_expert."""
    layers = [CM.LayerShape(1024, 8192, expert=True, a2a_width=8.0),
              CM.LayerShape(8192, 1024, transposed=True, expert=True)]
    c = CM.Constraints(max_expert=8, expert_divides=(8,))
    kw = dict(objective=objective)
    best, _ = CM.optimize_decomposition(layers, 256, 8, c, **kw)[0]
    if objective == "volume":
        assert best.g_expert == 8, best     # pure expert moves least data
    else:
        # the α term penalizes deep a2a rings, so time may split the
        # budget with y — but the search must still open the axis
        assert best.g_expert > 1, best
    # capping the axis falls back to a 5-tuple plan, no error
    best5, _ = CM.optimize_decomposition(
        layers, 256, 8, CM.Constraints(max_expert=1), **kw)[0]
    assert best5.g_expert == 1


def test_time_model_expert_overlap_conserves_volume():
    """OverlapConfig.expert_a2a moves a2a time from exposed to hidden;
    it never creates or destroys communication."""
    layers = [CM.LayerShape(512, 2048, expert=True, a2a_width=64.0)]
    d = CM.Decomposition(1, 1, 1, 1, 1, 4)
    t_no = CM.predict_step_time(layers, 4096, d,
                                include_data_parallel=False)
    t_ov = CM.predict_step_time(layers, 4096, d,
                                overlap=OverlapConfig(expert_a2a=True),
                                include_data_parallel=False)
    assert t_no.hidden_comm == 0.0
    assert t_ov.hidden_comm > 0.0
    assert np.isclose(t_ov.exposed_comm + t_ov.hidden_comm,
                      t_no.exposed_comm, rtol=0, atol=1e-18)
    assert t_ov.compute == t_no.compute


# ---------------------------------------------------------------------- #
# mesh + lifecycle plumbing
# ---------------------------------------------------------------------- #

def test_bind_expert_axis():
    mesh = LM.make_smoke_mesh((1, 2, 1, 1, 2), EXPERT_NAMES)
    axes = LM.bind_4d(mesh)
    assert axes.gexpert == 2 and axes.expert == "expert"
    assert axes.batch_shards == 2           # data(1) * z(1) * expert(2)
    assert "expert" in axes.batch_axes()
    assert "expert" in axes.all_names()
    assert axes.axis("expert") == "expert"
    # the 4-axis binding stays expert-free (size-1 ⇒ None)
    mesh4 = LM.make_smoke_mesh((1, 2, 1, 1))
    axes4 = LM.bind_4d(mesh4)
    assert axes4.expert is None and axes4.gexpert == 1


def test_lifecycle_six_factors_shrink_then_grow():
    life = LM.MeshLifecycle(2, 1, 1, 1, g_expert=2)
    assert life.factors == (2, 1, 1, 1, 1, 2)
    assert life.required == 4 and life.tensor == 2
    mesh, axes = life.build()
    assert "expert" in mesh.axis_names and axes.gexpert == 2
    life.mark_failed(2)
    plan = life.replan(global_batch=8)
    assert plan["g_expert"] == 2            # tensor factors never shrink

    def best_gd(surviving):
        # largest g_data fitting the pool AND the batch-divisibility
        # rule: global_batch % (g_data * g_z * g_expert * od) == 0
        return max(gd for gd in range(1, surviving // 2 + 1)
                   if 8 % (gd * 2) == 0)

    shrunk = best_gd(N_DEVICES - 2)
    assert plan["g_data"] == shrunk
    life.mark_recovered()                   # the elastic grow path
    plan = life.replan(global_batch=8)
    assert plan["g_data"] == N_DEVICES // 2 and plan["g_expert"] == 2
    assert plan["g_data"] > shrunk


def test_all_to_all_blocking_and_ring_agree():
    p = 4
    mesh = LM.make_smoke_mesh((p,), ("expert",))
    x = jnp.arange(p * p * 3, dtype=jnp.float32).reshape(p * p, 3)

    def body(v):
        return (M.all_to_all(v, "expert", dim=0),
                M.ring_all_to_all(v, "expert", dim=0))

    blk, ring = shard_map(body, mesh=mesh, in_specs=P("expert"),
                          out_specs=(P("expert"), P("expert")),
                          check_vma=False)(x)
    # reference: global row r*p+s of the output is input row s*p+r
    ref = np.asarray(x).reshape(p, p, 3).swapaxes(0, 1).reshape(p * p, 3)
    np.testing.assert_array_equal(np.asarray(blk), ref)
    np.testing.assert_array_equal(np.asarray(ring), ref)


def test_ring_a2a_expert_matches_blocking_roundtrip():
    """ring_a2a_expert == all_to_all -> per-block FFN -> all_to_all,
    bitwise, including a rank-dependent FFN (the expert weights)."""
    p = 4
    mesh = LM.make_smoke_mesh((p,), ("expert",))
    buf = jax.random.normal(jax.random.PRNGKey(0), (p * p, 3, 2))

    def body(b):                            # b: (p, C, d) per rank
        r = jax.lax.axis_index("expert").astype(jnp.float32)

        def ffn(block):                     # (C, d) -> (C, d)
            return block * (r + 1.0) + r

        ring = CMM.ring_a2a_expert(b, "expert", ffn)
        recv = M.all_to_all(b, "expert", dim=0)
        blk = M.all_to_all(jax.vmap(ffn)(recv), "expert", dim=0)
        return ring, blk

    ring, blk = shard_map(body, mesh=mesh, in_specs=P("expert"),
                          out_specs=(P("expert"), P("expert")),
                          check_vma=False)(buf)
    np.testing.assert_array_equal(np.asarray(ring), np.asarray(blk))
    assert not np.allclose(np.asarray(ring), np.asarray(buf))


def test_ring_a2a_expert_rejects_bad_leading_dim():
    p = 2
    mesh = LM.make_smoke_mesh((p,), ("expert",))
    buf = jnp.zeros((p * 3, 4, 2))          # dim 0 != p per rank

    def body(b):
        return CMM.ring_a2a_expert(b, "expert", lambda x: x)

    with pytest.raises(ValueError, match="expert-axis ring size"):
        shard_map(body, mesh=mesh, in_specs=P("expert"),
                  out_specs=P("expert"), check_vma=False)(buf)


# ---------------------------------------------------------------------- #
# MoE layer: dispatch bookkeeping, routing parity, end-to-end parity
# ---------------------------------------------------------------------- #

def _dispatch(idx, gates, e_block, capacity, n_tok, top_k):
    """The capacity bookkeeping of layers/moe.moe_apply, verbatim."""
    eflat = jnp.where((idx >= 0) & (idx < e_block), idx, e_block)
    onehot = jax.nn.one_hot(eflat.reshape(-1), e_block + 1,
                            dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos, eflat.reshape(-1, 1), axis=1)[:, 0]
    fits = (pos < capacity) & (eflat.reshape(-1) < e_block)
    slot = jnp.where(fits, eflat.reshape(-1) * capacity + pos,
                     e_block * capacity)
    tok_ids = jnp.tile(jnp.arange(n_tok)[:, None], (1, top_k)).reshape(-1)
    owner = jnp.zeros(e_block * capacity + 1, jnp.int32).at[slot].set(
        tok_ids, mode="drop")[:-1]
    filled = jnp.zeros(e_block * capacity + 1, jnp.bool_).at[slot].set(
        True, mode="drop")[:-1]
    gate_of = jnp.zeros(e_block * capacity + 1, jnp.float32).at[slot].set(
        gates.reshape(-1), mode="drop")[:-1]
    return owner, filled, gate_of, fits


def test_capacity_overflow_drop_determinism():
    """Overflowing an expert queue drops the HIGHEST flattened
    (token, slot) indices — deterministically, run to run."""
    n_tok, top_k, e_block, capacity = 8, 1, 2, 3
    idx = jnp.zeros((n_tok, top_k), jnp.int32)      # all -> expert 0
    gates = jnp.linspace(0.1, 0.8, n_tok).reshape(n_tok, top_k)
    a = _dispatch(idx, gates, e_block, capacity, n_tok, top_k)
    b = _dispatch(idx, gates, e_block, capacity, n_tok, top_k)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    owner, filled, gate_of, fits = a
    # first `capacity` tokens keep their slots, in order
    np.testing.assert_array_equal(np.asarray(owner[:capacity]),
                                  np.arange(capacity))
    assert bool(filled[:capacity].all())
    assert not bool(filled[capacity:].any())        # expert 1 untouched
    np.testing.assert_array_equal(
        np.asarray(fits), np.arange(n_tok) < capacity)
    np.testing.assert_array_equal(np.asarray(gate_of[:capacity]),
                                  np.asarray(gates[:capacity, 0]))


def _router_outputs(shape, names=("data", "x", "y", "z")):
    """Router gates/indices/aux on one mesh decomposition (the
    moe_apply front half, shard_map'ped)."""
    from repro.layers import moe as MOE

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    mc = cfg.moe
    mesh = LM.make_smoke_mesh(shape, names)
    axes = LM.bind_4d(mesh)
    w = PP.tp_linear_init(jax.random.PRNGKey(7), cfg.d_model,
                          mc.n_experts, axes, in_shard="x",
                          out_shard=None, dtype=jnp.float32)
    hf = jax.random.normal(jax.random.PRNGKey(8), (16, cfg.d_model))

    def body(h, wv):
        logits = PP.tp_matmul(h, wv, axes, "x", None).astype(jnp.float32)
        gates, idx = MOE._topk_gates(logits, mc)
        return gates, idx, MOE._aux_losses(logits, idx, mc)

    fn = shard_map(body, mesh=mesh, in_specs=(P(None, "x"), w.spec),
                   out_specs=(P(), P(), P()), check_vma=False)
    gates, idx, aux = fn(hf, w.value)
    return np.asarray(gates), np.asarray(idx), float(aux)


def test_routing_parity_across_decompositions():
    """Satellite: gates, top-k indices and aux losses are bitwise
    identical across (data, y, z) re-decompositions of the same device
    count — routing depends on the x contraction only."""
    variants = [(1, 2, 2, 1), (1, 2, 1, 2), (2, 2, 1, 1)]
    if N_DEVICES >= 8:
        variants.append((1, 2, 2, 2))
    ref = _router_outputs(variants[0])
    for shape in variants[1:]:
        gates, idx, aux = _router_outputs(shape)
        np.testing.assert_array_equal(gates, ref[0], err_msg=str(shape))
        np.testing.assert_array_equal(idx, ref[1], err_msg=str(shape))
        assert aux == ref[2], shape


def _train_losses(shape, names=None, overlap=None, steps=3, B=8, S=32):
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import steps as ST
    from repro.optim.adamw import AdamWConfig, init_state

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    mesh = LM.make_smoke_mesh(
        shape, names or ("data", "x", "y", "z")[:len(shape)])
    axes = LM.bind_4d(mesh)
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    state = init_state(params)
    step_fn, _, _ = ST.make_train_step(
        cfg, mesh, axes,
        AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50),
        ST.TrainOptions(overdecompose=1, dtype=jnp.float32,
                        overlap=overlap or OverlapConfig()))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)}
    losses = []
    for _ in range(steps):
        params, state, metrics = step_fn(params, state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    return losses


def _parity_shapes():
    """(baseline, expert) shapes holding the token shards fixed: the
    expert axis replaces one factor of g_data, so dense layers see the
    identical batch split and losses must match bitwise."""
    if N_DEVICES >= 8:
        return (2, 2, 2, 1), (1, 2, 2, 1, 2)
    return (2, 2, 1, 1), (1, 2, 1, 1, 2)


def test_expert_blocking_parity_with_data_axis():
    base, ex = _parity_shapes()
    l_base = _train_losses(base)
    l_blk = _train_losses(ex, EXPERT_NAMES)
    assert l_blk == l_base, (l_blk, l_base)
    assert l_base[-1] < l_base[0]           # it actually trains


def test_expert_ring_parity_with_blocking():
    _, ex = _parity_shapes()
    l_blk = _train_losses(ex, EXPERT_NAMES)
    l_ring = _train_losses(ex, EXPERT_NAMES,
                           overlap=OverlapConfig(expert_a2a=True))
    assert l_ring == l_blk, (l_ring, l_blk)


def test_moe_init_rejects_nondividing_expert_axis():
    from repro.layers import moe as MOE

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=6))
    axes = M.MeshAxes(y="y", expert="expert",
                      sizes=(("y", 2), ("expert", 2)))
    with pytest.raises(ValueError, match="not divisible"):
        MOE.moe_init(jax.random.PRNGKey(0), cfg, axes)


# ---------------------------------------------------------------------- #
# gradient sync, param layout, step-builder guard, calibration
# ---------------------------------------------------------------------- #

def test_expert_reduce_grads_is_spec_aware():
    axes = M.MeshAxes(expert="expert", sizes=(("expert", 2),))
    specs = {"dense": ParamSpec(P(None, "x"), z_reduced=True),
             "bank": ParamSpec(P(("y", "expert"), "x", None),
                               z_reduced=True)}
    grads = {"dense": jnp.ones(3), "bank": jnp.ones(3)}
    synced = []

    def psum_fn(g, ax):
        synced.append(ax)
        return g + 1.0

    out = expert_reduce_grads(grads, specs, axes, psum_fn)
    assert synced == ["expert"]             # dense only
    np.testing.assert_array_equal(np.asarray(out["dense"]),
                                  np.full(3, 2.0))
    np.testing.assert_array_equal(np.asarray(out["bank"]),
                                  np.ones(3))


def test_spec_names_flattens_tuples():
    assert spec_names(P(("y", "expert"), "x", None)) == ("y", "expert",
                                                        "x")
    assert spec_names(ParamSpec(P(None, "z"), z_reduced=True)) == ("z",)


def test_tp_expert_init_shards_bank_over_y_and_expert():
    mesh = LM.make_smoke_mesh((1, 1, 2, 1, 2), EXPERT_NAMES)
    axes = LM.bind_4d(mesh)
    b = PP.tp_expert_init(jax.random.PRNGKey(0), 4, 8, 8, axes,
                          abstract=True)
    assert set(spec_names(b.spec)) >= {"y", "expert"}
    # without the expert axis the layout is today's y-only placement
    mesh4 = LM.make_smoke_mesh((1, 1, 2, 1), ("data", "x", "y", "z"))
    b4 = PP.tp_expert_init(jax.random.PRNGKey(0), 4, 8, 8,
                           LM.bind_4d(mesh4), abstract=True)
    assert "expert" not in spec_names(b4.spec)
    assert "y" in spec_names(b4.spec)


def test_make_train_step_guards_expert_with_sharded_gradsync():
    from repro.launch import steps as ST
    from repro.optim.adamw import AdamWConfig

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    mesh = LM.make_smoke_mesh((1, 2, 1, 1, 2), EXPERT_NAMES)
    axes = LM.bind_4d(mesh)
    with pytest.raises(NotImplementedError, match="expert parallelism"):
        ST.make_train_step(
            cfg, mesh, axes, AdamWConfig(lr=1e-3, total_steps=10),
            ST.TrainOptions(overdecompose=1, dtype=jnp.float32,
                            gradsync=GradSyncConfig(zero=True)))


def test_calibrate_measures_all_to_all_class():
    from repro.core import calibrate as CA

    mesh = LM.make_smoke_mesh((2,), ("expert",))
    samples = CA.measure_axis(mesh, "expert", [512], reps=1)
    a2a = [s for s in samples if s.kind == "all_to_all"]
    assert len(a2a) == 1
    s = a2a[0]
    assert s.p == 2 and s.steps == 1
    assert s.wire_bytes == 0.5 * 512 * 4    # (p-1)/p * buf, fp32
    assert s.seconds >= 0.0
    CA.fit_constants(samples)               # the fitter accepts the class
