"""End-to-end behaviour tests for the whole system: real multi-step
training runs that must converge, checkpoint/restore continuity, and
decomposition-invariance of the training trajectory (paper Fig. 6)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import N_DEVICES
from repro.configs import get_config
from repro.core.partition import spec_tree_to_pspecs
from repro.data.synthetic import DataConfig, SyntheticText, make_batch
from repro.launch import mesh as LM
from repro.launch import steps as ST
from repro.optim.adamw import AdamWConfig, init_state

# the default (2,2,2,1) smoke mesh, shrunk to fit 4-device CI hosts
SHAPE0 = (2, 2, 2, 1) if N_DEVICES >= 8 else (1, 2, 2, 1)
# three decompositions of the same device count (trajectory invariance)
SHAPES_INV = ([(2, 2, 2, 1), (2, 1, 4, 1), (1, 2, 2, 2)]
              if N_DEVICES >= 8
              else [(1, 2, 2, 1), (2, 1, 2, 1), (1, 1, 2, 2)])


def _run(arch, mesh_shape, steps, *, seed=0, B=8, S=64, od=2):
    mesh = LM.make_smoke_mesh(mesh_shape)
    axes = LM.bind_4d(mesh)
    cfg = get_config(arch).reduced()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(seed),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    state = init_state(params)
    fn, _, _ = ST.make_train_step(
        cfg, mesh, axes, AdamWConfig(lr=1e-3, warmup_steps=2,
                                     total_steps=steps),
        ST.TrainOptions(overdecompose=od, dtype=jnp.float32))
    data = SyntheticText(DataConfig(cfg.vocab_size, S, B, seed=1))
    losses = []
    for step in range(steps):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, step, data).items()}
        params, state, m = fn(params, state, batch)
        losses.append(float(m["loss"]))
    return cfg, params, losses


def test_training_converges_markov():
    """The markov synthetic task is learnable: loss must drop well below
    the starting entropy within 25 steps."""
    _, _, losses = _run("stablelm-1.6b", SHAPE0, 25)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] * 0.75, losses[::6]


def test_trajectory_invariant_to_decomposition():
    """Paper Fig. 6: the training trajectory must not depend on the
    decomposition (same init, same data, different meshes)."""
    _, _, l1 = _run("qwen3-1.7b", SHAPES_INV[0], 4)
    _, _, l2 = _run("qwen3-1.7b", SHAPES_INV[1], 4)
    _, _, l3 = _run("qwen3-1.7b", SHAPES_INV[2], 4)
    np.testing.assert_allclose(l1, l2, rtol=2e-4)
    np.testing.assert_allclose(l1, l3, rtol=2e-4)


def test_checkpoint_resume_continues(tmp_path):
    from repro.checkpoint import restore, save
    cfg, params, losses = _run("stablelm-1.6b", SHAPE0, 3)
    host = jax.tree.map(np.asarray, params)
    path = os.path.join(tmp_path, "ck.npz")
    save(path, host, step=3)
    got, step = restore(path, host)
    assert step == 3
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(host)):
        np.testing.assert_array_equal(a, b)


def test_prefill_then_decode_consistent():
    """Prefill+decode must give the same next-token logits as running the
    full sequence through the train-mode forward."""
    from repro.models import decoder as D
    from repro.core.compat import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = LM.make_smoke_mesh(SHAPE0)
    axes = LM.bind_4d(mesh)
    cfg = get_config("qwen3-1.7b").reduced()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    pspecs = spec_tree_to_pspecs(specs)
    params = ST.device_put_tree(mesh, params, pspecs)
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 9)), jnp.int32)

    # full forward logits at position 8
    def full(params, toks):
        h, _, _ = D.decoder_hidden(params, cfg, axes, toks, mode="train",
                                   remat=False)
        return D.lm_logits(params, cfg, axes, h[:, -1:, :])

    f = shard_map(full, mesh=mesh,
                  in_specs=(pspecs, axes.pspec(axes.batch_axes(), None)),
                  out_specs=axes.pspec(axes.batch_axes(), None, axes.y),
                  check_vma=False)
    want = np.asarray(jax.jit(f)(params, toks))

    # prefill on the first 8 tokens, then decode token 8
    pre_build, _ = ST.make_prefill_step(cfg, mesh, axes, dtype=jnp.float32)
    pre_fn, bt, ct = pre_build(2, 8, 16)
    caches = ST.zeros_caches(mesh, ct)
    _, caches = pre_fn(params, caches, {"tokens": toks[:, :8]})
    dec_build, _ = ST.make_decode_step(cfg, mesh, axes, dtype=jnp.float32)
    dec_fn, _ = dec_build(2, 16)
    got, _ = dec_fn(params, caches, toks[:, 8:9], jnp.int32(8))
    np.testing.assert_allclose(np.asarray(got)[:, 0], want[:, 0],
                               rtol=2e-3, atol=2e-4)
