"""Ring-decomposed collective matmuls (core/collective_matmul.py) and the
α-β overlap-aware time model (core/comm_model.py).

The overlapped schedules must be pure *decompositions* of the blocking
ones: same forward outputs and same dX/dW gradients (within fp32-accum
reassociation) across (x, y, z) decompositions of the CPU smoke mesh,
with collective-permute chains in the HLO where the monolithic weight
all-gather / reduce-scatter — and, with ``all_reduce`` on, the x/y
activation all-reduces — used to be. Shapes scale down automatically on
4-device CI hosts (conftest.N_DEVICES).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES, fitting_shapes
from repro.core import collective_matmul as CMM
from repro.core import comm_model as CM
from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.compat import shard_map
from repro.core.overlap import OverlapConfig
from repro.launch import mesh as LM
from repro.launch import roofline as RL

K, N, B, S = 16, 24, 8, 8

SHAPES_4D = fitting_shapes([(1, 2, 2, 2), (2, 2, 1, 2), (2, 1, 2, 2),
                            (1, 1, 2, 4), (2, 2, 2, 1),
                            (1, 2, 2, 1), (1, 1, 2, 2)])
# the deepest-z shape the host holds (z rings of size > 2)
SHAPE_Z = (1, 2, 2, 2) if N_DEVICES >= 8 else (1, 1, 2, 2)
OVERLAPS = [OverlapConfig.all_on(),
            OverlapConfig.all_on(z_chunks=2),
            OverlapConfig.all_on(ar_chunks=2),
            OverlapConfig(all_reduce=True),
            OverlapConfig.all_on(cache_weight_gather=True)]


def _ids(v):
    if isinstance(v, OverlapConfig):
        tags = []
        if v.matmul:
            tags.append(f"z{v.z_chunks}")
        if v.all_reduce:
            tags.append(f"ar{v.ar_chunks}")
        if v.cache_weight_gather:
            tags.append("cache")
        return "_".join(tags)
    return str(v)


def _exact_random(key, shape):
    """Random fp32 values whose sums/products are exact (small ints), so
    every reduction order gives bitwise-identical results."""
    return jax.random.randint(key, shape, -4, 5).astype(jnp.float32)


# --------------------------------------------------------------------- #
# ring primitives == blocking collectives
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("shape", SHAPES_4D, ids=str)
def test_ring_primitives_match_blocking(shape):
    mesh = LM.make_smoke_mesh(shape)
    axes = LM.bind_4d(mesh)

    def body(v):
        ag = M.all_gather(v, axes.z, dim=1)
        rag = M.ring_all_gather(v, axes.z, dim=1)
        rs = M.psum_scatter(ag, axes.z, dim=1)
        rrs = M.ring_reduce_scatter(ag, axes.z, dim=1)
        d_ag = jnp.max(jnp.abs(ag - rag))
        d_rs = jnp.max(jnp.abs(rs - rrs))
        return M.pmax(M.pmax(jnp.stack([d_ag, d_rs]), axes.z), axes.data)

    f = shard_map(body, mesh=mesh,
                  in_specs=axes.pspec(axes.x, axes.y),
                  out_specs=P(), check_vma=False)
    v = jax.random.normal(jax.random.PRNGKey(0),
                          (8 * shape[1], 16 * shape[2]))
    d_ag, d_rs = np.asarray(jax.jit(f)(v))
    assert d_ag == 0.0, "ring_all_gather must be bitwise all_gather"
    assert d_rs < 1e-5, d_rs


def test_ring_identity_on_unmapped_axis():
    shape = (2, 2, 2, 1) if N_DEVICES >= 8 else (1, 2, 2, 1)
    mesh = LM.make_smoke_mesh(shape)
    axes = M.bind_axes(mesh, data=("data",), x="x", y="y")  # z unmapped

    def body(v):
        a = M.ring_all_gather(v, axes.z, dim=1)
        b = M.ring_reduce_scatter(v, axes.z, dim=1)
        c = M.ppermute_ring(v, axes.z)
        d = M.ring_all_reduce(v, axes.z)
        return jnp.max(jnp.abs(a - v) + jnp.abs(b - v) + jnp.abs(c - v)
                       + jnp.abs(d - v))

    f = shard_map(body, mesh=mesh, in_specs=P(None, None),
                  out_specs=P(), check_vma=False)
    assert float(jax.jit(f)(jnp.ones((4, 4)))) == 0.0


def test_ppermute_ring_shifts():
    shape = (1, 1, 2, 4) if N_DEVICES >= 8 else (1, 1, 1, 4)
    mesh = LM.make_smoke_mesh(shape)
    axes = LM.bind_4d(mesh)

    def body(v):
        # rank i receives rank i-1's value -> the global view rotates
        return M.ppermute_ring(v, axes.z)

    f = shard_map(body, mesh=mesh, in_specs=P("z"), out_specs=P("z"),
                  check_vma=False)
    out = np.asarray(jax.jit(f)(jnp.arange(4.0)))
    np.testing.assert_array_equal(out, np.asarray([3.0, 0.0, 1.0, 2.0]))


# --------------------------------------------------------------------- #
# ring_all_reduce == psum (satellite: identity / tuple axes / bitwise)
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("logical", ["x", "y", "z", "data"])
@pytest.mark.parametrize("shape", SHAPES_4D, ids=str)
def test_ring_all_reduce_matches_psum(shape, logical):
    """ring_all_reduce == psum over every mesh axis: bitwise on
    exactly-summable values (any ring size — the decomposition must move
    the right blocks to the right places), and within reassociation
    tolerance on generic floats."""
    mesh = LM.make_smoke_mesh(shape)
    axes = LM.bind_4d(mesh)
    ax = axes.axis(logical)

    def body(v):
        d = jnp.max(jnp.abs(M.ring_all_reduce(v, ax, dim=-1)
                            - M.psum(v, ax)))
        return M.pmax(M.pmax(M.pmax(M.pmax(
            d, axes.data), axes.x), axes.y), axes.z)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False))
    exact = _exact_random(jax.random.PRNGKey(0), (4, 8))
    assert float(f(exact)) == 0.0, "schedule must be bitwise on exact sums"
    fuzzy = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    assert float(f(fuzzy)) < 1e-5


def test_ring_all_reduce_tuple_axis():
    """A tuple (multi-name) ring axis must flatten into ONE ring, not
    fall back to blocking: correct sum AND no all-reduce in the HLO."""
    shape = (1, 2, 2, 2) if N_DEVICES >= 8 else (1, 2, 2, 1)
    mesh = LM.make_smoke_mesh(shape)
    names = ("x", "y", "z") if N_DEVICES >= 8 else ("x", "y")

    def body(v):
        return M.ring_all_reduce(v, names, dim=-1)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False))
    v = _exact_random(jax.random.PRNGKey(0), (2, 8))
    p = int(np.prod(shape[1:]))
    np.testing.assert_array_equal(np.asarray(f(v)), np.asarray(v) * p)
    stats = RL.parse_collectives(f.lower(v).compile().as_text())
    assert stats.counts.get("all-reduce", 0) == 0
    assert stats.counts.get("collective-permute", 0) >= 1


def test_ring_all_reduce_fallback_nondivisible():
    """Rings (p > 2) that don't split the dim evenly must silently fall
    back to the blocking psum — correctness over decomposition."""
    shape = (1, 1, 2, 4) if N_DEVICES >= 8 else (1, 1, 1, 4)
    mesh = LM.make_smoke_mesh(shape)
    axes = LM.bind_4d(mesh)

    def body(v):
        return M.ring_all_reduce(v, axes.z, dim=-1)  # 6 % 4 != 0

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False))
    v = _exact_random(jax.random.PRNGKey(0), (2, 6))
    np.testing.assert_array_equal(np.asarray(f(v)), np.asarray(v) * 4)


@pytest.mark.parametrize("chunks", [1, 2, 3])
def test_ar_matmul_bitwise_vs_psum(chunks):
    """Satellite acceptance: the fused AR-matmul forward is bitwise
    identical to the blocking GEMM + psum at matching chunk counts (on
    exactly-summable values, where reduction order cannot hide schedule
    bugs)."""
    shape = (1, 2, 2, 2) if N_DEVICES >= 8 else (1, 2, 2, 1)
    mesh = LM.make_smoke_mesh(shape)
    x = _exact_random(jax.random.PRNGKey(0), (B, K))
    w = _exact_random(jax.random.PRNGKey(1), (K, N))

    def body(x, w):
        blocking = jax.lax.psum(
            jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32
                                ).astype(x.dtype), ("x", "y"))
        ring2 = CMM.ar_matmul(x, w, "x", chunks=chunks)       # p = 2 path
        ring2 = jax.lax.psum(ring2, "y")
        ring4 = CMM.ar_matmul(x, w, ("x", "y"), chunks=chunks)  # tuple ring
        d2 = jnp.max(jnp.abs(blocking - ring2))
        d4 = jnp.max(jnp.abs(blocking - ring4))
        return jax.lax.pmax(jax.lax.pmax(jnp.stack([d2, d4]), "x"), "y")

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(), P()),
                          out_specs=P(), check_vma=False))
    d2, d4 = np.asarray(f(x, w))
    assert d2 == 0.0 and d4 == 0.0, (d2, d4)


# --------------------------------------------------------------------- #
# overlapped tp primitives == blocking (values AND gradients)
# --------------------------------------------------------------------- #

def _run_matmul(mesh, base, axes, x, w, in_shard, out_shard):
    wspec = PP.wspec(base, in_shard, out_shard)
    in_ax = base.axis(in_shard) if in_shard else None
    out_ax = base.axis(out_shard) if out_shard else None
    xspec = base.pspec(base.batch_axes(), None, in_ax)

    def loss(x, w):
        y = PP.tp_matmul(x, w, axes, in_shard, out_shard)
        s = jnp.sum(y.astype(jnp.float32) ** 2)
        return PP.ar_bwd_identity(
            s, M._names(axes.batch_axes()) + M._names(out_ax))

    def step(x, w):
        v, (gx, gw) = jax.value_and_grad(loss, argnums=(0, 1))(x, w)
        return v, gx, M.psum(gw, axes.data)

    f = shard_map(step, mesh=mesh, in_specs=(xspec, wspec),
                  out_specs=(P(), xspec, wspec), check_vma=False)
    return jax.jit(f)(x, w)


@pytest.mark.parametrize("shards", [("x", "y"), ("y", "x")],
                         ids=["normal", "transposed"])
@pytest.mark.parametrize("shape", SHAPES_4D, ids=str)
@pytest.mark.parametrize("ov", OVERLAPS, ids=_ids)
def test_tp_matmul_overlap_matches_blocking(shape, ov, shards):
    """Fwd + dX + dW parity, normal and transposed (§4.1) layers."""
    mesh = LM.make_smoke_mesh(shape)
    base = LM.bind_4d(mesh)
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    in_shard, out_shard = shards
    vb, gxb, gwb = _run_matmul(mesh, base, base, x, w, in_shard, out_shard)
    vo, gxo, gwo = _run_matmul(mesh, base, base.with_overlap(ov), x, w,
                               in_shard, out_shard)
    np.testing.assert_allclose(np.asarray(vb), np.asarray(vo), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(gxb), np.asarray(gxo),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gwb), np.asarray(gwo),
                               rtol=2e-5, atol=1e-5)


def test_tp_matmul_tuple_z_ring():
    """Tuple (multi-name) z axes must take the fused ring path — parity
    with blocking AND collective-permutes (not a blocking fallback) in
    the HLO."""
    shape = (1, 2, 2, 2) if N_DEVICES >= 8 else (1, 1, 2, 2)
    mesh = LM.make_smoke_mesh(shape)
    # depth axis spans two mesh names: gz = 4
    base = M.bind_axes(mesh, data=("data",), x="x", z=("y", "z"))
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    vb, gxb, gwb = _run_matmul(mesh, base, base, x, w, "x", None)
    ov = OverlapConfig.all_on()
    axes = base.with_overlap(ov)
    vo, gxo, gwo = _run_matmul(mesh, base, axes, x, w, "x", None)
    np.testing.assert_allclose(np.asarray(vb), np.asarray(vo), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(gxb), np.asarray(gxo),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gwb), np.asarray(gwo),
                               rtol=2e-5, atol=1e-5)

    wspec = PP.wspec(base, "x", None)
    xspec = base.pspec(base.batch_axes(), None, base.x)

    def fwd(x, w):
        return PP.tp_matmul(x, w, axes, "x", None)

    f = shard_map(fwd, mesh=mesh, in_specs=(xspec, wspec),
                  out_specs=base.pspec(base.batch_axes(), None, None),
                  check_vma=False)
    stats = RL.parse_collectives(jax.jit(f).lower(x, w).compile().as_text())
    assert stats.counts.get("all-gather", 0) == 0, stats.counts
    assert stats.counts.get("collective-permute", 0) >= 1, stats.counts


@pytest.mark.parametrize("ov", OVERLAPS, ids=_ids)
def test_batched_matmul_overlap_matches_blocking(ov):
    mesh = LM.make_smoke_mesh(SHAPE_Z)
    base = LM.bind_4d(mesh)
    E, C = 4, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (E, C, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (E, K, N)) * 0.1
    xspec, wspec = P("y", None, "x"), P("y", "x", "z")

    def run(axes):
        def loss(x, w):
            y = PP.tp_batched_matmul(x, w, axes, "x", None)
            return PP.ar_bwd_identity(
                jnp.sum(y.astype(jnp.float32) ** 2), ("y", "z"))

        def step(x, w):
            v, g = jax.value_and_grad(loss, argnums=(0, 1))(x, w)
            return v, g[0], g[1]

        f = shard_map(step, mesh=mesh, in_specs=(xspec, wspec),
                      out_specs=(P(), xspec, wspec), check_vma=False)
        return jax.jit(f)(x, w)

    rb = run(base)
    ro = run(base.with_overlap(ov))
    for name, a, b in zip(("val", "dx", "dw"), rb, ro):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-5, err_msg=name)


@pytest.mark.parametrize("shape",
                         fitting_shapes([(1, 2, 2, 2), (1, 1, 2, 4),
                                         (1, 2, 2, 1), (1, 1, 2, 2)]),
                         ids=str)
@pytest.mark.parametrize("ov", OVERLAPS, ids=_ids)
def test_tied_logits_overlap_matches_blocking(shape, ov):
    mesh = LM.make_smoke_mesh(shape)
    base = LM.bind_4d(mesh)
    V, D = 32, 16
    table = jax.random.normal(jax.random.PRNGKey(2), (V, D)) * 0.1
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0, V)
    tspec = base.pspec(base.y, M._names(base.x) + M._names(base.z))

    def run(axes):
        def par(table, toks):
            h = PP.embedding_lookup(toks, table, axes)
            logits = PP.tied_lm_logits(h, table, axes)
            return PP.ar_bwd_identity(
                jnp.sum(logits.astype(jnp.float32) ** 2), axes.y)

        def step(table, toks):
            return jax.value_and_grad(par)(table, toks)

        f = shard_map(step, mesh=mesh, in_specs=(tspec, P(None, None)),
                      out_specs=(P(), tspec), check_vma=False)
        return jax.jit(f)(table, toks)

    vb, gb = run(base)
    vo, go = run(base.with_overlap(ov))
    np.testing.assert_allclose(np.asarray(vb), np.asarray(vo), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(go),
                               rtol=2e-5, atol=1e-5)


def _tp_collective_counts(ov):
    """Collective op counts of one tp_matmul fwd+bwd toy program."""
    mesh = LM.make_smoke_mesh(SHAPE_Z)
    base = LM.bind_4d(mesh)
    axes = base.with_overlap(ov) if ov is not None else base
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    wspec = PP.yz_spec(base, False)
    xspec = base.pspec(base.batch_axes(), None, base.x)

    def loss(x, w):
        y = PP.tp_matmul(x, w, axes, "x", "y")
        return PP.ar_bwd_identity(
            jnp.sum(y.astype(jnp.float32) ** 2),
            M._names(axes.batch_axes()) + M._names(axes.y))

    def step(x, w):
        v, g = jax.value_and_grad(loss, argnums=(0, 1))(x, w)
        return v, g[0], M.psum(g[1], axes.data)

    f = shard_map(step, mesh=mesh, in_specs=(xspec, wspec),
                  out_specs=(P(), xspec, wspec), check_vma=False)
    compiled = jax.jit(f).lower(x, w).compile()
    return RL.parse_collectives(compiled.as_text())


def test_overlap_hlo_uses_collective_permute():
    """Acceptance: the overlapped mode's HLO replaces the monolithic z
    all-gather / reduce-scatter of the matmul path with collective-permute
    chains."""
    blocking = _tp_collective_counts(None)
    ring = _tp_collective_counts(OverlapConfig(
        matmul=True, batched_matmul=True, tied_logits=True))
    assert blocking.counts.get("all-gather", 0) >= 2
    assert blocking.counts.get("reduce-scatter", 0) >= 1
    assert blocking.counts.get("collective-permute", 0) == 0
    assert ring.counts.get("all-gather", 0) == 0
    assert ring.counts.get("reduce-scatter", 0) == 0
    assert ring.counts.get("collective-permute", 0) >= 3  # fwd + dX + dW
    # the overlap-aware estimate must see the ring traffic as hideable
    est_b = RL.step_time_estimate(1e9, blocking.bytes_by_kind)
    est_r = RL.step_time_estimate(1e9, ring.bytes_by_kind)
    assert est_r.exposed_comm < est_b.exposed_comm
    assert est_r.hidden_comm > 0.0


def test_ar_overlap_hlo_replaces_all_reduces():
    """Acceptance (this PR): with ``all_reduce`` on, the x/y activation
    all-reduces of the matmul fwd/bwd also become collective-permute
    chains; only the loss-level psums stay all-reduce."""
    ring_z = _tp_collective_counts(OverlapConfig(
        matmul=True, batched_matmul=True, tied_logits=True))
    ring_xy = _tp_collective_counts(OverlapConfig.all_on())
    # the fwd (over x) and bwd dX (over y) activation all-reduces convert
    # (mapped axes of size > 1 only: x is unmapped on the 4-device shape)
    converts = sum(1 for p in SHAPE_Z[1:3] if p > 1)
    assert (ring_xy.counts.get("all-reduce", 0)
            <= ring_z.counts.get("all-reduce", 0) - converts), (
        ring_z.counts, ring_xy.counts)
    assert (ring_xy.counts.get("collective-permute", 0)
            > ring_z.counts.get("collective-permute", 0))
    assert ring_xy.counts.get("all-gather", 0) == 0
    assert ring_xy.counts.get("reduce-scatter", 0) == 0


# --------------------------------------------------------------------- #
# α-β time model
# --------------------------------------------------------------------- #

def test_time_model_reduces_to_volume_model():
    """With α = 0 and overlap off, exposed comm time == volume * β."""
    layers = CM.transformer_layers(2048, n_layers=4)
    hw = CM.HardwareParams(alpha=0.0)
    for d in [CM.Decomposition(4, 4, 4, 4), CM.Decomposition(16, 4, 4, 1),
              CM.Decomposition(2, 2, 2, 2)]:
        st = CM.predict_step_time(layers, 1 << 18, d, hw)
        want = (CM.model_volume(layers, 1 << 18, d)
                * hw.bytes_per_elem / hw.link_bw)
        assert abs(st.exposed_comm - want) <= 1e-9 * want
        assert st.hidden_comm == 0.0


def test_time_model_conserves_volume_under_overlap():
    """The ring knobs move time from exposed to hidden, never delete it:
    at α = 0, exposed + hidden == volume * β for EVERY overlap config
    (the shared layer_geometry keeps the two models in lockstep)."""
    layers = CM.transformer_layers(2048, n_layers=4)
    hw = CM.HardwareParams(alpha=0.0)
    d = CM.Decomposition(4, 4, 4, 4)
    for ov in [None, OverlapConfig.all_on(),
               OverlapConfig(matmul=True),
               OverlapConfig(all_reduce=True),
               OverlapConfig.all_on(cache_weight_gather=True)]:
        st = CM.predict_step_time(layers, 1 << 18, d, hw, overlap=ov)
        want = (CM.model_volume(layers, 1 << 18, d, overlap=ov)
                * hw.bytes_per_elem / hw.link_bw)
        got = st.exposed_comm + st.hidden_comm
        assert abs(got - want) <= 1e-9 * want, (ov, got, want)


def test_time_model_monotone_in_volume():
    """More volume (same decomposition/hardware) => more exposed time."""
    hw = CM.HardwareParams()
    d = CM.Decomposition(4, 4, 2, 2)
    prev = -1.0
    for h in (512, 1024, 2048, 4096):
        layers = CM.transformer_layers(h)
        st = CM.predict_step_time(layers, 1 << 18, d, hw)
        assert st.exposed_comm > prev
        prev = st.exposed_comm
    # and in tokens at fixed shapes
    layers = CM.transformer_layers(1024)
    prev = -1.0
    for tokens in (1 << 14, 1 << 16, 1 << 18):
        st = CM.predict_step_time(layers, tokens, d, hw)
        assert st.exposed_comm > prev
        prev = st.exposed_comm


def test_overlap_hides_z_traffic_only():
    """The z-only ring knob hides z weight traffic and nothing else."""
    layers = CM.transformer_layers(4096, n_layers=8)
    d = CM.Decomposition(4, 2, 2, 8)
    z_only = OverlapConfig(matmul=True, batched_matmul=True,
                           tied_logits=True)
    blocking = CM.predict_step_time(layers, 1 << 20, d)
    ring = CM.predict_step_time(layers, 1 << 20, d, overlap=z_only)
    assert ring.hidden_comm > 0.0
    assert ring.exposed_comm < blocking.exposed_comm
    # conservation: hiding moves time, it doesn't delete it
    assert (abs((ring.exposed_comm + ring.hidden_comm)
                - blocking.exposed_comm) < 1e-12)
    # z = 1 has nothing to hide under the z-only knob
    d1 = CM.Decomposition(4, 8, 8, 1)
    r1 = CM.predict_step_time(layers, 1 << 20, d1, overlap=z_only)
    assert r1.hidden_comm == 0.0


def test_overlap_hides_activation_all_reduces():
    """The ``all_reduce`` knob hides x/y activation traffic — including
    at g_z = 1, where the z knob has nothing to do — within the compute
    window left over by the z rings."""
    layers = CM.transformer_layers(4096, n_layers=8)
    d1 = CM.Decomposition(4, 8, 8, 1)        # pure tensor-parallel point
    blocking = CM.predict_step_time(layers, 1 << 20, d1)
    ar = CM.predict_step_time(layers, 1 << 20, d1,
                              overlap=OverlapConfig(all_reduce=True))
    assert ar.hidden_comm > 0.0
    assert ar.exposed_comm < blocking.exposed_comm
    assert (abs((ar.exposed_comm + ar.hidden_comm)
                - blocking.exposed_comm) < 1e-12)
    # with both knobs, z traffic claims the window first; total hidden
    # can only grow vs either knob alone
    d = CM.Decomposition(4, 2, 2, 8)
    z_only = CM.predict_step_time(
        layers, 1 << 20, d, overlap=OverlapConfig(matmul=True))
    both = CM.predict_step_time(layers, 1 << 20, d,
                                overlap=OverlapConfig.all_on())
    assert both.hidden_comm >= z_only.hidden_comm
    # and never exceed the overlap-efficiency-scaled compute window
    hw = CM.TPU_V5E
    assert both.hidden_comm <= hw.overlap_efficiency * both.compute + 1e-12


def test_time_model_ranks_eq7_optimum():
    """predict_step_time must rank the paper's Eq. 7 transformer optimum
    (G_c = sqrt(3 G_tensor)) no worse than the volume-only model does on
    the 2D (g_z = 1) closed form."""
    H, tokens = 4096, 1 << 20
    layers = CM.transformer_layers(H, n_layers=24)
    g, g_tensor = 256, 16
    cons = CM.Constraints(min_tensor=g_tensor, z_divides=(1,))

    def best_gy(objective):
        ranked = CM.optimize_decomposition(
            layers, tokens, g, cons, top_k=8, objective=objective,
            include_data_parallel=False)
        cands = [d for d, v in ranked if d.g_tensor == g_tensor]
        assert cands, ranked
        return cands[0].g_y

    want = CM.paper_optimal_gc(g_tensor)  # ~6.93
    vol_err = abs(best_gy("volume") - want)
    time_err = abs(best_gy("time") - want)
    assert time_err <= vol_err, (time_err, vol_err)


def test_layer_volume_overlap_cache_knob():
    """cache_weight_gather drops exactly one AG_z worth of volume."""
    ls = CM.LayerShape(1024, 4096)
    d = CM.Decomposition(2, 2, 2, 4)
    base = CM.layer_volume(ls, 1 << 16, d)
    cached = CM.layer_volume(
        ls, 1 << 16, d,
        overlap=OverlapConfig(cache_weight_gather=True))
    w_full = ls.k * ls.n / (d.g_x * d.g_y)
    ag = CM.gather_or_scatter_volume(d.g_z, w_full)
    assert abs((base - cached) - ag) < 1e-9
