"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
same-family variant (<=2-8 layers, d_model<=256, <=4 experts) runs one
forward/train step on CPU with finite loss + decreasing over 3 steps,
plus a decode step where the family supports serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import N_DEVICES, train_smoke
from repro.configs import ASSIGNED, get_config

DECODE_ARCHS = ["qwen3-1.7b", "deepseek-v2-lite-16b", "jamba-v0.1-52b",
                "xlstm-350m", "whisper-small"]


@pytest.mark.parametrize("arch", list(ASSIGNED) + ["gpt-paper-20b"])
def test_train_step(arch, mesh4, axes4):
    cfg, losses = train_smoke(arch, mesh4, axes4, steps=3, B=8, S=32)
    assert cfg.d_model <= 512 and cfg.n_layers <= 8
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_step(arch, mesh4, axes4):
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import steps as ST

    cfg = get_config(arch).reduced()
    params, specs = ST.init_model(cfg, axes4, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh4, params, spec_tree_to_pspecs(specs))
    build, _ = ST.make_decode_step(cfg, mesh4, axes4, dtype=jnp.float32)
    step_fn, ct = build(4, 64)
    caches = ST.zeros_caches(mesh4, ct)
    tok = jnp.ones((4, 1), jnp.int32)
    logits, caches = step_fn(params, caches, tok, jnp.int32(0))
    logits, caches = step_fn(params, caches, tok, jnp.int32(1))
    assert logits.shape[0] == 4 and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "jamba-v0.1-52b",
                                  "xlstm-350m"])
def test_decode_seqshard(arch, mesh4, axes4):
    """long_500k path: batch 1, KV-cache sequence sharded over data."""
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import steps as ST

    cfg = get_config(arch).reduced()
    params, specs = ST.init_model(cfg, axes4, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh4, params, spec_tree_to_pspecs(specs))
    build, _ = ST.make_decode_step(cfg, mesh4, axes4, seqshard=True,
                                   dtype=jnp.float32)
    step_fn, ct = build(1, 128)
    caches = ST.zeros_caches(mesh4, ct)
    tok = jnp.ones((1, 1), jnp.int32)
    logits, caches = step_fn(params, caches, tok, jnp.int32(5))
    assert np.isfinite(np.asarray(logits)).all()


def test_decode_seqshard_matches_plain(mesh4, axes4):
    """Sequence-sharded decode (batch 1, cache seq over data) must equal
    plain decode. The plain path needs data=1 to hold batch 1, so it runs
    on a different factorization of the same 8 devices — mesh invariance
    of the math is itself pinned by test_system."""
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import mesh as LM
    from repro.launch import steps as ST

    cfg = get_config("h2o-danube-3-4b").reduced()
    outs = {}
    shapes = (((False, (1, 2, 4, 1)), (True, (2, 2, 2, 1)))
              if N_DEVICES >= 8
              else ((False, (1, 2, 2, 1)), (True, (2, 1, 2, 1))))
    for seqshard, shape in shapes:
        mesh = LM.make_smoke_mesh(shape)
        axes = LM.bind_4d(mesh)
        params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                      dtype=jnp.float32)
        params = ST.device_put_tree(mesh, params,
                                    spec_tree_to_pspecs(specs))
        build, _ = ST.make_decode_step(cfg, mesh, axes, seqshard=seqshard,
                                       dtype=jnp.float32)
        step_fn, ct = build(1, 64)
        caches = ST.zeros_caches(mesh, ct)
        logits = None
        for pos in range(3):
            tok = jnp.full((1, 1), 7 + pos, jnp.int32)
            logits, caches = step_fn(params, caches, tok, jnp.int32(pos))
        outs[seqshard] = np.asarray(logits)
    np.testing.assert_allclose(outs[False], outs[True], rtol=2e-3,
                               atol=1e-4)


def test_all_configs_have_citations():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        assert cfg.source, arch
        assert cfg.param_count() > 0


def test_param_counts_plausible():
    """Config param counts should be near the advertised sizes."""
    expect = {
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "stablelm-1.6b": (1.3e9, 2.1e9),
        "h2o-danube-3-4b": (3.0e9, 5.0e9),
        "nemotron-4-15b": (12e9, 18e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "jamba-v0.1-52b": (40e9, 60e9),
        "deepseek-v3-671b": (580e9, 720e9),
        "internvl2-26b": (17e9, 26e9),   # LLM backbone only (vision stubbed)
        "whisper-small": (0.15e9, 0.3e9),
        "xlstm-350m": (0.25e9, 0.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_dsv3_mtp_trains(mesh4, axes4):
    """DeepSeek-V3's MTP head (depth 1) contributes a finite, decreasing
    auxiliary loss."""
    import jax
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import steps as ST
    from repro.optim.adamw import AdamWConfig, init_state

    cfg = get_config("deepseek-v3-671b").reduced()
    assert cfg.mtp_depth == 1
    params, specs = ST.init_model(cfg, axes4, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh4, params,
                                spec_tree_to_pspecs(specs))
    state = init_state(params)
    fn, _, _ = ST.make_train_step(
        cfg, mesh4, axes4,
        AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20),
        ST.TrainOptions(overdecompose=1, dtype=jnp.float32,
                        mtp_weight=0.3))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32)}
    mtps = []
    for _ in range(3):
        params, state, m = fn(params, state, batch)
        assert np.isfinite(float(m["loss"]))
        mtps.append(float(m["mtp"]))
    assert mtps[-1] < mtps[0]
