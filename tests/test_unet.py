"""Channel-parallel U-Net (the paper's own model family): DDPM training
smoke + decomposition invariance of the loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES
from repro.core import mesh as M
from repro.core.compat import shard_map
from repro.core.partition import spec_tree_to_pspecs, unbox, z_reduce_grads
from repro.launch import mesh as LM
from repro.models import unet as U

SHAPE0 = (2, 2, 2, 1) if N_DEVICES >= 8 else (1, 2, 2, 1)
SHAPES_INV = ([(2, 2, 2, 1), (2, 1, 4, 1), (1, 2, 2, 2)]
              if N_DEVICES >= 8
              else [(1, 2, 2, 1), (2, 1, 2, 1), (1, 1, 2, 2)])


def _run(mesh_shape, steps=3):
    mesh = LM.make_smoke_mesh(mesh_shape)
    axes = LM.bind_4d(mesh)
    cfg = U.UNetConfig().reduced()
    boxed = U.unet_init(jax.random.PRNGKey(0), cfg, axes,
                        dtype=jnp.float32)
    params, specs = unbox(boxed)
    pspecs = spec_tree_to_pspecs(specs)
    rng = np.random.RandomState(0)
    B = 8
    imgs = jnp.asarray(rng.randn(B, cfg.image_size, cfg.image_size, 3),
                       jnp.float32)
    t = jnp.asarray(rng.randint(0, 1000, (B,)), jnp.int32)
    noise = jnp.asarray(rng.randn(B, cfg.image_size, cfg.image_size, 3),
                        jnp.float32)
    bspec = axes.pspec(axes.batch_axes(), None, None, None)
    tspec = axes.pspec(axes.batch_axes())

    def sgd(params, imgs, t, noise):
        loss, grads = jax.value_and_grad(
            lambda p: U.ddpm_loss(p, cfg, axes, imgs, t, noise))(params)
        grads = jax.tree.map(lambda g: M.psum(g, axes.data), grads)
        grads = z_reduce_grads(grads, specs, axes, M.psum)
        new = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
        return new, loss

    fn = jax.jit(shard_map(sgd, mesh=mesh,
                           in_specs=(pspecs, bspec, tspec, bspec),
                           out_specs=(pspecs, P()), check_vma=False))
    losses = []
    for _ in range(steps):
        params, l = fn(params, imgs, t, noise)
        losses.append(float(l))
    return losses


def test_unet_ddpm_trains():
    losses = _run(SHAPE0)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_unet_mesh_invariant():
    l1 = _run(SHAPES_INV[0], steps=2)
    l2 = _run(SHAPES_INV[1], steps=2)
    l3 = _run(SHAPES_INV[2], steps=2)
    np.testing.assert_allclose(l1, l2, rtol=2e-4)
    np.testing.assert_allclose(l1, l3, rtol=2e-4)
