"""ZeRO-3 param-shard streaming (core/gradsync.py make_leaf_plan /
ParamStreamer + the zero3 train-step path).

The streaming schedule must be a pure decomposition of the replicated
one: params sharded 1/G_data with per-layer just-in-time ring gathers
(and their autodiff-transpose reduce-scatters) match the blocking
psum + replicated-AdamW baseline — bitwise on exactly-summable values,
within fp32 reassociation on a real model. The compiled step must keep
every data-axis gather inside the per-layer streaming window (no
full-parameter all-gather), per-rank param+optimizer state must shrink
by ~G_data, checkpoints must round-trip across different g_data, and
the cross-step comm model must reduce exactly to the PR-3 exposed model
when the window is off. Shapes scale to 4-device CI hosts.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES
from repro.core import comm_model as CM
from repro.core import gradsync as GS
from repro.core import mesh as M
from repro.core.compat import shard_map
from repro.core.gradsync import GradSyncConfig
from repro.core.partition import ParamSpec, spec_tree_to_pspecs
from repro.launch import mesh as LM
from repro.launch import roofline as RL
from repro.launch import steps as ST
from repro.optim import adamw as OPT

SHAPE_2X2 = (2, 2, 1, 1)
SHAPE_DP4 = (4, 1, 2, 1) if N_DEVICES >= 8 else (4, 1, 1, 1)


def _exact_random(key, shape):
    return jax.random.randint(key, shape, -4, 5).astype(jnp.float32)


# --------------------------------------------------------------------- #
# synthetic tree with a scan-stacked leaf
# --------------------------------------------------------------------- #

N_LAYERS = 3


def _toy_tree():
    def leaf(shape, spec, z_reduced=False, y_reduce=False):
        return (jax.ShapeDtypeStruct(shape, jnp.float32),
                ParamSpec(spec, z_reduced, y_reduce))
    tree = {
        "embed": leaf((16, 4), P(None, None)),
        "segments": {"seg0": {
            "w": leaf((N_LAYERS, 8, 4), P(None, "x", None)),
            "norm": leaf((N_LAYERS, 9), P(None, None)),
        }},
        "final_norm": leaf((7,), P()),
    }
    structs = jax.tree.map(lambda t: t[0], tree,
                           is_leaf=lambda t: isinstance(t, tuple))
    specs = jax.tree.map(lambda t: t[1], tree,
                         is_leaf=lambda t: isinstance(t, tuple))
    return structs, specs


def _toy_values(structs, seed=0):
    leaves, treedef = jax.tree.flatten(structs)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(
        treedef, [_exact_random(k, l.shape) for k, l in zip(keys, leaves)])


def _stack_of(path, local_shape):
    keys = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
    if keys and keys[0] == "segments" and len(local_shape) > 0:
        return int(local_shape[0])
    return 1


def _leaf_plan(structs, specs, axes):
    return GS.make_leaf_plan(structs, specs, axes,
                             no_decay=OPT._no_decay, stack_of=_stack_of)


# --------------------------------------------------------------------- #
# leaf plan structure
# --------------------------------------------------------------------- #

def test_leaf_plan_structure():
    mesh = LM.make_smoke_mesh(SHAPE_2X2)
    axes = LM.bind_4d(mesh)
    structs, specs = _toy_tree()
    plan = _leaf_plan(structs, specs, axes)
    flat, _ = jax.tree_util.tree_flatten_with_path(structs)
    assert len(plan.buckets) == plan.n_leaves == len(flat)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec))
    for i, (b, (path, leaf)) in enumerate(zip(plan.buckets, flat)):
        assert len(b.segments) == 1 and b.segments[0].leaf == i
        assert b.padded % plan.dp == 0 and b.padded >= b.size
        lshape = GS._local_shape(tuple(leaf.shape),
                                 tuple(spec_leaves[i].spec), axes)
        if _stack_of(path, lshape) > 1:
            assert b.stack == lshape[0]
            assert b.segments[0].shape == lshape[1:]
        else:
            assert b.stack == 1 and b.segments[0].shape == lshape
    # the shard tree keeps the params' own structure
    shard_structs = GS.abstract_param_shards(plan, axes)
    assert (jax.tree.structure(shard_structs)
            == jax.tree.structure(structs))
    # stacked leaves keep their scan dim, flat dims tile over the mesh
    g = axes.size(axes.all_names())
    seg = shard_structs["segments"]["seg0"]["w"]
    assert seg.shape[0] == N_LAYERS and seg.shape[1] % g == 0
    pspecs = GS.param_shard_pspecs(plan, axes)
    assert tuple(pspecs["segments"]["seg0"]["w"])[0] is None


def test_prefetch_requires_zero3():
    with pytest.raises(ValueError, match="zero3"):
        GradSyncConfig(prefetch=True)
    assert GradSyncConfig(zero3=True).enabled
    assert GradSyncConfig(zero3=True).state_sharded
    assert not GradSyncConfig(bucketed=True).state_sharded


# --------------------------------------------------------------------- #
# shard / gather round trip (bitwise)
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("ring", [True, False], ids=["ring", "blocking"])
def test_shard_gather_roundtrip(ring):
    mesh = LM.make_smoke_mesh(SHAPE_DP4)
    axes = LM.bind_4d(mesh)
    structs, specs = _toy_tree()
    plan = _leaf_plan(structs, specs, axes)
    pspecs = spec_tree_to_pspecs(specs)

    def body(params):
        shards = GS.shard_params(params, plan, axes)
        back = GS.unshard_params(shards, plan, axes, ring=ring)
        # a scan-sliced slot row gathers to exactly that layer's params
        slot = jax.tree.map(lambda x: x[1],
                            shards["segments"]["seg0"])
        bt = GS.ParamStreamer(plan=plan, axes=axes,
                              ring=ring).buckets_like()
        row = jax.tree.map(
            lambda s, b: GS.gather_param_leaf(s, b, axes, ring=ring),
            slot, bt["segments"]["seg0"])
        return back, row

    params = _toy_values(structs, seed=3)
    out, row = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(pspecs,),
        out_specs=(pspecs, jax.tree.map(lambda x: P(*tuple(x)[1:]),
                                        pspecs["segments"]["seg0"],
                                        is_leaf=lambda x: isinstance(x, P))),
        check_vma=False))(params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ("w", "norm"):
        np.testing.assert_array_equal(
            np.asarray(row[k]),
            np.asarray(params["segments"]["seg0"][k][1]))


# --------------------------------------------------------------------- #
# full train step: parity, HLO window, memory
# --------------------------------------------------------------------- #

def _model_setup(shape, gs, *, overdecompose=2, arch="stablelm-1.6b"):
    from repro.configs import get_config
    from repro.optim.adamw import AdamWConfig, init_state

    mesh = LM.make_smoke_mesh(shape)
    axes = LM.bind_4d(mesh)
    cfg = get_config(arch).reduced()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    opts = ST.TrainOptions(overdecompose=overdecompose, dtype=jnp.float32,
                           gradsync=gs)
    fn, _, _ = ST.make_train_step(
        cfg, mesh, axes, AdamWConfig(lr=1e-3, warmup_steps=1,
                                     total_steps=50), opts)
    if gs.state_sharded:
        tools = ST.make_gradsync_tools(cfg, mesh, axes, opts)
        state = tools.init(params)
        if gs.zero3:
            params = tools.shard_params(params)
    else:
        tools, state = None, init_state(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32)}
    return cfg, mesh, axes, opts, fn, params, state, batch, tools


ZERO3_MODES = [
    ("zero3", GradSyncConfig(zero3=True, bucket_mb=0.25)),
    ("zero3_prefetch", GradSyncConfig(zero3=True, prefetch=True,
                                      bucket_mb=0.25)),
    ("zero3_noring", GradSyncConfig(zero3=True, ring=False)),
    ("zero3_od1", GradSyncConfig(zero3=True)),  # single microbatch
]


def test_zero3_train_step_parity():
    results = {}
    modes = ([("base", GradSyncConfig(), 2), ("base_od1",
              GradSyncConfig(), 1)]
             + [(n, g, 1 if n == "zero3_od1" else 2)
                for n, g in ZERO3_MODES])
    for name, gs, od in modes:
        _, _, _, _, fn, params, state, batch, tools = _model_setup(
            SHAPE_2X2, gs, overdecompose=od)
        p, s = params, state
        for _ in range(3):
            p, s, m = fn(p, s, batch)
        if gs.zero3:
            p = tools.unshard_params(p)
        results[name] = (float(m["loss"]), float(m["grad_norm"]),
                         [np.asarray(x) for x in jax.tree.leaves(p)])
    for name, _ in ZERO3_MODES:
        # compare against the SAME overdecompose's replicated baseline
        # (od changes fp32 accumulation order on its own)
        lb, nb, pb = results["base_od1" if name == "zero3_od1"
                             else "base"]
        l, n, pz = results[name]
        assert abs(l - lb) < 1e-5, (name, l, lb)
        assert abs(n - nb) < 1e-4 * max(1.0, nb), (name, n, nb)
        gap = max(float(np.max(np.abs(a - b))) for a, b in zip(pb, pz))
        # fp32 reassociation only: the streamed programs fuse FMAs
        # differently (prefetch additionally runs its last layer outside
        # the scan), and the drift compounds over the 3 steps
        assert gap < 2e-5, f"{name}: params diverged from baseline: {gap}"


def test_zero3_n1_segment_parity():
    """Segments with n_periods == 1 (deepseek's dense head segment, and
    EVERY segment of the dry-run depth probes) plan as unstacked: their
    single layer is resident, not streamed, and the scan must not
    re-gather it (regression: the first cut double-gathered and died at
    trace time on any heterogeneous-depth config)."""
    results = {}
    for name, gs in [("base", GradSyncConfig()),
                     ("zero3", GradSyncConfig(zero3=True)),
                     ("zero3_pref", GradSyncConfig(zero3=True,
                                                   prefetch=True))]:
        _, _, _, _, fn, params, state, batch, tools = _model_setup(
            SHAPE_2X2, gs, overdecompose=1, arch="deepseek-v2-lite-16b")
        p, s = params, state
        for _ in range(2):
            p, s, m = fn(p, s, batch)
        results[name] = (float(m["loss"]), float(m["grad_norm"]))
    for name in ("zero3", "zero3_pref"):
        assert abs(results[name][0] - results["base"][0]) < 1e-5, results
        assert abs(results[name][1] - results["base"][1]) < 1e-4 * max(
            1.0, results["base"][1]), results


def test_zero3_unrolled_parity():
    """The python-unrolled layer path (what the dry-run depth probes
    lower) must match the scanned zero3 step: same gather-inside-remat /
    prefetch-retention schedules, python loop instead of scan."""
    from repro.configs import get_config
    from repro.optim.adamw import AdamWConfig, init_state

    def run(gs, unroll):
        mesh = LM.make_smoke_mesh(SHAPE_2X2)
        axes = LM.bind_4d(mesh)
        cfg = get_config("stablelm-1.6b").reduced()
        params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                      dtype=jnp.float32)
        params = ST.device_put_tree(mesh, params,
                                    spec_tree_to_pspecs(specs))
        opts = ST.TrainOptions(overdecompose=1, dtype=jnp.float32,
                               gradsync=gs, unroll_layers=unroll)
        fn, _, _ = ST.make_train_step(
            cfg, mesh, axes, AdamWConfig(lr=1e-3, warmup_steps=1,
                                         total_steps=50), opts)
        if gs.zero3:
            tools = ST.make_gradsync_tools(cfg, mesh, axes, opts)
            state = tools.init(params)
            params = tools.shard_params(params)
        else:
            state = init_state(params)
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (8, 32)), jnp.int32),
            "labels": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (8, 32)), jnp.int32)}
        p, s = params, state
        for _ in range(2):
            p, s, m = fn(p, s, batch)
        return float(m["loss"]), float(m["grad_norm"])

    base = run(GradSyncConfig(), True)
    got = run(GradSyncConfig(zero3=True, prefetch=True), True)
    assert abs(got[0] - base[0]) < 1e-5, (got, base)
    assert abs(got[1] - base[1]) < 1e-4 * max(1.0, base[1]), (got, base)


def test_zero3_hlo_streaming_window():
    """No data-axis gradient all-reduce survives, and no data-axis
    all-gather/ring hop moves more than one gathered unit of the leaf
    plan — i.e. no full-parameter all-gather outside the streamed
    per-layer window (the satellite HLO assertion)."""
    dp = SHAPE_DP4[0]
    gs = GradSyncConfig(zero3=True)
    _, _, _, _, fn, params, state, batch, tools = _model_setup(
        SHAPE_DP4, gs)
    hlo = fn.lower(params, state, batch).compile().as_text()
    ops = RL.parse_collective_ops(hlo)
    big_dp_ar = [op for op in ops if op.kind == "all-reduce"
                 and op.group_size == dp and op.raw_bytes > 2048]
    assert not big_dp_ar, "DP gradient all-reduces survived zero3"
    plan = tools.plan
    unit = max(b.padded * jnp.dtype(b.dtype).itemsize
               for b in plan.buckets)
    total = sum(b.padded * b.stack * jnp.dtype(b.dtype).itemsize
                for b in plan.buckets)
    assert unit < total / 2  # the bound is meaningfully tighter
    offenders = [op for op in ops
                 if op.kind in ("all-gather", "collective-permute")
                 and op.raw_bytes > unit]
    assert not offenders, \
        [(o.kind, o.group_size, o.raw_bytes) for o in offenders[:5]]
    assert any(op.kind == "collective-permute" for op in ops)


def test_zero3_state_memory_sharded_by_dp():
    """Per-rank persistent param+optimizer bytes under zero3 are the
    replicated layout's divided by G_data (+ bounded padding slack) —
    the acceptance-bound accounting the dry-run records report."""
    from repro.configs import get_config
    mesh = LM.make_smoke_mesh(SHAPE_DP4)
    axes = LM.bind_4d(mesh)
    cfg = get_config("stablelm-1.6b").reduced()
    base = ST.TrainOptions(dtype=jnp.float32)
    z3 = ST.TrainOptions(dtype=jnp.float32,
                         gradsync=GradSyncConfig(zero3=True))

    def bytes_per_rank(opts):
        (pst, pps), (ost, ops) = ST.state_layouts(cfg, axes, opts)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

        def tree_bytes(structs, pspecs):
            total = 0
            fs = jax.tree.leaves(structs)
            fp = jax.tree.leaves(pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
            for st, sp in zip(fs, fp):
                div = 1
                for e in tuple(sp):
                    if e is None:
                        continue
                    for nm in (e if isinstance(e, tuple) else (e,)):
                        div *= sizes.get(nm, 1)
                n = int(np.prod(st.shape)) if st.shape else 1
                total += (n // div) * jnp.dtype(st.dtype).itemsize
            return total
        return tree_bytes(pst, pps) + tree_bytes(ost, ops)

    rep, shard = bytes_per_rank(base), bytes_per_rank(z3)
    dp = SHAPE_DP4[0]
    # padding slack: one dp-block of fp32 per (m, v, master, param) leaf
    axes2 = axes.with_overlap(z3.overlap)
    structs, specs = ST.init_model(cfg, axes2, abstract=True,
                                   dtype=jnp.float32)
    plan = ST._zero3_plan(structs, specs, axes2)
    slack = 4 * 4 * sum(b.stack * dp for b in plan.buckets)
    assert shard <= rep / dp + slack, (shard, rep, dp, slack)


# --------------------------------------------------------------------- #
# checkpoint round-trip across g_data (bitwise resumed step)
# --------------------------------------------------------------------- #

def test_zero3_checkpoint_roundtrip_across_gdata(tmp_path):
    """Save the zero3 run (params + state in the replicated layout)
    under g_data=2, restore under g_data=4, and bitwise-compare the
    resumed step against staying on the source mesh. The toy loss runs
    through gather_param_leaf, so the gradient arrives through the
    gather's transpose (the real streaming path); exact small-int
    values make every reduction order exact."""
    from repro.checkpoint import ckpt

    structs, specs = _toy_tree()
    cfg = OPT.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
    path = os.path.join(tmp_path, "zero3.npz")
    meshes = {"A": LM.make_smoke_mesh(SHAPE_2X2),
              "B": LM.make_smoke_mesh((4, 1, 1, 1))}
    env = {}
    for k, mesh in meshes.items():
        axes = LM.bind_4d(mesh)
        plan = _leaf_plan(structs, specs, axes)
        pspecs = spec_tree_to_pspecs(specs)
        sspecs = GS.sharded_state_pspecs(plan, axes)
        ppspecs = GS.param_shard_pspecs(plan, axes)
        fullspecs = OPT.state_pspecs(pspecs)
        tools = {
            "init": jax.jit(shard_map(
                lambda p, _pl=plan, _ax=axes: GS.init_sharded_state(
                    p, _pl, _ax), mesh=mesh, in_specs=(pspecs,),
                out_specs=sspecs, check_vma=False)),
            "shard_p": jax.jit(shard_map(
                lambda p, _pl=plan, _ax=axes: GS.shard_params(
                    p, _pl, _ax), mesh=mesh, in_specs=(pspecs,),
                out_specs=ppspecs, check_vma=False)),
            "unshard_p": jax.jit(shard_map(
                lambda s, _pl=plan, _ax=axes: GS.unshard_params(
                    s, _pl, _ax), mesh=mesh, in_specs=(ppspecs,),
                out_specs=pspecs, check_vma=False)),
            "gather": jax.jit(shard_map(
                lambda s, _pl=plan, _ax=axes: GS.gather_sharded_state(
                    s, _pl, _ax), mesh=mesh, in_specs=(sspecs,),
                out_specs=fullspecs, check_vma=False)),
            "scatter": jax.jit(shard_map(
                lambda s, _pl=plan, _ax=axes: GS.scatter_full_state(
                    s, _pl, _ax), mesh=mesh, in_specs=(fullspecs,),
                out_specs=sspecs, check_vma=False)),
        }
        env[k] = (mesh, axes, plan, pspecs, sspecs, ppspecs, tools)

    def step_fn(mesh, axes, plan, pspecs, sspecs, ppspecs):
        bt_order = [None] * plan.n_leaves
        for b in plan.buckets:
            bt_order[b.segments[0].leaf] = b
        btree = jax.tree.unflatten(plan.treedef, bt_order)

        def body(shards, state, gbase):
            dp = float(axes.dp)

            def loss(sh):
                full = jax.tree.map(
                    lambda s, b: GS.gather_param_leaf(s, b, axes),
                    sh, btree)
                tot = 0.0
                for w, g in zip(jax.tree.leaves(full),
                                jax.tree.leaves(gbase)):
                    tot = tot + jnp.sum(w * g)
                return tot / dp  # per-rank partials: global grad is
                # mesh-independent (the transpose RS sums dp copies)
            g_sh = jax.grad(loss)(shards)
            gl = [g.astype(jnp.float32) for g in jax.tree.leaves(g_sh)]
            gl = GS.tensor_reduce_shards(gl, plan, axes)
            p, s, _ = OPT.apply_updates_sharded(gl, state, plan, axes,
                                                cfg, rebuild=False)
            return p, s
        return jax.jit(shard_map(body, mesh=mesh,
                                 in_specs=(ppspecs, sspecs, pspecs),
                                 out_specs=(ppspecs, sspecs),
                                 check_vma=False))

    params = _toy_values(structs, seed=1)
    gbase = _toy_values(structs, seed=2)

    mesh, axes, plan, pspecs, sspecs, ppspecs, T = env["A"]
    step_a = step_fn(mesh, axes, plan, pspecs, sspecs, ppspecs)
    pa, sa = step_a(T["shard_p"](params), T["init"](params), gbase)
    ckpt.save_sharded(path, jax.tree.map(np.asarray, T["unshard_p"](pa)),
                      sa, T["gather"], step=1, extra={"zero3": True})
    pa2, sa2 = step_a(pa, sa, gbase)
    ref_p = jax.device_get(T["unshard_p"](pa2))
    ref_s = jax.device_get(T["gather"](sa2))

    mesh, axes, plan, pspecs, sspecs, ppspecs, T = env["B"]
    like_state = {"opt": jax.tree.map(
        lambda s: {k: jax.ShapeDtypeStruct(s.shape, jnp.float32)
                   for k in ("m", "v", "master")}, structs),
        "step": jax.ShapeDtypeStruct((), jnp.int32)}
    full_p, sb, step = ckpt.restore_sharded(path, structs, like_state,
                                            T["scatter"])
    assert step == 1
    pb = T["shard_p"](jax.tree.map(jnp.asarray, full_p))
    pb2, sb2 = step_fn(mesh, axes, plan, pspecs, sspecs, ppspecs)(
        pb, sb, gbase)
    res_p = jax.device_get(T["unshard_p"](pb2))
    res_s = jax.device_get(T["gather"](sb2))
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(res_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ref_s), jax.tree.leaves(res_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# comm model: zero3 volume/time + the cross-step window
# --------------------------------------------------------------------- #

LAYERS = CM.transformer_layers(256, 2)
D = CM.Decomposition(4, 2, 2, 2)
TOKENS = 4096


def test_zero3_volume_formulas():
    buf = 120.0
    gsv = CM.gather_or_scatter_volume(4, buf)
    z3 = GradSyncConfig(zero3=True)
    z3p = GradSyncConfig(zero3=True, prefetch=True)
    # per microbatch: fwd AG + bwd re-gather AG + RS (2 with prefetch)
    assert CM.dp_sync_volume(4, buf, z3, 1) == pytest.approx(3 * gsv)
    assert CM.dp_sync_volume(4, buf, z3, 2) == pytest.approx(6 * gsv)
    assert CM.dp_sync_volume(4, buf, z3p, 2) == pytest.approx(4 * gsv)
    # prefetch at one microbatch: AG + RS == the all-reduce floor
    assert CM.dp_sync_volume(4, buf, z3p, 1) == \
        pytest.approx(CM.allreduce_volume(4, buf))
    assert CM.dp_sync_volume(1, buf, z3, 3) == 0.0


def test_zero3_time_conservation_and_hiding():
    gs = GradSyncConfig(zero3=True)
    hw0 = CM.HardwareParams(alpha=0.0)
    st = CM.predict_step_time(LAYERS, TOKENS, D, hw0, gradsync=gs,
                              microbatches=2)
    vol = CM.model_volume(LAYERS, TOKENS, D, gradsync=gs, microbatches=2)
    # α=0 conservation: hiding re-buckets time, it does not destroy it
    assert st.exposed_comm + st.hidden_comm == pytest.approx(
        vol * hw0.bytes_per_elem / hw0.link_bw, rel=1e-12)
    # per-layer streams hide even at ONE microbatch (unlike ZeRO-1's
    # cross-microbatch window) — the scan itself is the window
    st1 = CM.predict_step_time(LAYERS, TOKENS, D, gradsync=gs,
                               microbatches=1)
    assert st1.hidden_comm > 0.0
    # blocking collectives never hide
    nr = GradSyncConfig(zero3=True, ring=False)
    stb = CM.predict_step_time(LAYERS, TOKENS, D, hw0, gradsync=nr,
                               microbatches=2)
    assert stb.hidden_comm == 0.0
    assert stb.exposed_comm == pytest.approx(
        vol * hw0.bytes_per_elem / hw0.link_bw, rel=1e-12)


@pytest.mark.parametrize("gs", [
    GradSyncConfig(zero=True),
    GradSyncConfig(zero=True, stream=False),
    GradSyncConfig(bucketed=True),
    GradSyncConfig(zero3=True),
    GradSyncConfig(zero3=True, prefetch=True),
], ids=["zero", "zero_nostream", "bucketed", "zero3", "zero3_prefetch"])
def test_cross_step_reduces_to_pr3_model_when_off(gs):
    """cross_step=False must be EXACTLY the prior exposed model (same
    total, same hideable); cross_step=True moves the terminal passes
    (param gather + last RS) into the hideable bucket without changing
    the total."""
    hw = CM.TPU_V5E
    import dataclasses as dc
    on = dc.replace(gs, cross_step=True)
    for mb in (1, 3):
        t_off, h_off = CM.dp_sync_time(4, 1e6, gs, mb, hw)
        t_on, h_on = CM.dp_sync_time(4, 1e6, on, mb, hw)
        assert t_on == t_off                 # hiding never changes total
        assert h_on > h_off                  # the window opens
        if gs.zero3:
            assert h_on == pytest.approx(t_on)   # everything hideable
        else:
            # exactly the two terminal passes move
            t_pass = t_off / (
                (mb if gs.stream else 1) + 1)
            assert h_on - h_off == pytest.approx(2 * t_pass)


def test_cross_step_off_is_default_and_degenerate():
    # the α=0/no-window degeneracy of PR 3 is untouched by the new knob
    hw = CM.HardwareParams(alpha=0.0)
    gs = GradSyncConfig(zero=True)
    st = CM.predict_step_time(LAYERS, TOKENS, D, hw, gradsync=gs,
                              microbatches=1)
    vol = CM.model_volume(LAYERS, TOKENS, D, gradsync=gs, microbatches=1)
    assert st.hidden_comm == 0.0
    assert st.exposed_comm == pytest.approx(
        vol * hw.bytes_per_elem / hw.link_bw, rel=1e-12)
    # cross_step widens the window under the SAME total
    on = GradSyncConfig(zero=True, cross_step=True)
    st_on = CM.predict_step_time(LAYERS, TOKENS, D, hw, gradsync=on,
                                 microbatches=1)
    assert st_on.hidden_comm > 0.0
    assert st_on.exposed_comm + st_on.hidden_comm == pytest.approx(
        st.exposed_comm, rel=1e-12)


def test_roofline_cross_step_split():
    by_kind = {"collective-permute": 1e9, "all-gather": 2e9,
               "all-reduce": 4e9}
    flops = 1e15  # large compute window: everything hideable fits
    off = RL.step_time_estimate(flops, by_kind)
    on = RL.step_time_estimate(flops, by_kind, cross_step=True)
    assert on.total <= off.total
    assert on.hidden_comm > off.hidden_comm
    # all-reduces stay exposed either way
    hw = CM.TPU_V5E
    assert on.exposed_comm >= 4e9 / hw.link_bw * 0.999
