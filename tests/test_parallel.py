"""Core 4D tensor-parallel primitives vs single-device dense reference:
forward values AND gradients must match exactly (the paper's Fig. 6
statistical-efficiency claim, in unit-test form)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES
from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.compat import default_axis_types, make_mesh, shard_map

K, N, B, S = 16, 24, 8, 8


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(0)
    kx, kw, kw2, kt = jax.random.split(key, 4)
    return {
        "x": jax.random.normal(kx, (B, S, K)),
        "w": jax.random.normal(kw, (K, N)) * 0.1,
        "w2": jax.random.normal(kw2, (N, K)) * 0.1,
        "gamma": jnp.ones((K,)),
        "labels": jax.random.randint(kt, (B, S), 0, N),
    }


def _ref(data):
    def loss(w, w2, gamma, x, labels):
        xf = x.astype(jnp.float32)
        ms = jnp.mean(xf * xf, -1, keepdims=True)
        h = xf * jax.lax.rsqrt(ms + 1e-6) * gamma
        y = h @ w
        y2 = jax.nn.gelu(y) @ w2
        logits = (y2 @ w).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, -1)
        tgt = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return jnp.mean(lse - tgt)
    val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(
        data["w"], data["w2"], data["gamma"], data["x"], data["labels"])
    return val, grads


MESHES = [
    ((2, 2, 2, 1), ("data", "x", "y", "z"),
     dict(data=("data",), x="x", y="y", z="z")),
    ((1, 2, 2, 2), ("data", "x", "y", "z"),
     dict(data=("data",), x="x", y="y", z="z")),
    ((2, 2, 1, 2), ("data", "x", "y", "z"),
     dict(data=("data",), x="x", y="y", z="z")),
    ((1, 2, 2, 1), ("data", "x", "y", "z"),
     dict(data=("data",), x="x", y="y", z="z")),
    ((1, 1, 2, 2), ("data", "x", "y", "z"),
     dict(data=("data",), x="x", y="y", z="z")),
    ((2, 4), ("data", "model"), dict(data=("data",), x="model")),
    ((4, 2), ("data", "model"), dict(data=("data",), y="model")),
    ((2, 2), ("data", "model"), dict(data=("data",), x="model")),
    ((2, 2, 2), ("pod", "data", "model"),
     dict(data=("pod", "data"), y="model")),
    ((2, 2, 1), ("pod", "data", "model"),
     dict(data=("pod", "data"), x="model")),
]
MESHES = [m for m in MESHES if math.prod(m[0]) <= N_DEVICES]


@pytest.mark.parametrize("shape,names,bind", MESHES,
                         ids=[str(m[0]) + str(m[2].get("x")) for m in MESHES])
def test_tp_matches_dense(shape, names, bind, data):
    mesh = make_mesh(shape, names,
                     axis_types=default_axis_types(len(names)))
    axes = M.bind_axes(mesh, **bind)
    ref_val, ref_grads = _ref(data)

    wspec = PP.yz_spec(axes, False)
    w2spec = PP.yz_spec(axes, True)
    gspec = axes.pspec(axes.x)
    bax = axes.batch_axes()
    xspec = axes.pspec(bax, None, axes.x)
    lspec = axes.pspec(bax, None)

    def par_loss(w, w2, gamma, x, labels):
        h = PP.rms_norm(x, gamma, axes, K)
        y = PP.tp_matmul(h, w, axes, "x", "y")
        y2 = PP.tp_matmul(jax.nn.gelu(y), w2, axes, "y", "x")
        logits = PP.tp_matmul(y2, w, axes, "x", "y")
        tot = PP.ar_bwd_identity(
            jnp.sum(PP.vocab_parallel_xent(logits, labels, axes)),
            axes.batch_axes())
        return tot / (B * S)

    def step(w, w2, gamma, x, labels):
        val, grads = jax.value_and_grad(par_loss, argnums=(0, 1, 2))(
            w, w2, gamma, x, labels)
        gw, gw2, gg = grads
        gw = M.psum(gw, axes.data)
        gw2 = M.psum(gw2, axes.data)
        gg = M.psum(M.psum(gg, axes.data), axes.z)
        return val, (gw, gw2, gg)

    f = shard_map(step, mesh=mesh,
                  in_specs=(wspec, w2spec, gspec, xspec, lspec),
                  out_specs=(P(), (wspec, w2spec, gspec)), check_vma=False)
    val, grads = jax.jit(f)(data["w"], data["w2"], data["gamma"], data["x"],
                            data["labels"])
    np.testing.assert_allclose(np.asarray(val), np.asarray(ref_val),
                               rtol=1e-5)
    for g, rg in zip(grads, ref_grads):
        np.testing.assert_allclose(np.asarray(g), np.asarray(rg),
                                   rtol=3e-4, atol=1e-5)


def test_embedding_and_tied_head(mesh4, axes4):
    V, H = 32, 16
    key = jax.random.PRNGKey(1)
    table = jax.random.normal(key, (V, H)) * 0.1
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, V)

    def ref(table):
        h = table[toks]
        logits = h @ table.T
        return jnp.sum(logits ** 2)

    rv, rg = jax.value_and_grad(ref)(table)

    tspec = axes4.pspec(axes4.y, M._names(axes4.x) + M._names(axes4.z))

    def par(table, toks):
        h = PP.embedding_lookup(toks, table, axes4)
        logits = PP.tied_lm_logits(h, table, axes4)
        # logits (B,T,V/y) replicated over x; sum of squares over full V
        # (ar_bwd_identity: raw psum autodiff would double the cotangent)
        loc = jnp.sum(logits.astype(jnp.float32) ** 2)
        return PP.ar_bwd_identity(loc, axes4.y)

    def step(table, toks):
        v, g = jax.value_and_grad(par)(table, toks)
        return v, g

    f = shard_map(step, mesh=mesh4, in_specs=(tspec, P(None, None)),
                  out_specs=(P(), tspec), check_vma=False)
    v, g = jax.jit(f)(table, toks)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), rtol=1e-3,
                               atol=1e-5)


def test_layer_norm_matches(mesh4, axes4):
    D = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, D))
    g0 = jnp.ones((D,)) * 1.3
    b0 = jnp.ones((D,)) * 0.1

    def ref(x, g, b):
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.var(x, -1, keepdims=True)
        return jnp.sum((x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b)

    rv, rgs = jax.value_and_grad(ref, argnums=(0, 1, 2))(x, g0, b0)

    gspec = axes4.pspec(axes4.x)
    xspec = axes4.pspec(axes4.data, None, axes4.x)

    sum_axes = M._names(axes4.data) + M._names(axes4.x)

    def par(x, g, b):
        y = PP.layer_norm(x, g, b, axes4, D)
        return PP.ar_bwd_identity(jnp.sum(y.astype(jnp.float32)), sum_axes)

    def step(x, g, b):
        v, grads = jax.value_and_grad(par, argnums=(0, 1, 2))(x, g, b)
        gx, gg, gb = grads
        return v, (gx, M.psum(gg, axes4.data), M.psum(gb, axes4.data))

    f = shard_map(step, mesh=mesh4, in_specs=(xspec, gspec, gspec),
                  out_specs=(P(), (xspec, gspec, gspec)), check_vma=False)
    v, (gx, gg, gb) = jax.jit(f)(x, g0, b0)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rgs[0]),
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gg), np.asarray(rgs[1]),
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rgs[2]),
                               rtol=1e-3, atol=1e-5)
