"""Substrate tests: optimizer, checkpointing, MoE dispatch, and
overdecomposition equivalence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.compat import shard_map


# --------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------- #

def test_adamw_converges_quadratic():
    from repro.core.mesh import MeshAxes
    from repro.core.partition import Boxed, unbox
    from repro.optim.adamw import AdamWConfig, apply_updates, init_state

    axes = MeshAxes(data=(), x=None, y=None, z=None, sizes=())
    target = jnp.arange(8.0)
    boxed = {"w": Boxed(jnp.zeros(8), P())}
    params, specs = unbox(boxed)
    state = init_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=1,
                      total_steps=200, grad_clip=0)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return apply_updates(params, g, state, specs, axes, cfg)

    for _ in range(150):
        params, state, _ = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.5)


def test_grad_clip_scales():
    from repro.core.partition import Boxed, unbox
    from repro.optim.adamw import AdamWConfig, apply_updates, init_state
    from repro.core.mesh import MeshAxes

    axes = MeshAxes(data=(), x=None, y=None, z=None, sizes=())
    boxed = {"w": Boxed(jnp.zeros(4), P())}
    params, specs = unbox(boxed)
    state = init_state(params)
    big = {"w": jnp.full(4, 100.0)}
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, total_steps=10)
    _, _, m = apply_updates(params, big, state, specs, axes, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# --------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------- #

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore, save
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
            "b": jnp.ones(4, jnp.int32)}
    path = os.path.join(tmp_path, "ck.npz")
    save(path, tree, step=17)
    got, step = restore(path, tree)
    assert step == 17
    np.testing.assert_array_equal(np.asarray(got["a"]["w"]),
                                  np.asarray(tree["a"]["w"]))
    np.testing.assert_array_equal(np.asarray(got["b"]),
                                  np.asarray(tree["b"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    from repro.checkpoint import restore, save
    path = os.path.join(tmp_path, "ck.npz")
    save(path, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore(path, {"w": jnp.ones((3, 2))})


# --------------------------------------------------------------------- #
# MoE: capacity-dispatch conservation vs dense loop oracle
# --------------------------------------------------------------------- #

def test_moe_matches_dense_loop(mesh4, axes4):
    from repro.configs import get_config
    from repro.core.partition import unbox
    from repro.layers import moe as MOE
    import dataclasses

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     n_shared=0))  # no drops, no shared
    key = jax.random.PRNGKey(0)
    boxed = MOE.moe_init(key, cfg, axes4, dtype=jnp.float32)
    params, specs = unbox(boxed)
    B, T = 2, 16
    h = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.3

    # dense oracle on unsharded params
    def oracle(params, h):
        hf = h.reshape(-1, cfg.d_model)
        logits = hf @ params["w_router"]
        gates, idx = MOE._topk_gates(logits.astype(jnp.float32), cfg.moe)
        out = jnp.zeros_like(hf)
        for e in range(cfg.moe.n_experts):
            w_up = params["w_up"][e]
            w_dn = params["w_down"][e]
            u = hf @ w_up
            g, u2 = jnp.split(u, 2, axis=-1)
            eo = (jax.nn.silu(g) * u2) @ w_dn
            for slot in range(cfg.moe.top_k):
                sel = (idx[:, slot] == e).astype(h.dtype)
                out = out + eo * (gates[:, slot] * sel)[:, None]
        return out.reshape(B, T, cfg.d_model)

    want = oracle(params, h)

    from repro.core.partition import spec_tree_to_pspecs
    pspecs = spec_tree_to_pspecs(specs)
    hspec = axes4.pspec(axes4.batch_axes(), None, axes4.x)

    def par(params, h):
        out, aux = MOE.moe_apply(params, cfg, axes4, h)
        return out

    f = shard_map(lambda p, h: MOE.moe_apply(p, h, cfg, axes4)[0],
                  mesh=mesh4, in_specs=(pspecs, hspec), out_specs=hspec,
                  check_vma=False)
    got = jax.jit(f)(params, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-4)


# --------------------------------------------------------------------- #
# overdecomposition (paper §4.2): grads identical to full-batch
# --------------------------------------------------------------------- #

def test_overdecomposition_preserves_gradients():
    from repro.core.overdecompose import overdecomposed_value_and_grad

    w0 = jnp.array([1.0, -2.0, 0.5])
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 3))
    y = jax.random.normal(jax.random.PRNGKey(1), (8,))

    def loss(w, batch):
        pred = batch["x"] @ w
        return jnp.mean((pred - batch["y"]) ** 2)

    v1, g1 = jax.value_and_grad(loss)(w0, {"x": x, "y": y})
    v2, g2 = overdecomposed_value_and_grad(loss, 2)(w0, {"x": x, "y": y})
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


def test_overdecomposed_trainstep_matches(mesh4, axes4):
    """Full train step: overdecompose=2 equals overdecompose=1 (same data)."""
    from conftest import train_smoke
    _, l1 = train_smoke("stablelm-1.6b", mesh4, axes4, steps=2,
                        overdecompose=1, check_decreases=False)
    _, l2 = train_smoke("stablelm-1.6b", mesh4, axes4, steps=2,
                        overdecompose=2, check_decreases=False)
    np.testing.assert_allclose(l1, l2, rtol=1e-4)
