"""Context parallelism (PR 6): striped ring attention over the ``seq``
mesh axis.

Covers the contract at every layer: the Pallas partial-block flash kernel
vs its oracle (including chained blocks, non-dividing lengths and strided
global positions), the striped layout helpers, ``seq_attn`` parity vs the
single-device core for g_seq in {1, 2, 4} under both the blocking-gather
and ring schedules, the HLO guarantee (ring mode lowers the KV exchange
to collective-permute chains with NO all-gather of the full sequence),
end-to-end train-loss parity vs an unsharded decomposition (exercising
the seq-axis gradient reductions), the comm model's ring_exchange
collective class and its g_seq=1 bitwise degeneracy, the satellite ring
embedding gather, and the fp32-softmax dtype pin."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES
from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.compat import shard_map
from repro.core.overlap import OverlapConfig
from repro.kernels import ops
from repro.layers import attention as A
from repro.launch import mesh as LM


def _qkv_bhtd(T, S, hq=4, hkv=2, d=32, seed=0):
    """Kernel-layout (B, H, T, D) tensors."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (1, hq, T, d)),
            jax.random.normal(ks[1], (1, hkv, S, d)),
            jax.random.normal(ks[2], (1, hkv, S, d)))


def _qkv_bthd(T, hq=4, hkv=2, d=16, B=2, seed=0):
    """Layer-layout (B, T, H, D) tensors."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, T, hq, d)),
            jax.random.normal(ks[1], (B, T, hkv, d)),
            jax.random.normal(ks[2], (B, T, hkv, d)))


def _partial_init(B, hq, T, d):
    return (jnp.full((B, hq, T), A.NEG_INF, jnp.float32),
            jnp.zeros((B, hq, T), jnp.float32),
            jnp.zeros((B, hq, T, d), jnp.float32))


def _finalize(acc, l):
    return acc / jnp.maximum(l, 1e-30)[..., None]


# ---------------------------------------------------------------------- #
# Pallas partial-block kernel vs the full flash kernel
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("T", [128, 200])   # 200: non-dividing block pad
def test_partial_kernel_single_block(T):
    q, k, v = _qkv_bhtd(T, T)
    full = ops.flash_attention(q, k, v, causal=True)
    m, l, acc = _partial_init(1, 4, T, 32)
    acc, m, l = ops.flash_attention_partial(q, k, v, m, l, acc,
                                            causal=True)
    err = float(jnp.max(jnp.abs(_finalize(acc, l) - full)))
    assert err < 1e-5, err


def test_partial_kernel_chained_blocks():
    T = 200
    q, k, v = _qkv_bhtd(T, T)
    full = ops.flash_attention(q, k, v, causal=True)
    m, l, acc = _partial_init(1, 4, T, 32)
    s1 = 72  # non-block-aligned split
    acc, m, l = ops.flash_attention_partial(
        q, k[:, :, :s1], v[:, :, :s1], m, l, acc, causal=True, k_pos0=0)
    acc, m, l = ops.flash_attention_partial(
        q, k[:, :, s1:], v[:, :, s1:], m, l, acc, causal=True, k_pos0=s1)
    err = float(jnp.max(jnp.abs(_finalize(acc, l) - full)))
    assert err < 1e-5, err


def test_partial_kernel_strided_positions():
    """Striped context-parallel positions: rank r of p=2 holds global
    positions r, r+2, r+4, ... — the kernel's affine (pos0, stride)
    masks must reproduce dense causal attention on the interleaving."""
    p, C = 2, 64
    T = p * C
    q, k, v = _qkv_bhtd(T, T)
    full = ops.flash_attention(q, k, v, causal=True)
    for r in range(p):
        qr = q[:, :, r::p]
        m, l, acc = _partial_init(1, 4, C, 32)
        for owner in range(p):
            acc, m, l = ops.flash_attention_partial(
                qr, k[:, :, owner::p], v[:, :, owner::p], m, l, acc,
                causal=True, q_pos0=r, q_stride=p, k_pos0=owner,
                k_stride=p)
        err = float(jnp.max(jnp.abs(_finalize(acc, l) - full[:, :, r::p])))
        assert err < 1e-5, (r, err)


def test_partial_oracle_matches_kernel_windowed():
    """The jnp oracle (attn_core_partial, layer layout) and the Pallas
    partial kernel agree on a sliding-window block with vector/affine
    positions respectively."""
    T, W = 96, 37
    q, k, v = _qkv_bhtd(T, T, d=32)
    m, l, acc = _partial_init(1, 4, T, 32)
    acc, m, l = ops.flash_attention_partial(q, k, v, m, l, acc,
                                            causal=True, window=W)
    out_kernel = _finalize(acc, l)
    # oracle works in (B, T, H, D)
    qt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
    pos = jnp.arange(T)
    carry = A.attn_partial_init(1, T, 2, 2, 32)
    carry = A.attn_core_partial(qt, kt, vt, carry, q_pos=pos, k_pos=pos,
                                causal=True, window=W)
    out_oracle = A.attn_partial_finalize(carry, jnp.float32)
    err = float(jnp.max(jnp.abs(jnp.swapaxes(out_kernel, 1, 2)
                                - out_oracle)))
    assert err < 1e-5, err


# ---------------------------------------------------------------------- #
# striped layout helpers
# ---------------------------------------------------------------------- #

def test_stripe_roundtrip_and_layout():
    x = jnp.arange(2 * 12).reshape(2, 12)
    for p in (1, 2, 3, 4, 6):
        assert (M.unstripe_seq(M.stripe_seq(x, p), p) == x).all()
    s = np.asarray(M.stripe_seq(x, 4))
    xn = np.asarray(x)
    C = 12 // 4
    for r in range(4):
        for j in range(C):
            # contiguous shard r holds global positions r, r+p, r+2p, ...
            assert (s[:, r * C + j] == xn[:, j * 4 + r]).all()
    with pytest.raises(ValueError):
        M.stripe_seq(x, 5)


# ---------------------------------------------------------------------- #
# seq_attn parity under shard_map
# ---------------------------------------------------------------------- #

def _seq_mesh(p):
    return LM.make_smoke_mesh((1, 1, 1, 1, p),
                              ("data", "x", "y", "z", "seq"))


def test_seq_attn_gseq1_bitwise():
    """g_seq == 1 must degenerate to the plain core, bit for bit."""
    axes = LM.bind_4d(LM.make_smoke_mesh((1, 1, 2, 1)))
    q, k, v = _qkv_bthd(64)
    out = A.seq_attn(q, k, v, axes, causal=True)
    ref = A.attn_core(q, k, v, causal=True)
    assert (np.asarray(out) == np.asarray(ref)).all()


@pytest.mark.parametrize("p", [2, 4])
@pytest.mark.parametrize("ring", [False, True])
@pytest.mark.parametrize("window", [0, 37])
def test_seq_attn_parity(p, ring, window):
    if p > N_DEVICES:
        pytest.skip(f"needs {p} devices")
    mesh = _seq_mesh(p)
    axes = LM.bind_4d(mesh)
    if ring:
        axes = axes.with_overlap(OverlapConfig(ring_attention=True))
    q, k, v = _qkv_bthd(64)
    ref = A.attn_core(q, k, v, causal=True, window=window)
    qs, ks, vs = (M.stripe_seq(t, p) for t in (q, k, v))
    spec = P(None, "seq", None, None)
    f = shard_map(
        lambda a, b, c: A.seq_attn(a, b, c, axes, causal=True,
                                   window=window),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec)
    out = M.unstripe_seq(f(qs, ks, vs), p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.skipif(N_DEVICES < 4, reason="needs a 4-way seq axis")
def test_seq_attn_hlo_contract():
    """The ring schedule must lower the KV exchange to collective-permute
    chains; the full-sequence all-gather may only appear in blocking
    mode."""
    from repro.launch import roofline as RL
    p = 4
    mesh = _seq_mesh(p)
    q, k, v = _qkv_bthd(64)
    qs, ks, vs = (M.stripe_seq(t, p) for t in (q, k, v))
    spec = P(None, "seq", None, None)
    counts = {}
    for ring in (False, True):
        axes = LM.bind_4d(mesh).with_overlap(
            OverlapConfig(ring_attention=ring))
        f = jax.jit(shard_map(
            lambda a, b, c, ax=axes: A.seq_attn(a, b, c, ax, causal=True),
            mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec))
        hlo = f.lower(qs, ks, vs).compile().as_text()
        counts[ring] = RL.parse_collectives(hlo).counts
    assert counts[False].get("all-gather", 0) > 0, counts
    assert counts[True].get("all-gather", 0) == 0, counts
    assert (counts[True].get("collective-permute", 0)
            >= 2 * (p - 1)), counts  # k and v rings, p-1 hops each


# ---------------------------------------------------------------------- #
# end-to-end: train-loss parity vs an unsharded decomposition
# ---------------------------------------------------------------------- #

def _train_losses(mesh_shape, steps=3, B=4, S=32):
    from repro.configs import get_config
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import steps as ST
    from repro.optim.adamw import AdamWConfig, init_state

    names = ("data", "x", "y", "z", "seq")[:len(mesh_shape)]
    mesh = LM.make_smoke_mesh(mesh_shape, names)
    axes = LM.bind_4d(mesh)
    cfg = get_config("stablelm-1.6b").reduced()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    state = init_state(params)
    fn, _, _ = ST.make_train_step(
        cfg, mesh, axes,
        AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50),
        ST.TrainOptions(dtype=jnp.float32))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    batch = ST.stripe_batch(batch, axes)
    losses = []
    for _ in range(steps):
        params, state, m = fn(params, state, batch)
        losses.append(float(m["loss"]))
    return losses


@pytest.mark.skipif(N_DEVICES < 4, reason="needs 4 devices")
def test_train_loss_parity_seq_vs_unsharded():
    """Same model/data on (y=2) vs (y=2, seq=2): the loss trajectories
    must coincide — this exercises the striped batch/positions, the
    token-axes loss reduction and the seq-axis gradient psum (a missing
    grad reduction diverges by step 2)."""
    base = _train_losses((1, 1, 2, 1))
    seq = _train_losses((1, 1, 2, 1, 2))
    gap = max(abs(a - b) for a, b in zip(base, seq))
    assert gap < 1e-3, (base, seq)


# ---------------------------------------------------------------------- #
# satellite: ring embedding gather (bitwise vs blocking AG_z)
# ---------------------------------------------------------------------- #

@pytest.mark.skipif(N_DEVICES < 8, reason="needs the z=2 mesh")
def test_embed_ring_gather_bitwise(meshz, axesz):
    V, H, B, S = 64, 32, 2, 16
    tokens = jnp.asarray(
        np.random.RandomState(0).randint(0, V, (B, S)), jnp.int32)
    table = jax.random.normal(jax.random.PRNGKey(1), (V, H))
    tspec = axesz.pspec(axesz.y, M._names(axesz.x) + M._names(axesz.z))
    outs = {}
    for ring in (False, True):
        axes = axesz.with_overlap(OverlapConfig(embed_gather=ring))
        f = shard_map(
            lambda t, w, ax=axes: PP.embedding_lookup(t, w, ax),
            mesh=meshz, in_specs=(P(None, None), tspec),
            out_specs=axesz.pspec(None, None, axesz.x),
            check_vma=False)  # custom-vjp lookup defeats the rep checker
        outs[ring] = np.asarray(f(tokens, table))
    assert (outs[False] == outs[True]).all()


# ---------------------------------------------------------------------- #
# satellite: softmax accumulates in fp32 regardless of activation dtype
# ---------------------------------------------------------------------- #

def test_attn_core_softmax_fp32_under_bf16():
    q, k, v = _qkv_bthd(64)
    qb, kb, vb = (t.astype(jnp.bfloat16) for t in (q, k, v))
    # fp32 math on the same rounded inputs: the bf16 path may differ only
    # by the final output-dtype cast (scores/softmax/PV all in fp32)
    ref = A.attn_core(qb.astype(jnp.float32), kb.astype(jnp.float32),
                      vb.astype(jnp.float32), causal=True)
    out = A.attn_core(qb, kb, vb, causal=True)
    assert out.dtype == jnp.bfloat16
    assert (np.asarray(out) == np.asarray(ref.astype(jnp.bfloat16))).all()
    # chunked (online-softmax) path: fp32 carries, tolerance-level parity
    out_c = A.attn_core(qb, kb, vb, causal=True, chunked_threshold=16)
    err = float(jnp.max(jnp.abs(out_c.astype(jnp.float32) - ref)))
    assert err < 8e-3, err  # one bf16 output rounding, not a bf16 softmax


# ---------------------------------------------------------------------- #
# comm model: the ring_exchange collective class
# ---------------------------------------------------------------------- #

def test_comm_model_gseq1_degenerate():
    from repro.configs import get_config
    from repro.core import comm_model as CM
    layers = list(get_config("stablelm-1.6b").reduced().comm_layers())
    d4 = CM.Decomposition(2, 2, 2, 1)
    d5 = CM.Decomposition(2, 2, 2, 1, 1)
    assert CM.model_volume(layers, 4096, d4) == \
        CM.model_volume(layers, 4096, d5)
    assert CM.predict_step_time(layers, 4096, d4).total == \
        CM.predict_step_time(layers, 4096, d5).total


def test_comm_model_ring_exchange_pricing():
    from repro.core import comm_model as CM
    assert CM.ring_exchange_volume(1, 10.0) == 0.0
    assert CM.ring_exchange_volume(4, 10.0) == 30.0  # (p-1) full blocks
    hw = dataclasses.replace(CM.TPU_V5E, alpha=0.0, gamma=0.0)
    t = CM.collective_time("ring_exchange", 4, 10.0, hw)
    assert t == pytest.approx(30.0 * hw.bytes_per_elem / hw.link_bw)
    assert CM.collective_time("ring_exchange", 1, 10.0, hw) == 0.0
    # α charges one hop per ring step: p-1 of them
    hw_a = dataclasses.replace(CM.TPU_V5E, gamma=0.0)
    assert CM.collective_time("ring_exchange", 4, 10.0, hw_a) == \
        pytest.approx(t + 3 * hw_a.alpha)


def test_enumerate_decompositions_seq():
    from repro.core import comm_model as CM
    base = list(CM.enumerate_decompositions(16))
    assert all(d.g_seq == 1 for d in base)  # default stays 4-factor
    cons = CM.Constraints(max_seq=4, seq_divides=(128,))
    ds = list(CM.enumerate_decompositions(16, cons))
    assert {d.g_seq for d in ds} == {1, 2, 4}
    assert all(math.prod((d.g_data, d.g_x, d.g_y, d.g_z, d.g_seq)) == 16
               for d in ds)
    # g_seq stays out of the weight-sharding product
    d = next(d for d in ds if d.g_seq == 4)
    assert d.g_tensor == d.g_x * d.g_y * d.g_z
