"""ZeRO-sharded data-parallel gradient sync (core/gradsync.py).

The bucketed ring schedule must be a pure *decomposition* of the
blocking one: bucketed ring reduce-scatter + ZeRO-1 sharded AdamW +
param all-gather matches the blocking ``psum`` + replicated-AdamW
baseline — bitwise on exactly-summable values (the repo's standard for
ring-vs-blocking claims), within fp32 reassociation on a real model —
and the compiled DP path must contain collective-permute chains with NO
data-axis all-reduce left above scalar size. The α-β time model's DP
term must degenerate to the volume model at α = 0 with no overlap
window. Shapes scale down automatically on 4-device CI hosts.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES
from repro.core import comm_model as CM
from repro.core import gradsync as GS
from repro.core import mesh as M
from repro.core.compat import shard_map
from repro.core.gradsync import GradSyncConfig
from repro.core.overdecompose import split_batch
from repro.core.partition import ParamSpec, spec_tree_to_pspecs, \
    z_reduce_grads
from repro.launch import mesh as LM
from repro.launch import roofline as RL
from repro.launch import steps as ST
from repro.optim import adamw as OPT

# the acceptance mesh: 2 (data) x 2 (tensor); fits 4-device CI hosts
SHAPE_2X2 = (2, 2, 1, 1)
# mixed y/z mesh for the reduction-class coverage
SHAPE_YZ = (2, 1, 2, 2) if N_DEVICES >= 8 else (2, 1, 1, 2)
# dp=4 mesh whose data replica-group size is unambiguous in HLO
SHAPE_DP4 = (4, 1, 2, 1) if N_DEVICES >= 8 else (4, 1, 1, 1)


def _exact_random(key, shape):
    """Random fp32 small-int values: every reduction order is exact."""
    return jax.random.randint(key, shape, -4, 5).astype(jnp.float32)


# --------------------------------------------------------------------- #
# synthetic param/spec trees (optimizer-level tests)
# --------------------------------------------------------------------- #

def _toy_tree(with_yz: bool = False):
    """(global structs, ParamSpec tree) with mixed sharding/decay/class."""
    def leaf(shape, spec, z_reduced=False, y_reduce=False):
        return (jax.ShapeDtypeStruct(shape, jnp.float32),
                ParamSpec(spec, z_reduced, y_reduce))
    tree = {
        "blk": {
            "w_in": leaf((16, 8), P("x", None)),
            "w_out": leaf((8, 16), P(None, "x")),
            "norm_scale": leaf((16,), P()),          # no decay, replicated
            "bias": leaf((24,), P()),                # no decay
        },
        "emb": leaf((32, 4), P(None, None)),
    }
    if with_yz:
        tree["blk"]["w_z"] = leaf((8, 8), P("y", "z"), z_reduced=True)
        tree["blk"]["w_kv"] = leaf((4, 8), P(None, "y"), y_reduce=True)
    structs = jax.tree.map(lambda t: t[0], tree,
                           is_leaf=lambda t: isinstance(t, tuple))
    specs = jax.tree.map(lambda t: t[1], tree,
                         is_leaf=lambda t: isinstance(t, tuple))
    return structs, specs


def _toy_values(structs, seed=0):
    leaves, treedef = jax.tree.flatten(structs)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(
        treedef, [_exact_random(k, l.shape) for k, l in zip(keys, leaves)])


# --------------------------------------------------------------------- #
# plan packing
# --------------------------------------------------------------------- #

def test_plan_packing_and_coverage():
    mesh = LM.make_smoke_mesh(SHAPE_YZ)
    axes = LM.bind_4d(mesh)
    structs, specs = _toy_tree(with_yz=True)
    cap_bytes = 256  # 64 fp32 elements: forces multiple buckets
    plan = GS.make_plan(structs, specs, axes, cap_bytes,
                        no_decay=OPT._no_decay)
    dp = axes.dp
    assert plan.dp == dp
    seen = {}
    for b in plan.buckets:
        assert b.padded % dp == 0 and b.padded >= b.size
        assert len(b.gid) == b.padded
        # greedy cap: only single-leaf buckets may exceed it
        if len(b.segments) > 1:
            assert b.size <= cap_bytes // 4
        off = 0
        for s in b.segments:
            assert s.offset == off  # contiguous layout
            off += s.size
            assert s.leaf not in seen
            seen[s.leaf] = b
        assert off == b.size
    assert len(seen) == plan.n_leaves  # every leaf exactly once
    # class purity: y/z flags match the leaf's ParamSpec
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec))
    for i, ps in enumerate(spec_leaves):
        b = seen[i]
        assert b.z_reduced == ps.z_reduced and b.y_reduce == ps.y_reduce
    # padding slack is bounded by one ring block per bucket
    assert plan.padded_elements - plan.total_elements \
        < len(plan.buckets) * dp
    assert plan.shard_sizes == tuple(b.padded // dp for b in plan.buckets)


def test_plan_decay_and_norm_groups():
    mesh = LM.make_smoke_mesh(SHAPE_2X2)
    axes = LM.bind_4d(mesh)
    structs, specs = _toy_tree()
    plan = GS.make_plan(structs, specs, axes, 1 << 20,
                        no_decay=OPT._no_decay)
    flat, _ = jax.tree_util.tree_flatten_with_path(structs)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec))
    by_leaf = {s.leaf: (b, s) for b in plan.buckets for s in b.segments}
    for i, ((path, _), ps) in enumerate(zip(flat, spec_leaves)):
        b, seg = by_leaf[i]
        gids = set(b.gid[seg.offset:seg.offset + seg.size].tolist())
        assert len(gids) == 1  # one group per leaf
        meta = b.groups[gids.pop()]
        assert meta.decay == (not OPT._no_decay(path))
        names = tuple(n for e in ps.spec if e is not None
                      for n in (e if isinstance(e, tuple) else (e,)))
        assert meta.norm_names == names


def test_flatten_unflatten_roundtrip():
    mesh = LM.make_smoke_mesh(SHAPE_2X2)
    axes = LM.bind_4d(mesh)
    structs, specs = _toy_tree()
    plan = GS.make_plan(structs, specs, axes, 512)
    # local-shaped leaves (shapes from the plan's own segments)
    leaves = [None] * plan.n_leaves
    rng = np.random.RandomState(0)
    for b in plan.buckets:
        for s in b.segments:
            leaves[s.leaf] = jnp.asarray(
                rng.randint(-4, 5, s.shape).astype(np.float32))
    for b in plan.buckets:
        flat = GS.flatten_bucket(leaves, b)
        assert flat.shape == (b.padded,) and flat.dtype == jnp.float32
        for i, arr in GS.unflatten_bucket(flat, b):
            np.testing.assert_array_equal(np.asarray(arr),
                                          np.asarray(leaves[i]))


def test_gradsync_config_validation():
    with pytest.raises(ValueError):
        GradSyncConfig(bucket_mb=0.0)
    assert not GradSyncConfig().enabled
    assert GradSyncConfig(bucketed=True).enabled
    assert GradSyncConfig(zero=True).enabled


# --------------------------------------------------------------------- #
# bucketed sync == blocking psum (bitwise, exact values)
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("ring", [True, False], ids=["ring", "blocking"])
def test_bucketed_sync_matches_psum(ring):
    mesh = LM.make_smoke_mesh(SHAPE_YZ)
    axes = LM.bind_4d(mesh)
    structs, specs = _toy_tree(with_yz=True)
    pspecs = spec_tree_to_pspecs(specs)
    plan = GS.make_plan(structs, specs, axes, 256,
                        no_decay=OPT._no_decay)

    def local_grads(gbase):
        # per-rank partials: data ranks always differ; z/y ranks differ
        # only where the baseline schedule reduces over z/y
        didx = M.axis_index(axes.data).astype(jnp.float32)
        zidx = M.axis_index(axes.z).astype(jnp.float32)
        yidx = M.axis_index(axes.y).astype(jnp.float32)

        def one(g, s):
            f = 1.0 + didx
            if not s.z_reduced:
                f = f + 2.0 * zidx
            if s.y_reduce:
                f = f + 3.0 * yidx
            return g * f
        return jax.tree.map(one, gbase, specs,
                            is_leaf=lambda s: isinstance(s, ParamSpec))

    def baseline(gbase):
        grads = local_grads(gbase)
        grads = jax.tree.map(lambda g: M.psum(g, axes.data), grads)
        return z_reduce_grads(grads, specs, axes, M.psum)

    def bucketed(gbase):
        grads = local_grads(gbase)
        shards = GS.reduce_scatter_grads(grads, plan, axes, ring=ring)
        shards = GS.tensor_reduce_shards(shards, plan, axes)
        return GS.all_gather_grads(shards, plan, axes, ring=ring)

    gbase = _toy_values(structs)
    out_b = jax.jit(shard_map(baseline, mesh=mesh, in_specs=(pspecs,),
                              out_specs=pspecs, check_vma=False))(gbase)
    out_r = jax.jit(shard_map(bucketed, mesh=mesh, in_specs=(pspecs,),
                              out_specs=pspecs, check_vma=False))(gbase)
    for a, b in zip(jax.tree.leaves(out_b), jax.tree.leaves(out_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# ZeRO-1 update == blocking psum + replicated AdamW (bitwise, 2x2 mesh)
# --------------------------------------------------------------------- #

def test_zero_update_bitwise_vs_baseline():
    mesh = LM.make_smoke_mesh(SHAPE_2X2)
    axes = LM.bind_4d(mesh)
    structs, specs = _toy_tree()
    pspecs = spec_tree_to_pspecs(specs)
    plan = GS.make_plan(structs, specs, axes, 256,
                        no_decay=OPT._no_decay)
    cfg = OPT.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
    sspecs = OPT.state_pspecs(pspecs)
    opt_out = jax.tree.map(lambda s: {"m": s, "v": s, "master": s},
                           pspecs,
                           is_leaf=lambda x: isinstance(x, P))

    def grads_of(params, gbase):
        didx = M.axis_index(axes.data).astype(jnp.float32)
        return jax.tree.map(lambda g: g * (1.0 + didx), gbase)

    # both schedules inside ONE program (the repo's standard for bitwise
    # ring-vs-blocking claims: separate jit compilations may fuse FMAs
    # differently, which is a compiler artifact, not a schedule one)
    def both(params, gbase):
        p, s = params, OPT.init_state(params)
        for _ in range(2):  # two steps: step-count/bias-corr coverage
            grads = jax.tree.map(lambda g: M.psum(g, axes.data),
                                 grads_of(p, gbase))
            grads = z_reduce_grads(grads, specs, axes, M.psum)
            p, s, m = OPT.apply_updates(p, grads, s, specs, axes, cfg)
        base = (p, m["grad_norm"], s["opt"])
        p, s = params, GS.init_sharded_state(params, plan, axes)
        for _ in range(2):
            shards = GS.reduce_scatter_grads(grads_of(p, gbase), plan,
                                             axes, ring=True)
            shards = GS.tensor_reduce_shards(shards, plan, axes)
            p, s, m = OPT.apply_updates_sharded(shards, s, plan, axes,
                                                cfg, ring=True)
        zero = (p, m["grad_norm"],
                GS.gather_sharded_state(s, plan, axes)["opt"])
        return base + zero

    params = _toy_values(structs, seed=1)
    gbase = _toy_values(structs, seed=2)
    out_specs = (pspecs, P(), opt_out)
    pb, nb, sb, pz, nz, sz = jax.jit(shard_map(
        both, mesh=mesh, in_specs=(pspecs, pspecs),
        out_specs=out_specs + out_specs, check_vma=False))(params, gbase)
    assert float(nb) == float(nz), "grad norm must match bitwise"
    for a, b in zip(jax.tree.leaves(pb), jax.tree.leaves(pz)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(sb), jax.tree.leaves(sz)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# full train step: parity, HLO shape, memory
# --------------------------------------------------------------------- #

def _model_setup(shape, gs, *, overdecompose=2, arch="stablelm-1.6b"):
    from repro.configs import get_config
    from repro.optim.adamw import AdamWConfig, init_state

    mesh = LM.make_smoke_mesh(shape)
    axes = LM.bind_4d(mesh)
    cfg = get_config(arch).reduced()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    opts = ST.TrainOptions(overdecompose=overdecompose, dtype=jnp.float32,
                           gradsync=gs)
    fn, _, _ = ST.make_train_step(
        cfg, mesh, axes, AdamWConfig(lr=1e-3, warmup_steps=1,
                                     total_steps=50), opts)
    if gs.zero:
        tools = ST.make_gradsync_tools(cfg, mesh, axes, opts)
        state = tools.init(params)
    else:
        tools, state = None, init_state(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32)}
    return cfg, mesh, axes, opts, fn, params, state, batch, tools


ZERO_MODES = [
    ("bucketed", GradSyncConfig(bucketed=True, bucket_mb=0.25)),
    ("zero", GradSyncConfig(zero=True, bucket_mb=0.25)),
    ("zero_noring", GradSyncConfig(zero=True, bucket_mb=0.25, ring=False)),
    ("zero_nostream", GradSyncConfig(zero=True, bucket_mb=0.25,
                                     stream=False)),
]


def test_train_step_parity_all_modes():
    results = {}
    for name, gs in [("base", GradSyncConfig())] + ZERO_MODES:
        _, _, _, _, fn, params, state, batch, _ = _model_setup(
            SHAPE_2X2, gs)
        p, s = params, state
        for _ in range(3):
            p, s, m = fn(p, s, batch)
        results[name] = (float(m["loss"]), float(m["grad_norm"]),
                         [np.asarray(x) for x in jax.tree.leaves(p)])
    lb, nb, pb = results["base"]
    for name, _ in ZERO_MODES:
        l, n, pz = results[name]
        assert abs(l - lb) < 1e-5, (name, l, lb)
        assert abs(n - nb) < 1e-4 * max(1.0, nb), (name, n, nb)
        gap = max(float(np.max(np.abs(a - b))) for a, b in zip(pb, pz))
        assert gap < 5e-6, f"{name}: params diverged from baseline: {gap}"


def test_zero_hlo_collective_permute_no_data_allreduce():
    dp = SHAPE_DP4[0]
    hlos = {}
    for name, gs in [("base", GradSyncConfig()),
                     ("zero", GradSyncConfig(zero=True, bucket_mb=0.25))]:
        _, _, _, _, fn, params, state, batch, _ = _model_setup(
            SHAPE_DP4, gs)
        hlos[name] = fn.lower(params, state, batch).compile().as_text()
    ops = {k: RL.parse_collective_ops(h) for k, h in hlos.items()}

    def big_dp_ar(k):
        return sum(1 for op in ops[k] if op.kind == "all-reduce"
                   and op.group_size == dp and op.raw_bytes > 2048)

    def permutes(k):
        return sum(1 for op in ops[k] if op.kind == "collective-permute")

    assert big_dp_ar("base") > 0          # the blocking path psums per leaf
    assert big_dp_ar("zero") == 0, \
        "DP gradient all-reduces survived the ZeRO ring schedule"
    assert permutes("zero") > permutes("base"), \
        "DP rings must lower to collective-permute chains"


def test_zero_state_memory_sharded_by_dp():
    gs = GradSyncConfig(zero=True, bucket_mb=0.25)
    cfg, mesh, axes, opts, _, params, state, _, tools = _model_setup(
        SHAPE_DP4, gs)
    plan = tools.plan
    per_rank = sum(plan.shard_sizes)  # fp32 elements per m/v/master each
    # each rank holds ~1/dp of the fp32 state (+ bounded padding slack)
    assert per_rank * plan.dp <= plan.total_elements \
        + len(plan.buckets) * plan.dp
    # plan covers every param element exactly once, at its local size
    structs, mspecs = ST.init_model(cfg, axes.with_overlap(opts.overlap),
                                    abstract=True, dtype=opts.dtype)
    spec_leaves = jax.tree.leaves(
        mspecs, is_leaf=lambda s: isinstance(s, ParamSpec))
    expect = sum(
        int(np.prod(GS._local_shape(tuple(l.shape), tuple(s.spec), axes))
            or 1)
        for l, s in zip(jax.tree.leaves(structs), spec_leaves))
    assert plan.total_elements == expect
    # abstract state (dry-run) matches the real init's global shapes
    astate = ST.abstract_opt_state(cfg, axes, opts)
    real = jax.tree.map(lambda x: (x.shape, str(x.dtype)), state)
    abst = jax.tree.map(lambda x: (x.shape, str(x.dtype)), astate)
    assert real == abst


# --------------------------------------------------------------------- #
# checkpoint round-trip across different g_data
# --------------------------------------------------------------------- #

def _toy_tools(mesh, axes, structs, specs, plan):
    """shard_map'd init/gather/scatter for the synthetic tree (what
    launch.steps.make_gradsync_tools builds for a real model)."""
    pspecs = spec_tree_to_pspecs(specs)
    sspecs = GS.sharded_state_pspecs(plan, axes)
    fullspecs = OPT.state_pspecs(pspecs)
    init = jax.jit(shard_map(
        lambda p: GS.init_sharded_state(p, plan, axes), mesh=mesh,
        in_specs=(pspecs,), out_specs=sspecs, check_vma=False))
    gather = jax.jit(shard_map(
        lambda s: GS.gather_sharded_state(s, plan, axes), mesh=mesh,
        in_specs=(sspecs,), out_specs=fullspecs, check_vma=False))
    scatter = jax.jit(shard_map(
        lambda s: GS.scatter_full_state(s, plan, axes), mesh=mesh,
        in_specs=(fullspecs,), out_specs=sspecs, check_vma=False))
    return init, gather, scatter, pspecs, sspecs, fullspecs


def test_checkpoint_roundtrip_across_gdata(tmp_path):
    """Save ZeRO state under g_data=2, restore under g_data=4, and
    bitwise-compare the resumed step against staying on the source mesh
    (exact-valued grads; per-rank partials scale 1/dp so the *global*
    gradient is mesh-independent)."""
    from repro.checkpoint import ckpt

    structs, specs = _toy_tree()
    cfg = OPT.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
    path = os.path.join(tmp_path, "zero.npz")
    meshes = {"A": LM.make_smoke_mesh(SHAPE_2X2),
              "B": LM.make_smoke_mesh((4, 1, 1, 1))}
    env = {}
    for k, mesh in meshes.items():
        axes = LM.bind_4d(mesh)
        plan = GS.make_plan(structs, specs, axes, 256,
                            no_decay=OPT._no_decay)
        env[k] = (mesh, axes, plan) + _toy_tools(mesh, axes, structs,
                                                 specs, plan)

    def step_fn(mesh, axes, plan, pspecs, sspecs):
        def body(params, state, gbase):
            dp = float(axes.dp)
            grads = jax.tree.map(lambda g: g * (1.0 / dp), gbase)
            shards = GS.reduce_scatter_grads(grads, plan, axes)
            shards = GS.tensor_reduce_shards(shards, plan, axes)
            return OPT.apply_updates_sharded(shards, state, plan, axes,
                                             cfg)[:2]
        return jax.jit(shard_map(body, mesh=mesh,
                                 in_specs=(pspecs, sspecs, pspecs),
                                 out_specs=(pspecs, sspecs),
                                 check_vma=False))

    params = _toy_values(structs, seed=1)
    gbase = _toy_values(structs, seed=2)

    # source mesh A: init, one step, save
    mesh, axes, plan, init, gather, scatter, pspecs, sspecs, fullspecs = \
        env["A"]
    step_a = step_fn(mesh, axes, plan, pspecs, sspecs)
    pa, sa = step_a(params, init(params), gbase)
    ckpt.save_sharded(path, jax.tree.map(np.asarray, pa), sa, gather,
                      step=1, extra={"dp_bucket_mb": 0.25 / 1024})
    # continue on A: the reference trajectory
    pa2, sa2 = step_a(pa, sa, gbase)
    ref_full = jax.device_get(gather(sa2))

    # restore on mesh B (different g_data), resume one step
    mesh, axes, plan, init, gather, scatter, pspecs, sspecs, fullspecs = \
        env["B"]
    like_state = {"opt": jax.tree.map(
        lambda s: {"m": jax.ShapeDtypeStruct(s.shape, jnp.float32),
                   "v": jax.ShapeDtypeStruct(s.shape, jnp.float32),
                   "master": jax.ShapeDtypeStruct(s.shape, jnp.float32)},
        structs), "step": jax.ShapeDtypeStruct((), jnp.int32)}
    pb, sb, step = ckpt.restore_sharded(path, structs, like_state, scatter)
    assert step == 1
    # round trip is lossless: gather(scatter(full)) == full
    rt_full = jax.device_get(gather(sb))
    saved_full, _ = ckpt.restore(path, like_state, root="opt_state")
    for a, b in zip(jax.tree.leaves(rt_full), jax.tree.leaves(saved_full)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pb2, sb2 = step_fn(mesh, axes, plan, pspecs, sspecs)(
        jax.tree.map(jnp.asarray, pb), sb, gbase)
    res_full = jax.device_get(gather(sb2))
    # the resumed step matches the uninterrupted run bitwise
    for a, b in zip(jax.tree.leaves(pa2), jax.tree.leaves(pb2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ref_full), jax.tree.leaves(res_full)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# time/volume model: DP term + degeneracy + hiding
# --------------------------------------------------------------------- #

LAYERS = CM.transformer_layers(256, 2)
D = CM.Decomposition(4, 2, 2, 2)
TOKENS = 4096
GS_CFGS = [None,
           GradSyncConfig(bucketed=True),
           GradSyncConfig(zero=True),
           GradSyncConfig(zero=True, stream=False)]


def test_dp_sync_volume_formulas():
    buf = 120.0
    # blocking == bandwidth-optimal all-reduce
    assert CM.dp_sync_volume(4, buf) == CM.allreduce_volume(4, buf)
    # one microbatch: RS + AG == the all-reduce volume exactly
    gs = GradSyncConfig(zero=True)
    assert CM.dp_sync_volume(4, buf, gs, 1) == \
        pytest.approx(CM.allreduce_volume(4, buf))
    # streamed: one RS per microbatch + one AG
    assert CM.dp_sync_volume(4, buf, gs, 3) == \
        pytest.approx(4 * CM.gather_or_scatter_volume(4, buf))
    # stream off: volume is microbatch-independent
    ns = GradSyncConfig(zero=True, stream=False)
    assert CM.dp_sync_volume(4, buf, ns, 3) == \
        pytest.approx(CM.allreduce_volume(4, buf))
    assert CM.dp_sync_volume(1, buf, gs, 3) == 0.0


@pytest.mark.parametrize("gs", GS_CFGS, ids=lambda g: (
    "none" if g is None else
    f"{'zero' if g.zero else 'bucketed'}{'_nostream' if not g.stream else ''}"))
def test_dp_time_model_degenerates_to_volume(gs):
    """α=0 + no overlap window (one microbatch / stream off): exposed
    comm == model volume / bandwidth, exactly — the acceptance pin for
    the new bucketed DP path."""
    hw = CM.HardwareParams(alpha=0.0)
    for mb in ([1] if gs is None or gs.stream else [1, 4]):
        st = CM.predict_step_time(LAYERS, TOKENS, D, hw, gradsync=gs,
                                  microbatches=mb)
        vol = CM.model_volume(LAYERS, TOKENS, D, gradsync=gs,
                              microbatches=mb)
        assert st.hidden_comm == 0.0
        assert st.exposed_comm == pytest.approx(
            vol * hw.bytes_per_elem / hw.link_bw, rel=1e-12)


def test_dp_streaming_hides_under_microbatch_window():
    gs = GradSyncConfig(zero=True)
    st1 = CM.predict_step_time(LAYERS, TOKENS, D, gradsync=gs,
                               microbatches=1)
    st2 = CM.predict_step_time(LAYERS, TOKENS, D, gradsync=gs,
                               microbatches=2)
    assert st1.hidden_comm == 0.0      # nothing to ride under
    assert st2.hidden_comm > 0.0       # mb 0's RS hides under mb 1's bwd
    # conservation: hiding re-buckets time, it does not destroy it
    hw0 = CM.HardwareParams(overlap_efficiency=0.0)
    st2_exposed = CM.predict_step_time(LAYERS, TOKENS, D, hw0, gradsync=gs,
                                       microbatches=2)
    assert st2.exposed_comm + st2.hidden_comm == pytest.approx(
        st2_exposed.exposed_comm, rel=1e-12)
    # the blocking DP path never hides (it runs after the loop)
    stb = CM.predict_step_time(LAYERS, TOKENS, D, gradsync=None,
                               microbatches=2)
    assert stb.hidden_comm == 0.0


def test_dp_bucket_count_is_latency_knob():
    hw = CM.HardwareParams(alpha=1e-5)
    big = GradSyncConfig(zero=True, bucket_mb=64.0)
    small = GradSyncConfig(zero=True, bucket_mb=0.0625)
    t_big, _ = CM.dp_sync_time(4, 1e6, big, 1, hw)
    t_small, _ = CM.dp_sync_time(4, 1e6, small, 1, hw)
    assert t_small > t_big  # more rings, more α
    # α=0: bucket count is invisible (pure bandwidth)
    hw0 = CM.HardwareParams(alpha=0.0)
    assert CM.dp_sync_time(4, 1e6, big, 1, hw0)[0] == \
        pytest.approx(CM.dp_sync_time(4, 1e6, small, 1, hw0)[0])


# --------------------------------------------------------------------- #
# satellites: fp32 microbatch accumulation; split_batch errors
# --------------------------------------------------------------------- #

def test_overdecompose_fp32_accumulation_parity():
    """overdecompose=2 must track the single-batch (=1) trajectory to
    fp32-reassociation precision now that microbatch grads accumulate in
    fp32."""
    losses = {}
    for od in (1, 2):
        _, _, _, _, fn, params, state, batch, _ = _model_setup(
            SHAPE_2X2, GradSyncConfig(), overdecompose=od)
        p, s = params, state
        for _ in range(3):
            p, s, m = fn(p, s, batch)
        losses[od] = float(m["loss"])
    assert abs(losses[1] - losses[2]) < 1e-5, losses


def test_split_batch_error_is_clear():
    batch = {"tokens": jnp.zeros((3, 4), jnp.int32)}
    with pytest.raises(ValueError, match="per-shard batch 3.*not "
                                         "divisible by the "
                                         "overdecomposition factor"):
        split_batch(batch, 2)
    mesh = LM.make_smoke_mesh(SHAPE_2X2)
    axes = LM.bind_4d(mesh)
    with pytest.raises(ValueError, match="global batch must be divisible "
                                         "by batch_shards"):
        split_batch(batch, 2, axes=axes)
    with pytest.raises(ValueError, match="scalar"):
        split_batch({"pos": jnp.zeros((), jnp.int32)}, 2)
