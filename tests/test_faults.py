"""Fault tolerance: hardened checkpoints, chaos injection, the elastic
mesh lifecycle, per-collective probes, and the train-loop recovery path.

The acceptance claims under test:

* a corrupt/truncated checkpoint is refused with an error NAMING the
  offending leaf (zip-CRC layer and our own checksum layer separately);
* ``MeshLifecycle.reshard`` after a simulated rank loss is bitwise-equal
  to a ``save_sharded``/``restore_sharded`` round trip on the shrunk
  mesh — the online elastic path IS the checkpoint path;
* generation 0 of a lifecycle builds the byte-identical mesh (and hence
  byte-identical HLO) of the fixed ``make_smoke_mesh`` it replaced;
* the watchdog blames a hung collective class, not slow compute, when a
  stall is injected into that class's probe window;
* the train CLI survives ``--chaos`` rank loss + checkpoint corruption
  end to end (subprocess), and SIGTERM lands a final verified
  checkpoint.
"""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES
from test_gradsync import _toy_tree

from repro.checkpoint import ckpt
from repro.checkpoint.ckpt import CheckpointError
from repro.core import faultinject as FI
from repro.core import gradsync as GS
from repro.core.compat import shard_map
from repro.launch import mesh as LM

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"a": {"w": rng.randn(64, 32).astype(np.float32)},
            "b": rng.randn(128).astype(np.float32),
            "scale": np.float32(rng.randn())}


# --------------------------------------------------------------------- #
# hardened checkpoint container
# --------------------------------------------------------------------- #

def test_ckpt_atomic_write_roundtrip_and_verify(tmp_path):
    path = str(tmp_path / "ck.npz")
    t = _tree()
    ckpt.save(path, t, step=7)
    # atomic rename left no temp debris
    assert [f for f in os.listdir(tmp_path) if ".tmp-" in f] == []
    got, step = ckpt.restore(path, jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype),
        t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    info = ckpt.verify(path)
    assert info == {"step": 7, "leaves": 3, "checksummed": True}


def test_ckpt_truncated_raises_container_error(tmp_path):
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, _tree())
    FI.corrupt_checkpoint(path, mode="truncate")
    with pytest.raises(CheckpointError,
                       match="unreadable .truncated or corrupt container"):
        ckpt.restore(path, _tree())
    with pytest.raises(CheckpointError):
        ckpt.verify(path)


def test_ckpt_bitflip_names_offending_leaf(tmp_path):
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, _tree())
    FI.corrupt_checkpoint(path, leaf="params/a/w")
    with pytest.raises(CheckpointError,
                       match=r"leaf 'params/a/w' is corrupt"):
        ckpt.restore(path, _tree())
    with pytest.raises(CheckpointError, match=r"params/a/w"):
        ckpt.verify(path)
    # the untouched sibling leaf is still readable on its own
    data, meta = ckpt._open(path)
    np.testing.assert_array_equal(
        ckpt._read_leaf(data, meta, "params/b"), _tree()["b"])


def test_ckpt_checksum_layer_catches_valid_zip(tmp_path, monkeypatch):
    """A file whose zip container is intact but whose recorded checksums
    disagree (e.g. silent media corruption caught by neither layer below
    us) must fail OUR verification, naming the leaf."""
    path = str(tmp_path / "ck.npz")
    monkeypatch.setattr(ckpt, "_crc", lambda arr: 12345)
    ckpt.save(path, _tree())
    monkeypatch.undo()
    with pytest.raises(CheckpointError,
                       match=r"failed checksum verification "
                             r".recorded 0x00003039"):
        ckpt.restore(path, _tree())


def test_ckpt_legacy_without_checksums_still_restores(tmp_path):
    path = str(tmp_path / "ck.npz")
    legacy = str(tmp_path / "legacy.npz")
    ckpt.save(path, _tree(), step=3)
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    del meta["checksums"]
    arrays = {k: data[k] for k in data.files if k != "__meta__"}
    with open(legacy, "wb") as fh:
        np.savez(fh, __meta__=json.dumps(meta), **arrays)
    got, step = ckpt.restore(legacy, _tree())
    assert step == 3
    np.testing.assert_array_equal(got["a"]["w"], _tree()["a"]["w"])
    assert ckpt.verify(legacy)["checksummed"] is False


def test_ckpt_missing_leaf_is_keyerror(tmp_path):
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, {"a": np.zeros(4, np.float32)})
    with pytest.raises(KeyError, match="checkpoint missing leaf"):
        ckpt.restore(path, {"a": np.zeros(4, np.float32),
                            "extra": np.zeros(2, np.float32)})


# --------------------------------------------------------------------- #
# chaos spec parsing + deterministic injection
# --------------------------------------------------------------------- #

def test_chaos_parse_and_fire_once():
    inj = FI.parse_chaos("seed=3;rank_loss@5:n=2,via=ckpt;"
                         "ckpt_corrupt@4;timeout@7:class=z_ring,secs=0.5")
    assert inj.seed == 3
    assert [e.kind for e in inj.events] == ["ckpt_corrupt", "rank_loss",
                                            "timeout"]
    evs = inj.events_at(5)
    assert len(evs) == 1 and evs[0].get("n") == "2"
    assert inj.events_at(5) == []   # fires once, even on step retry
    assert inj.probe_delay(7, "z_ring") == 0.5
    assert inj.probe_delay(7, "xy_ar") == 0.0
    assert inj.step_stall(7) == 0.5
    assert inj.summary()["fired"] == 1


@pytest.mark.parametrize("bad", ["bogus@3", "rank_loss=5",
                                 "timeout@2:oops"])
def test_chaos_bad_tokens_raise(bad):
    with pytest.raises(ValueError, match="chaos token"):
        FI.parse_chaos(bad)


def test_chaos_corruption_is_deterministic(tmp_path):
    a, b, c = (str(tmp_path / f"{n}.npz") for n in "abc")
    for p in (a, b, c):
        ckpt.save(p, _tree())
    da = FI.corrupt_checkpoint(a, seed=0, step=4)
    db = FI.corrupt_checkpoint(b, seed=0, step=4)
    dc = FI.corrupt_checkpoint(c, seed=1, step=4)
    assert da == db
    assert open(a, "rb").read() == open(b, "rb").read()
    assert open(a, "rb").read() != open(c, "rb").read()


# --------------------------------------------------------------------- #
# mesh lifecycle
# --------------------------------------------------------------------- #

def test_lifecycle_gen0_is_byte_identical_to_smoke_mesh():
    """Swapping the fixed mesh factory for a lifecycle must change no
    HLO while the pool is intact (the chaos-off acceptance bar)."""
    shape = (2, 2, 2, 1) if N_DEVICES >= 8 else (1, 2, 2, 1)
    ref = LM.make_smoke_mesh(shape)
    life = LM.MeshLifecycle(*shape)
    mesh, axes = life.build()
    assert life.state == "active" and life.generation == 1
    assert [d.id for d in np.ravel(mesh.devices)] == \
        [d.id for d in np.ravel(ref.devices)]

    def prog(v):
        import repro.core.mesh as M
        return M.psum(v * 2.0, "x")
    x = np.ones((4, 4), np.float32)
    texts = [jax.jit(shard_map(prog, mesh=m, in_specs=(P("x", None),),
                               out_specs=P("x", None), check_vma=False)
                     ).lower(x).as_text() for m in (ref, mesh)]
    assert texts[0] == texts[1]


def test_lifecycle_failure_replan_and_recovery():
    # pin the pool to exactly 4 devices so one loss leaves a deficit
    life = LM.MeshLifecycle(2, 2, 1, 1, devices=jax.devices()[:4])
    life.build()
    lost = life.mark_failed(1)
    assert life.state == "degraded" and len(lost) == 1
    with pytest.raises(RuntimeError, match="needs 4 devices; only 3"):
        life.build()
    # largest g_data that fits 3 survivors with tensor=2 is 1
    assert life.replan()["g_data"] == 1
    assert life.replan(global_batch=8, overdecompose=2)["g_data"] == 1
    with pytest.raises(RuntimeError, match="no g_data"):
        life.replan(global_batch=7, overdecompose=2)
    # losing everything but one device cannot hold a 2-wide replica
    life.mark_failed(ids=[d.id for d in life.surviving[1:]])
    with pytest.raises(RuntimeError, match="cannot hold one model"):
        life.replan()
    life.mark_recovered()
    assert life.failed_ids == ()
    mesh, _ = life.build()
    assert mesh.devices.size == 4
    life.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        life.build()
    assert [e["event"] for e in life.log] == [
        "build", "mark_failed", "mark_failed", "mark_recovered", "build",
        "stop"]


def test_plan_fingerprint_invariant_across_gdata():
    """The bucket-plan fingerprint must ignore dp-dependent padding (so
    elastic restores across g_data pass) but catch real partitioning
    changes (bucket size)."""
    structs, specs = _toy_tree()
    from repro.optim import adamw as OPT
    # elastic re-shards only ever change g_data; the tensor factors (and
    # hence the per-leaf segment sizes) stay fixed
    shapes = ([(2, 2, 1, 1), (4, 2, 1, 1)] if N_DEVICES >= 8
              else [(2, 2, 1, 1), (1, 2, 1, 1)])
    fps = []
    for shape in shapes:
        axes = LM.bind_4d(LM.make_smoke_mesh(shape))
        plan = GS.make_plan(structs, specs, axes, 256,
                            no_decay=OPT._no_decay)
        fps.append(GS.plan_fingerprint(plan))
    assert fps[0] == fps[1]
    axes = LM.bind_4d(LM.make_smoke_mesh(shapes[0]))
    other = GS.make_plan(structs, specs, axes, 512,
                         no_decay=OPT._no_decay)
    assert GS.plan_fingerprint(other) != fps[0]


# --------------------------------------------------------------------- #
# online elastic re-shard == checkpoint restore (the tentpole claim)
# --------------------------------------------------------------------- #

def test_elastic_reshard_bitwise_equals_ckpt_restore(tmp_path):
    """Lose half the mesh mid-run; the state re-sharded online through
    ``MeshLifecycle.reshard`` must be bitwise-identical to restoring the
    checkpoint on the shrunk mesh, and training must continue with a
    finite loss."""
    from repro.configs import get_config
    from repro.core.gradsync import GradSyncConfig
    from repro.core.partition import spec_tree_to_pspecs
    from repro.launch import steps as ST
    from repro.optim import adamw as OPT

    shape = (2, 2, 2, 1) if N_DEVICES >= 8 else (2, 2, 1, 1)
    lose = shape[0] * shape[1] * shape[2] * shape[3] // 2
    B, S = 8, 32
    cfg = get_config("qwen3-1.7b").reduced()
    topts = ST.TrainOptions(overdecompose=2, dtype=jnp.float32,
                            gradsync=GradSyncConfig(zero=True,
                                                    bucket_mb=0.25))
    life = LM.MeshLifecycle(*shape)
    mesh, axes = life.build()
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    tools = ST.make_gradsync_tools(cfg, mesh, axes, topts)
    state = tools.init(params)
    opt = OPT.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step_fn, _, _ = ST.make_train_step(cfg, mesh, axes, opt, topts)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    for _ in range(2):
        params, state, metrics = step_fn(params, state, batch)

    snap = ST.snapshot_state(params, state, tools, topts, step=1)
    path = str(tmp_path / "elastic.npz")
    ckpt.save_sharded(path, jax.tree.map(np.asarray,
                                         jax.device_get(params)),
                      state, tools.gather, step=1)

    life.mark_failed(lose)
    es = life.reshard(cfg, topts, snap, global_batch=B)
    assert life.generation == 2
    assert es.mesh.devices.size == int(np.prod(shape)) - lose
    assert es.axes.dp == shape[0] // 2

    # reference: the checkpoint path on the SAME shrunk mesh
    structs, _ = ST.init_model(cfg, es.axes, abstract=True,
                               dtype=jnp.float32)
    like_state = OPT.init_state(structs, abstract=True)
    p_ref, s_ref, stp = ckpt.restore_sharded(path, structs, like_state,
                                             es.tools.scatter)
    assert stp == 1
    for a, b in zip(jax.tree.leaves(es.params), jax.tree.leaves(p_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    full_on = jax.device_get(es.tools.gather(es.opt_state))
    full_ck = jax.device_get(es.tools.gather(s_ref))
    for a, b in zip(jax.tree.leaves(full_on), jax.tree.leaves(full_ck)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # a mismatched bucket-plan fingerprint must be refused loudly
    with pytest.raises(ValueError, match="bucket-plan fingerprint"):
        ST.restore_state(dict(snap, fingerprint="0123456789abcdef"),
                         cfg, es.mesh, es.axes, es.tools, topts)

    # training continues on the survivors
    step2, _, _ = ST.make_train_step(cfg, es.mesh, es.axes, opt, topts)
    _, _, m2 = step2(es.params, es.opt_state, batch)
    assert np.isfinite(float(m2["loss"]))


# --------------------------------------------------------------------- #
# per-collective probes + watchdog
# --------------------------------------------------------------------- #

def test_probes_monitor_and_merge(mesh4, axes4):
    from repro.core import calibrate as CB
    from repro.launch import probes as PRB
    pr = PRB.CollectiveProbes(mesh4, axes4)
    assert "xy_ar" in pr.classes          # x is 2-wide on every CI host
    for cls in pr.classes:
        assert pr.meta[cls]["p"] > 1
    pr.run(0)
    results = pr.run(1)
    for cls, r in results.items():
        assert r.measured_s > 0 and r.predicted_s > 0
        assert r.injected_s == 0.0
    recs = pr.records()
    assert {r["workload"] for r in recs} == \
        {f"collective:{c}" for c in pr.classes}
    prof = CB.CalibrationProfile(
        backend="cpu", n_devices=N_DEVICES, mesh_shape=(1, 2, 2, 1),
        alpha=4e-4, gamma=1e-3, link_bw=2e8, flops=2.4e11,
        overlap_efficiency=0.25)
    merged = pr.merge_into(prof)
    for cls in pr.classes:
        assert f"drift:collective:{cls}" in merged.probes


def test_watchdog_blames_hung_collective(mesh4, axes4):
    from repro.launch import probes as PRB
    cls = PRB.CollectiveProbes(mesh4, axes4).classes[0]
    inj = FI.parse_chaos(f"timeout@5:class={cls},secs=0.3")
    pr = PRB.CollectiveProbes(mesh4, axes4, injector=inj)
    wd = PRB.Watchdog(pr, factor=3.0, min_steps=3)
    for _ in range(4):
        wd.observe(0.1)
    assert not wd.stalled(0.12)
    assert wd.stalled(1.0)
    pr.run(3)
    pr.run(4)          # build the self-baseline history, injection-free
    v5 = wd.classify(5)
    assert v5["verdict"] == "hung_collective"
    assert v5["suspects"] == [cls]
    assert v5["results"][cls].injected_s == 0.3
    v6 = wd.classify(6)
    assert v6["verdict"] == "slow_compute" and v6["suspects"] == []


def test_watchdog_without_probes_defaults_to_compute():
    from repro.launch import probes as PRB
    wd = PRB.Watchdog(None, min_steps=2)
    assert not wd.stalled(99.0)       # no baseline yet
    wd.observe(0.1)
    wd.observe(0.1)
    assert wd.classify()["verdict"] == "slow_compute"


# --------------------------------------------------------------------- #
# train CLI end to end (subprocess)
# --------------------------------------------------------------------- #

def _train_cmd(tmp, *extra):
    return [sys.executable, "-m", "repro.launch.train",
            "--arch", "qwen3-1.7b", "--preset", "smoke",
            "--batch", "8", "--seq", "32", "--dp-bucket-mb", "0.25",
            "--zero", "--log-every", "1",
            "--telemetry", "--log-file", os.path.join(tmp, "t.jsonl"),
            *extra]


def _train_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


@pytest.mark.skipif(N_DEVICES < 8, reason="chaos smoke shrinks 8 -> 4")
def test_train_cli_chaos_rank_loss_recovers(tmp_path):
    """Corrupt the checkpoint, then drop half the ranks at the same step:
    the run must detect the corruption (naming the leaf), fall back to
    the in-memory snapshot, re-shard online, and finish with a finite
    loss and a contiguous step sequence."""
    tmp = str(tmp_path)
    ck = os.path.join(tmp, "ck.npz")
    cmd = _train_cmd(
        tmp, "--steps", "8", "--mesh", "2,2,2,1",
        "--ckpt", ck, "--ckpt-every", "2",
        "--chaos", "seed=0;ckpt_corrupt@5;rank_loss@5:n=4,via=ckpt")
    out = subprocess.run(cmd, cwd=ROOT, env=_train_env(),
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "chaos: ckpt_corrupt@5: flipped byte" in out.stdout
    assert "checkpoint unusable" in out.stdout
    assert "failed checksum verification" in out.stdout \
        or "is corrupt" in out.stdout
    assert "resharded: generation 2" in out.stdout

    losses = {}
    for line in out.stdout.splitlines():
        if line.startswith("step "):
            parts = line.split()
            losses[int(parts[1])] = float(parts[3])
    assert sorted(losses) == list(range(8))       # contiguous, no gap
    assert all(np.isfinite(v) for v in losses.values())
    # loss continuity across the recovery boundary (state resumed from
    # the step-4 snapshot, so step 5 continues the same trajectory)
    assert abs(losses[5] - losses[4]) < 0.5

    from repro.launch import telemetry as TL
    tfile = os.path.join(tmp, "t.jsonl")
    assert TL.validate_file(tfile) > 0
    events = [json.loads(l)["event"] for l in open(tfile)
              if '"kind": "event"' in l]
    for ev in ("ckpt_corrupt", "rank_loss", "ckpt_unusable", "resharded"):
        assert ev in events
    # the post-recovery final checkpoint verifies clean
    assert ckpt.verify(ck)["step"] == 7


def test_train_cli_sigterm_graceful_checkpoint(tmp_path):
    tmp = str(tmp_path)
    ck = os.path.join(tmp, "ck.npz")
    mesh = "2,2,2,1" if N_DEVICES >= 8 else "1,2,2,1"
    cmd = _train_cmd(tmp, "--steps", "5000", "--mesh", mesh,
                     "--ckpt", ck)
    proc = subprocess.Popen(cmd, cwd=ROOT, env=_train_env(),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    try:
        deadline = time.time() + 420
        seen = 0
        for line in proc.stdout:
            if line.startswith("step ") and time.time() < deadline:
                seen += 1
                if seen >= 3:
                    break
        assert seen >= 3, "training never produced steps"
        proc.send_signal(signal.SIGTERM)
        rest = proc.stdout.read()
        rc = proc.wait(timeout=180)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert rc == 0
    assert "caught SIGTERM: shutting down" in rest
    assert f"saved {ck}" in rest
    info = ckpt.verify(ck)
    assert info["checksummed"] and info["step"] >= 2
    events = [json.loads(l)["event"]
              for l in open(os.path.join(tmp, "t.jsonl"))
              if '"kind": "event"' in l]
    assert "shutdown" in events
