"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in kernels/ref.py (interpret=True executes the kernel bodies on
CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 512),
                                   (100, 70, 130), (64, 1024, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_matmul(m, k, n, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = np.asarray(ops.matmul(a, b, bm=64, bn=64, bk=128))
    want = np.asarray(ref.block_matmul_ref(a, b))
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), **_tol(dtype))


@pytest.mark.parametrize("t,s,hq,hkv,d", [(128, 128, 4, 4, 64),
                                          (256, 256, 8, 2, 32),
                                          (100, 100, 4, 1, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(t, s, hq, hkv, d, causal, window, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (2, hq, t, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, hkv, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, hkv, s, d), dtype)
    out = np.asarray(ops.flash_attention(q, k, v, causal=causal,
                                         window=window, bq=64, bk=64))
    want = np.asarray(ref.flash_attention_ref(q, k, v, causal=causal,
                                              window=window))
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), **_tol(dtype))


@pytest.mark.parametrize("bt,t,d,n", [(2, 128, 64, 16), (1, 200, 100, 8),
                                      (3, 64, 256, 16)])
def test_selective_scan(bt, t, d, n):
    x = jax.random.normal(jax.random.PRNGKey(0), (bt, t, d))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (bt, t, d))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (d, n)) * 0.5)
    B = jax.random.normal(jax.random.PRNGKey(3), (bt, t, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (bt, t, n))
    out = np.asarray(ops.selective_scan(x, dt, A, B, C, bd=64, ck=64))
    want, _ = ref.selective_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(out, np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,d", [(256, 128), (100, 96), (17, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(m, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (d,), dtype)
    out = np.asarray(ops.rmsnorm(x, g, bm=64))
    want = np.asarray(ref.rmsnorm_ref(x, g))
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), **_tol(dtype))


def test_chunked_scan_model_path_matches_kernel():
    """The model's chunked associative scan, the Pallas kernel, and the
    sequential oracle all agree."""
    from repro.layers.mamba import ssm_scan_chunked
    bt, t, d, n = 2, 128, 32, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (bt, t, d))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (bt, t, d))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (d, n)) * 0.5)
    B = jax.random.normal(jax.random.PRNGKey(3), (bt, t, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (bt, t, n))
    y1, s1 = ssm_scan_chunked(x, dt, A, B, C, chunk=32)
    y2, s2 = ref.selective_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)
