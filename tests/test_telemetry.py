"""Runtime telemetry (launch/telemetry.py) + named-scope trace
attribution (core/trace.py).

Pins the observability contracts: the JSONL schema round-trips through
its own validator, MFU math agrees with a hand count and with the
roofline's model-flops constant, compiled HLO carries the scope names
for a ring matmul and a ZeRO-3 gather when tracing is on, the disabled
path is byte-identical to an uninstrumented build, and the drift monitor
warns exactly once per out-of-band excursion.
"""
import contextlib
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from conftest import N_DEVICES
from repro.configs import get_config
from repro.configs.shapes import InputShape
from repro.core import collective_matmul as CMM
from repro.core import comm_model as CM
from repro.core import gradsync as GS
from repro.core import mesh as M
from repro.core import trace
from repro.core.compat import shard_map
from repro.launch import mesh as LM
from repro.launch import roofline as RL
from repro.launch import telemetry as TL


@pytest.fixture
def traced():
    """Enable scopes for one test; always restore the disabled default
    (other tests pin the scope-free HLO)."""
    trace.enable()
    yield
    trace.enable(False)


# --------------------------------------------------------------------- #
# JSONL schema round-trip
# --------------------------------------------------------------------- #

def test_jsonl_schema_roundtrip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    telem = TL.Telemetry("t0", path=path, tokens_per_step=128,
                         flops_per_token=6.0, peak_flops_per_device=1e12,
                         n_devices=2, verbose=False,
                         meta={"arch": "toy", "mesh": "1,1,1,1"})
    for s in range(3):
        rec = telem.train_step(s + 1, 0.01 * (s + 1), loss=1.0 - 0.1 * s,
                               grad_norm=0.5)
        TL.validate_record(rec)
    telem.serve_step(0, 0.002, new_tokens=4, queue_depth=2, active=4,
                     page_util=0.25, preemptions=0, step_kind="mixed")
    telem.close(extra={"note_requests": 4.0})
    n = TL.validate_file(path)
    assert n == 6  # meta + 3 train + 1 serve + summary
    kinds = [json.loads(l)["kind"] for l in open(path)]
    assert kinds == ["meta"] + ["train_step"] * 3 + ["serve_step",
                                                     "summary"]
    summary = json.loads(open(path).readlines()[-1])
    assert summary["steps"] == 4 and summary["note_requests"] == 4.0

    # the validator actually rejects malformed records
    with pytest.raises(ValueError):
        TL.validate_record({"v": TL.SCHEMA_VERSION, "run": "x",
                            "kind": "train_step", "step": 1})
    with pytest.raises(ValueError):
        TL.validate_record({"v": 99, "run": "x", "kind": "meta"})
    with pytest.raises(ValueError):
        TL.validate_record({"v": TL.SCHEMA_VERSION, "run": "x",
                            "kind": "train_step", "step": 1,
                            "step_s": 0.1, "ema_s": 0.1, "tok_s": 10.0,
                            "mfu": "not-a-number"})
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    with pytest.raises(ValueError):
        TL.validate_file(str(empty))


# --------------------------------------------------------------------- #
# MFU math
# --------------------------------------------------------------------- #

def test_mfu_hand_count(tmp_path):
    # 6 flops/token * 4 tok/s over 2 devices * 12 flop/s peak => 100%
    telem = TL.Telemetry("t1", path=str(tmp_path / "m.jsonl"),
                         tokens_per_step=4, flops_per_token=6.0,
                         peak_flops_per_device=12.0, n_devices=2,
                         verbose=False)
    assert telem.mfu(4.0) == pytest.approx(1.0)
    assert telem.mfu(1.0) == pytest.approx(0.25)
    rec = telem.train_step(1, 1.0)  # 4 tokens in 1 s
    assert rec["mfu"] == pytest.approx(1.0)
    telem.close()
    # MFU disabled when any constant is missing
    t2 = TL.Telemetry("t2", path=str(tmp_path / "n.jsonl"),
                      tokens_per_step=4, verbose=False)
    assert t2.mfu(4.0) is None
    t2.close()


def test_model_flops_per_token_vs_roofline():
    cfg = get_config("qwen3-1.7b").reduced()
    n_active = float(cfg.active_param_count())
    assert CM.model_flops_per_token(cfg) == pytest.approx(6.0 * n_active)
    assert CM.model_flops_per_token(cfg, "serve") == pytest.approx(
        2.0 * n_active)
    with pytest.raises(ValueError):
        CM.model_flops_per_token(cfg, "prefill")

    # the roofline's per-device model flops divide the SAME constant —
    # telemetry MFU and dryrun useful_ratio share one numerator
    shape = InputShape("t", seq_len=32, global_batch=8, kind="train")
    assert RL.model_flops_per_device(cfg, shape, 4) == pytest.approx(
        6.0 * n_active * 8 * 32 / 4)
    dec = InputShape("d", seq_len=32, global_batch=8, kind="decode")
    assert RL.model_flops_per_device(cfg, dec, 4) == pytest.approx(
        2.0 * n_active * 8 / 4)


# --------------------------------------------------------------------- #
# named scopes in compiled HLO
# --------------------------------------------------------------------- #

def _z_mesh():
    return LM.make_smoke_mesh((1, 1, 2, 4) if N_DEVICES >= 8
                              else (1, 1, 1, 4))


def _ring_ag_hlo():
    """Fresh jit wrapper every call — jit caches do not key on the trace
    flag, so each enable-state needs its own trace."""
    mesh = _z_mesh()
    axes = LM.bind_4d(mesh)

    def body(v, w):
        return CMM.ag_matmul(v, w, axes.z)

    f = shard_map(body, mesh=mesh, in_specs=(P(None, None), P(None, "z")),
                  out_specs=P(None, None), check_vma=False)
    v = jnp.ones((4, 8))
    w = jnp.ones((8, 6 * mesh.shape["z"]))
    return jax.jit(f).lower(v, w).compile().as_text()


def test_scopes_in_ring_matmul_hlo(traced):
    txt = _ring_ag_hlo()
    assert "ring_ag[z]/hop0" in txt
    assert "gemm/chunk0" in txt
    assert "collective-permute" in txt


def test_scopes_in_zero3_and_dp_hlo(traced):
    shape = (4, 1, 2, 1) if N_DEVICES >= 8 else (4, 1, 1, 1)
    mesh = LM.make_smoke_mesh(shape)
    axes = LM.bind_4d(mesh)
    structs = {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32),
               "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
    from repro.core.partition import ParamSpec
    specs = {"w": ParamSpec(P(None, None), False),
             "b": ParamSpec(P(None,), False)}
    plan = GS.make_leaf_plan(structs, specs, axes)

    def body(w, b):
        # dict keys flatten sorted: bucket0 <-> "b", bucket1 <-> "w"
        shards = GS.reduce_scatter_grads({"w": w, "b": b}, plan, axes)
        leaf = GS.gather_param_leaf(shards[0], plan.buckets[0], axes)
        return leaf, shards[1]

    f = shard_map(body, mesh=mesh, in_specs=(P(None, None), P(None)),
                  out_specs=(P(None), P("data")), check_vma=False)
    txt = jax.jit(f).lower(jnp.ones((4, 8)), jnp.ones((8,))) \
        .compile().as_text()
    assert "dp_rs/bucket0" in txt and "dp_rs/bucket1" in txt
    assert "zero3_ag[data]/leaf0" in txt


def test_scopes_in_seq_kv_ring_hlo(traced):
    from repro.core.overlap import OverlapConfig
    from repro.layers import attention as A
    p = 4 if N_DEVICES >= 4 else 2
    mesh = LM.make_smoke_mesh((1, 1, 1, 1, p),
                              ("data", "x", "y", "z", "seq"))
    axes = LM.bind_4d(mesh).with_overlap(
        OverlapConfig(ring_attention=True))
    q = jnp.ones((2, 16, 2, 4))
    spec = P(None, "seq", None, None)
    f = shard_map(
        lambda a, b, c: A.seq_attn(a, b, c, axes, causal=True),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec)
    txt = jax.jit(f).lower(q, q, q).compile().as_text()
    assert "ring_exchange[seq]/hop1" in txt


def test_scope_disabled_hlo_byte_identical(monkeypatch):
    """The degeneracy pin: with tracing off, ``scope`` must be a true
    no-op — the compiled HLO is byte-for-byte what an uninstrumented
    build produces (same body, ``scope`` patched to nullcontext, fresh
    jit wrappers so nothing is cached across the comparison)."""
    assert not trace.enabled()
    base = _ring_ag_hlo()
    assert "ring_ag" not in base and "gemm/chunk" not in base

    monkeypatch.setattr(trace, "scope",
                        lambda *a, **k: contextlib.nullcontext())
    stripped = _ring_ag_hlo()
    assert base == stripped

    # sanity: the enabled path DOES change the text (the scopes above
    # were not vacuously absent)
    monkeypatch.undo()
    trace.enable()
    try:
        assert "ring_ag[z]/hop0" in _ring_ag_hlo()
    finally:
        trace.enable(False)


def test_scope_labels():
    assert trace.label("ring_ag", "z", "hop2") == "ring_ag[z]/hop2"
    assert trace.label("dp_rs", None, "bucket3") == "dp_rs/bucket3"
    assert trace.label("ring_rs", ("data", "z")) == "ring_rs[data+z]"
    assert trace.label("embed_gather", ()) == "embed_gather"


def test_scope_decorator_and_noop():
    calls = []

    @trace.scope("k", None, "d")
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2 and calls == [1]  # disabled: fn returned as-is
    trace.enable()
    try:
        dec = trace.scope("k", None, "d")(lambda x: x * 2)
        assert dec(3) == 6
    finally:
        trace.enable(False)


# --------------------------------------------------------------------- #
# drift monitor
# --------------------------------------------------------------------- #

def test_drift_monitor_warns_once_per_excursion():
    mon = TL.DriftMonitor(0.010, band=0.5, min_steps=5)
    # in-band steps: never warns
    for _ in range(6):
        mon.update(0.012)
    assert not mon.out_of_band and mon.check() is None
    # drift out of band (median must cross 1.5x): warn exactly once
    for _ in range(32):
        mon.update(0.020)
    assert mon.out_of_band
    assert mon.check() is not None
    assert mon.check() is None          # second call: already warned
    # back in band resets the latch...
    for _ in range(32):
        mon.update(0.010)
    assert not mon.out_of_band and mon.check() is None
    # ...so the next excursion warns again
    for _ in range(32):
        mon.update(0.005)               # too FAST is also drift
    assert mon.out_of_band and mon.check() is not None

    rec = mon.record(workload="unit")
    for k in ("predicted_s", "measured_p50_s", "ratio", "n"):
        assert isinstance(rec[k], (int, float))
    assert rec["workload"] == "unit" and rec["out_of_band"]

    with pytest.raises(ValueError):
        TL.DriftMonitor(0.0)


def test_drift_below_min_steps_is_silent():
    mon = TL.DriftMonitor(0.010, band=0.5, min_steps=5)
    for _ in range(4):
        mon.update(1.0)                 # wildly off, but too few samples
    assert not mon.out_of_band and mon.check() is None


def test_merge_drift_into_profile():
    from repro.core import calibrate as CB
    prof = CB.CalibrationProfile(
        backend="cpu", n_devices=8, mesh_shape=(2, 2, 2, 1),
        alpha=1e-6, link_bw=5e10, flops=1e12, overlap_efficiency=0.8)
    mon = TL.DriftMonitor(0.010)
    for _ in range(8):
        mon.update(0.018)
    out = CB.merge_drift(prof, mon.record(workload="toy@2,2,2,1"))
    assert out.probes["drift:toy@2,2,2,1"] == pytest.approx(1.8)
    assert out.probes["drift_ratio"] == pytest.approx(1.8)
    assert out.probes["drift_n"] == 8
    # fitted constants are never rescaled by a drift merge
    assert out.alpha == prof.alpha
    assert out.link_bw == prof.link_bw
    assert out.flops == prof.flops
    with pytest.raises(ValueError):
        CB.merge_drift(prof, {"ratio": 1.0})


# --------------------------------------------------------------------- #
# telemetry end-to-end against a real (tiny) engine run
# --------------------------------------------------------------------- #

def test_serve_telemetry_agrees_with_stats(tmp_path):
    """serve_step records + close(extra=stats) must leave a file whose
    summary quotes the engine's own tokens/s (the CSV/JSONL agreement
    satellite)."""
    path = str(tmp_path / "serve.jsonl")
    telem = TL.Telemetry("srv", path=path, verbose=False)
    total = 0
    for s in range(5):
        telem.serve_step(s, 0.001, new_tokens=3, queue_depth=1,
                         active=3, page_util=0.5, preemptions=0)
        total += 3
    engine_tok_s = 1234.5
    telem.close(extra={"tok_s": engine_tok_s, "tokens": total,
                       "steps": 5})
    TL.validate_file(path)
    summary = json.loads(open(path).readlines()[-1])
    assert summary["tok_s"] == engine_tok_s
    assert summary["tokens"] == total == telem.serve_tokens
