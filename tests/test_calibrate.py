"""Hardware calibration (core/calibrate.py): fit recovery, profile
round-trips, the --calib plumbing into the analytic model, and the
uncalibrated-run degeneracies."""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import calibrate as CB
from repro.core import comm_model as CM

from conftest import N_DEVICES


# --------------------------------------------------------------------- #
# fit: synthetic recovery and conventions
# --------------------------------------------------------------------- #

def _synthetic_samples(gamma, alpha, beta, axis="x", ps=(2, 4),
                       sizes=(1 << 10, 1 << 14, 1 << 18)):
    out = []
    for p in ps:
        for kind in ("all_gather", "reduce_scatter", "all_reduce", "psum"):
            for n in sizes:
                steps, wire = CB.collective_geometry(kind, p, n * 4.0)
                out.append(CB.Sample(
                    kind=kind, axis=axis, p=p, elems=n, steps=steps,
                    wire_bytes=wire,
                    seconds=gamma + steps * alpha + wire * beta))
    return out


def test_fit_recovers_known_constants_exactly():
    """Noiseless samples generated from the model must fit back to the
    generating (γ, α, β) — the least-squares system is exactly
    determined once two hop counts and a byte sweep are present."""
    gamma, alpha, beta = 8.1e-4, 3.7e-5, 2.2e-9
    g, a, b, r2 = CB.fit_constants(_synthetic_samples(gamma, alpha, beta))
    assert g == pytest.approx(gamma, rel=1e-9)
    assert a == pytest.approx(alpha, rel=1e-9)
    assert b == pytest.approx(beta, rel=1e-9)
    assert r2 == pytest.approx(1.0, abs=1e-12)
    # degenerate corners recover too (per-call-dominated CPU, pure ring)
    g, a, b, _ = CB.fit_constants(_synthetic_samples(1e-3, 0.0, 1e-9))
    assert g == pytest.approx(1e-3, rel=1e-9)
    assert a == pytest.approx(0.0, abs=1e-12)
    g, a, b, _ = CB.fit_constants(_synthetic_samples(0.0, 5e-5, 1e-9))
    assert a == pytest.approx(5e-5, rel=1e-6)
    assert g == pytest.approx(0.0, abs=1e-9)


def test_fit_clamps_nonphysical_solutions():
    """A fit cannot claim negative latency: pure-bandwidth timings with
    a tiny anticorrelated latency column clamp γ/α to 0."""
    # t = wire * beta - steps * eps  (eps tiny): unconstrained lstsq
    # would fit a negative alpha
    beta = 1e-9
    samples = []
    for p in (2, 4):
        for n in (1 << 10, 1 << 14, 1 << 18):
            steps, wire = CB.collective_geometry("all_gather", p, n * 4.0)
            samples.append(CB.Sample("all_gather", "x", p, n, steps,
                                     wire, max(wire * beta
                                               - steps * 1e-7, 0.0)))
            samples.append(CB.Sample("all_reduce", "x", p, n, 2 * steps,
                                     2 * wire, max(2 * wire * beta
                                                   - 2 * steps * 1e-7,
                                                   0.0)))
    g, a, b, _ = CB.fit_constants(samples)
    assert g >= 0.0 and a >= 0.0 and b >= 0.0


def test_fit_needs_three_samples():
    with pytest.raises(ValueError):
        CB.fit_constants([])


def test_collective_geometry_matches_comm_model_pricing():
    """The fit's regressor rows must use exactly the hop counts and
    bandwidth-optimal wire bytes collective_time charges — otherwise the
    fitted α/β would mean something else than the model's."""
    p, elems, bpe = 4, 1 << 12, 4.0
    for kind in ("all_gather", "reduce_scatter", "all_reduce"):
        steps, wire = CB.collective_geometry(kind, p, elems * bpe)
        hw = CM.HardwareParams(alpha=1.0, gamma=0.25, link_bw=1.0,
                               bytes_per_elem=bpe)
        t = CM.collective_time(kind, p, elems, hw)
        assert t == pytest.approx(0.25 + steps * 1.0 + wire / 1.0,
                                  rel=1e-12), kind
    # psum is priced as the all-reduce it is
    assert CB.collective_geometry("psum", p, 64.0) == \
        CB.collective_geometry("all_reduce", p, 64.0)
    # degenerate group
    assert CB.collective_geometry("all_reduce", 1, 64.0) == (0, 0.0)


# --------------------------------------------------------------------- #
# profile persistence
# --------------------------------------------------------------------- #

def _profile(**kw):
    base = dict(backend="cpu", n_devices=8, mesh_shape=(1, 2, 2, 2),
                alpha=4e-4, gamma=1e-3, link_bw=2e8, flops=2.4e11,
                overlap_efficiency=0.25, z_claims_first=False,
                cross_step_efficiency=0.5, bytes_per_elem=2.0,
                fit_r2=0.9,
                axis_fits=(CB.AxisFit("x", 2, 4e-4, 5e-9, 0.9, 16,
                                      gamma=1e-3),),
                probes={"overlap_z_hidden": 0.25},
                samples=(CB.Sample("all_gather", "x", 2, 1024, 1,
                                   2048.0, 1e-3),))
    base.update(kw)
    return CB.CalibrationProfile(**base)


def test_profile_json_roundtrip_through_hardware_params(tmp_path):
    """save -> load -> hardware_params() must reproduce every fitted
    constant, including the claim-order and cross-step knobs."""
    prof = _profile()
    path = prof.save(str(tmp_path / "cpu.json"))
    loaded = CB.CalibrationProfile.load(path)
    assert loaded == prof
    hw = loaded.hardware_params()
    assert hw == CM.HardwareParams(
        alpha=4e-4, gamma=1e-3, link_bw=2e8, flops=2.4e11,
        bytes_per_elem=2.0, overlap_efficiency=0.25, z_claims_first=False,
        cross_step_efficiency=0.5)


def test_profile_load_ignores_unknown_keys(tmp_path):
    """Forward compatibility: a profile written by a newer build (extra
    JSON keys) must still load."""
    import json
    d = _profile().as_dict()
    d["future_field"] = {"x": 1}
    p = tmp_path / "future.json"
    p.write_text(json.dumps(d))
    assert CB.CalibrationProfile.load(str(p)) == _profile()


def test_resolve_semantics(tmp_path, monkeypatch):
    assert CB.resolve(None) is None
    assert CB.resolve("") is None
    # auto with no profile on disk: uncalibrated, not an error
    monkeypatch.chdir(tmp_path)
    assert CB.resolve("auto") is None
    assert CB.resolve_hw(None) == CM.TPU_V5E
    prof = _profile()
    prof.save(CB.default_path("cpu"))
    import jax
    if jax.default_backend() == "cpu":
        got = CB.resolve("auto")
        assert got == prof
    # explicit path always works
    path = prof.save(str(tmp_path / "explicit.json"))
    assert CB.resolve(path) == prof
    assert CB.resolve_hw(path) == prof.hardware_params()


# --------------------------------------------------------------------- #
# --calib changes the model's choice; uncalibrated stays bitwise
# --------------------------------------------------------------------- #

def test_calib_profile_changes_chosen_factorization(tmp_path):
    """A latency-dominated profile (huge α) must steer
    optimize_decomposition away from the deep-ring factorization a
    bandwidth-dominated profile picks — the constructed-profile twin of
    'calibration turns the tuner measured'."""
    layers = CM.transformer_layers(1024, n_layers=4)
    tokens = 1 << 16
    lat = _profile(alpha=1.0, gamma=0.0, link_bw=1e30, flops=1e30)
    bw = _profile(alpha=0.0, gamma=0.0, link_bw=1e6, flops=1e30)
    p_lat = lat.save(str(tmp_path / "lat.json"))
    p_bw = bw.save(str(tmp_path / "bw.json"))
    picks = {}
    for name, path in (("lat", p_lat), ("bw", p_bw)):
        hw = CB.resolve_hw(path)
        picks[name] = CM.optimize_decomposition(
            layers, tokens, 16, objective="time", hw=hw)[0][0]
    # pure-bandwidth pricing is the volume model: max g_data (Eq. 5);
    # pure-latency pricing minimizes total ring hops instead
    assert picks["bw"].g_data == 16
    assert picks["lat"] != picks["bw"], picks


def _old_claim_order_layer_time(ls, tokens, d, hw, overlap):
    """The PR-2/PR-4 fixed z-first arithmetic, re-derived: the
    uncalibrated degeneracy pin for layer_time's claim-order knob."""
    g = CM.layer_geometry(ls, tokens, d, overlap)
    t_compute = 6.0 * g.m_local * ls.k * ls.n / (g.gx * g.gy) / hw.flops
    t_act = (CM.collective_time("all_reduce", g.gx, g.ar_fwd_buf, hw)
             + CM.collective_time("all_reduce", g.gy, g.ar_bwd_buf, hw))
    t_z = (g.n_gathers
           * CM.collective_time("all_gather", d.g_z, g.w_full_per_xy, hw)
           + CM.collective_time("reduce_scatter", d.g_z, g.w_full_per_xy,
                                hw))
    window = hw.overlap_efficiency * t_compute
    hidden_z = min(t_z, window) if (overlap.matmul and d.g_z > 1) else 0.0
    hidden_ar = (min(t_act, window - hidden_z)
                 if overlap.all_reduce else 0.0)
    return hidden_z, hidden_ar


def test_uncalibrated_layer_time_bitwise_unchanged():
    """Default HardwareParams (z_claims_first=True,
    cross_step_efficiency=1.0) must reproduce the pre-calibration model
    exactly — no --calib, no change."""
    from repro.core.overlap import OverlapConfig
    ls = CM.LayerShape(1024, 4096)
    d = CM.Decomposition(2, 2, 2, 2)
    ov = OverlapConfig.all_on()
    hw = CM.HardwareParams()          # defaults == uncalibrated
    st = CM.layer_time(ls, 1 << 14, d, hw, overlap=ov,
                       include_data_parallel=False)
    hz, har = _old_claim_order_layer_time(ls, 1 << 14, d, hw, ov)
    assert st.hidden_comm == hz + har  # bitwise: same ops, same order
    # explicit defaults are the same point
    hw2 = CM.HardwareParams(z_claims_first=True, cross_step_efficiency=1.0)
    st2 = CM.layer_time(ls, 1 << 14, d, hw2, overlap=ov,
                        include_data_parallel=False)
    assert st2 == st


def test_claim_order_swap_changes_split_not_total():
    """With a window smaller than either contender, swapping
    z_claims_first moves time between hidden_z and hidden_ar but
    conserves hidden + exposed (it is a priority rule, not a discount);
    with a window large enough for both, the split is order-invariant."""
    from repro.core.overlap import OverlapConfig
    ls = CM.LayerShape(1024, 1024)
    d = CM.Decomposition(1, 2, 2, 4)
    ov = OverlapConfig.all_on()
    tokens = 1 << 10  # small compute window: contention is real
    z_first = CM.HardwareParams(z_claims_first=True)
    ar_first = CM.HardwareParams(z_claims_first=False)
    st_z = CM.layer_time(ls, tokens, d, z_first, overlap=ov,
                         include_data_parallel=False)
    st_ar = CM.layer_time(ls, tokens, d, ar_first, overlap=ov,
                          include_data_parallel=False)
    assert st_z.compute == st_ar.compute
    assert st_z.exposed_comm + st_z.hidden_comm == pytest.approx(
        st_ar.exposed_comm + st_ar.hidden_comm, rel=1e-12)
    # the window binds here, so *what* hides differs between orders
    assert st_z.hidden_comm == pytest.approx(st_ar.hidden_comm, rel=1e-9)
    # huge compute: both fit, order invisible
    st_z2 = CM.layer_time(ls, 1 << 22, d, z_first, overlap=ov,
                          include_data_parallel=False)
    st_ar2 = CM.layer_time(ls, 1 << 22, d, ar_first, overlap=ov,
                           include_data_parallel=False)
    assert st_z2 == st_ar2


def test_cross_step_efficiency_scales_the_window():
    """cross_step_efficiency: 1.0 == the PR-4 cross-step model, 0.0 ==
    cross_step off entirely, and the hideable term interpolates
    linearly in between (it scales only the terminal 2·t_pass)."""
    from repro.core.gradsync import GradSyncConfig
    buf, p, mb = 1e6, 4, 2
    for gs_on in (GradSyncConfig(zero=True, cross_step=True),
                  GradSyncConfig(zero3=True, cross_step=True)):
        gs_off = dataclasses.replace(gs_on, cross_step=False)
        full = CM.HardwareParams(cross_step_efficiency=1.0)
        none = CM.HardwareParams(cross_step_efficiency=0.0)
        half = CM.HardwareParams(cross_step_efficiency=0.5)
        tot_on, hide_full = CM.dp_sync_time(p, buf, gs_on, mb, full)
        tot_off, hide_off = CM.dp_sync_time(p, buf, gs_off, mb, full)
        assert tot_on == tot_off  # the knob moves exposure, not volume
        _, hide_none = CM.dp_sync_time(p, buf, gs_on, mb, none)
        _, hide_half = CM.dp_sync_time(p, buf, gs_on, mb, half)
        assert hide_none == pytest.approx(hide_off, rel=1e-12)
        assert hide_half == pytest.approx(
            (hide_full + hide_none) / 2.0, rel=1e-12)
        assert hide_full > hide_none


# --------------------------------------------------------------------- #
# measured harness smoke (host devices) + validation helpers
# --------------------------------------------------------------------- #

@pytest.mark.skipif(N_DEVICES < 2, reason="calibration needs >= 2 devices")
def test_run_calibration_smoke_and_roundtrip(tmp_path):
    """A tiny real calibration on the host mesh: positive fits, sane
    probes, and a lossless trip through the JSON + HardwareParams."""
    prof = CB.run_calibration(sizes=(256, 2048), reps=1)
    assert prof.n_devices == N_DEVICES
    assert prof.alpha >= 0.0
    assert prof.link_bw > 0.0 and math.isfinite(prof.link_bw)
    assert prof.flops > 0.0
    assert 0.0 <= prof.overlap_efficiency <= 1.0
    assert 0.0 <= prof.cross_step_efficiency <= 1.0
    assert prof.axis_fits and all(f.p > 1 for f in prof.axis_fits)
    assert prof.samples
    path = prof.save(str(tmp_path / "smoke.json"))
    loaded = CB.CalibrationProfile.load(path)
    assert loaded.hardware_params() == prof.hardware_params()
    assert len(loaded.samples) == len(prof.samples)
    # the profile must be usable end to end by the optimizer
    layers = CM.transformer_layers(256)
    ranked = CM.optimize_decomposition(layers, 4096, 8, objective="time",
                                       hw=loaded.hardware_params())
    assert ranked


def test_spearman_rank_correlation():
    assert CB.spearman([1, 2, 3, 4], [10, 20, 30, 40]) == \
        pytest.approx(1.0)
    assert CB.spearman([1, 2, 3, 4], [40, 30, 20, 10]) == \
        pytest.approx(-1.0)
    # monotone in rank, not in value
    assert CB.spearman([1, 2, 3, 4], [1, 100, 101, 1e6]) == \
        pytest.approx(1.0)
    # constant series has no ranking to correlate with
    assert CB.spearman([1, 2, 3], [5, 5, 5]) == 0.0
