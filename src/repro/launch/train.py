"""End-to-end training driver.

Runs real optimization steps on the current host devices (CPU smoke scale
or a real TPU slice — same code path; only the mesh differs). Examples:

  # ~100M model, a few hundred steps on an 8-device CPU mesh:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.train --arch qwen3-1.7b --preset 100m \\
      --steps 300 --batch 16 --seq 256 --mesh 2,2,2,1

  # reduced smoke variant of any assigned arch:
  python -m repro.launch.train --arch jamba-v0.1-52b --preset smoke

Fault tolerance (docs/fault_tolerance.md): the mesh is owned by a
``MeshLifecycle``; ``--chaos`` injects deterministic failures
(``core/faultinject.py``) which the recovery loop survives by
checkpoint-or-snapshot restore + online re-shard of the data axis onto
the surviving devices; ``--probe-every`` runs per-collective health
probes (``launch/probes.py``) whose verdicts merge back into the
``--calib`` profile; SIGTERM/SIGINT trigger a final checkpoint and a
clean telemetry close.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core.gradsync import GradSyncConfig
from repro.core.partition import spec_tree_to_pspecs
from repro.data.synthetic import DataConfig, SyntheticText, make_batch
from repro.launch import mesh as LM
from repro.launch import steps as ST
from repro.optim.adamw import AdamWConfig, init_state
from repro.optim import adamw as OPT


def preset_config(cfg, preset: str):
    """Model-size presets for CPU-scale end-to-end runs."""
    if preset == "full":
        return cfg
    if preset == "smoke":
        return cfg.reduced()
    if preset == "100m":
        # ~100M-param member of the same family
        segs = cfg.segments()
        return dataclasses.replace(
            cfg.reduced(), name=cfg.name + "-100m", d_model=512,
            n_heads=8, n_kv_heads=min(8, cfg.n_kv_heads), head_dim=64,
            d_ff=(2048 if cfg.d_ff else 0), vocab_size=32000,
            n_layers=max(cfg.reduced().n_layers, 4)
            if not cfg.mixer_pattern and cfg.xlstm is None
            else cfg.reduced().n_layers)
    raise ValueError(preset)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.train",
        description="End-to-end training on the current host devices "
                    "(CPU smoke scale or a real TPU slice).")
    ap.add_argument("--arch", required=True,
                    help="architecture name (repro.configs)")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"],
                    help="model-size preset for CPU-scale runs")
    ap.add_argument("--steps", type=int, default=100,
                    help="optimizer steps")
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch (sequences)")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length (tokens)")
    ap.add_argument("--lr", type=float, default=3e-4,
                    help="peak AdamW learning rate")
    ap.add_argument("--mesh", default="2,2,2,1",
                    help="g_data,g_x,g_y,g_z[,g_seq[,g_expert]] over "
                         "host devices (5th/6th factors: context / "
                         "expert parallelism)")
    ap.add_argument("--overdecompose", type=int, default=2,
                    help="microbatch count of the overdecompose loop")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO-sharded DP sync: bucketed gradient "
                         "reduce-scatter rings streamed through the "
                         "overdecompose loop, AdamW state sharded over "
                         "the data axis (core/gradsync.py)")
    ap.add_argument("--zero3", action="store_true",
                    help="ZeRO-3 param-shard streaming: params live as "
                         "1/G_data shards, each layer's working copy "
                         "ring-all-gathered just-in-time inside the "
                         "layer scan (core/gradsync.py); implies the "
                         "--zero state sharding")
    ap.add_argument("--zero3-prefetch", action="store_true",
                    help="with --zero3: gather layer i+1's shards during "
                         "layer i's compute; the copy is retained for "
                         "the backward (no re-gather, ~full param "
                         "memory)")
    ap.add_argument("--dp-bucket-mb", type=float, default=4.0,
                    help="fp32 gradient bucket bound in MiB "
                         "(with --zero/--zero3)")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="activation/param compute dtype")
    ap.add_argument("--calib", default="",
                    help="hardware calibration profile (path or 'auto'; "
                         "benchmarks.calibrate): report the α-β model's "
                         "predicted step time next to the measured one "
                         "at the end of the run")
    ap.add_argument("--ckpt", default="",
                    help="checkpoint path (.npz) to save at the end "
                         "(atomic write + per-leaf checksums; see also "
                         "--ckpt-every / --resume)")
    ap.add_argument("--ckpt-every", type=int, default=0, metavar="N",
                    help="also checkpoint every N steps (0 = off); the "
                         "write is atomic, so a crash mid-save keeps "
                         "the previous checkpoint")
    ap.add_argument("--resume", action="store_true",
                    help="restore from --ckpt before training (verifies "
                         "checksums first) and continue from the saved "
                         "step; the mesh may differ from the saving "
                         "run's — the state re-shards through the "
                         "replicated checkpoint layout")
    ap.add_argument("--chaos", default="", metavar="SPEC",
                    help="deterministic fault injection "
                         "(core/faultinject.py), e.g. 'seed=0;"
                         "rank_loss@5:n=2,via=ckpt;ckpt_corrupt@4;"
                         "timeout@7:class=dp_rs_ag,secs=0.3'. rank_loss "
                         "shrinks g_data online via the mesh lifecycle; "
                         "rank_recover returns the lost capacity and "
                         "grows g_data back the same way; "
                         "ckpt_corrupt damages the --ckpt file in place; "
                         "timeout stalls one collective class so the "
                         "watchdog must classify the step")
    ap.add_argument("--probe-every", type=int, default=0, metavar="N",
                    help="run per-collective health probes every N "
                         "steps (launch/probes.py): one tiny timed "
                         "program per collective class on the mesh, "
                         "drift-monitored against the --calib profile's "
                         "alpha-beta prediction and merged back into "
                         "profile.probes at exit; 0 = off (the default "
                         "keeps the run's HLO byte-identical)")
    ap.add_argument("--log-every", type=int, default=10,
                    help="steps between metric log lines")
    ap.add_argument("--telemetry", action="store_true",
                    help="per-step JSONL telemetry to runs/telemetry/"
                         "<run>.jsonl (launch/telemetry.py): step time "
                         "EMA + p50/p99, tokens/s, MFU, loss/grad-norm, "
                         "peak device bytes, and — with --calib — the "
                         "predicted-vs-measured drift ratio. Blocks on "
                         "each step's metrics, so the host loop "
                         "serializes with the device")
    ap.add_argument("--profile-steps", default="", metavar="A:B",
                    help="capture a jax.profiler trace of steps A..B "
                         "(inclusive) to runs/profiles/<run>/, with "
                         "named-scope attribution (core/trace.py) "
                         "enabled so ring hops/buckets/gathers are "
                         "labeled in the trace")
    ap.add_argument("--log-file", default="",
                    help="telemetry JSONL path (implies --telemetry; "
                         "default runs/telemetry/<run>.jsonl)")
    return ap


def _ckpt_snapshot(path: str, cfg, axes, opts) -> dict:
    """Load a checkpoint into the host replicated-layout snapshot form
    of ``launch.steps.snapshot_state`` — verifying every leaf's checksum
    first, so a corrupt file is rejected with the offending leaf named
    instead of scattering garbage onto the mesh."""
    ckpt.verify(path)
    structs, _ = ST.init_model(cfg, axes.with_overlap(opts.overlap),
                               abstract=True, dtype=opts.dtype)
    like_state = OPT.init_state(structs, abstract=True)
    params, step = ckpt.restore(path, structs)
    state, _ = ckpt.restore(path, like_state, root="opt_state")
    return {"params": params, "opt_state": state, "step": int(step),
            "fingerprint": None}


def main():
    args = build_parser().parse_args()

    # resolve the calibration profile up front: a bad --calib path must
    # fail before the training loop, not after it
    calib_hw = None
    if args.calib:
        from repro.core import calibrate as CB
        calib_hw = CB.resolve_hw(args.calib)

    profile_steps = None
    if args.profile_steps:
        from repro.core import trace
        a, _, b = args.profile_steps.partition(":")
        profile_steps = (int(a), int(b))
        if not (0 <= profile_steps[0] <= profile_steps[1]):
            raise SystemExit(f"--profile-steps {args.profile_steps}: "
                             f"need 0 <= A <= B")
        # the captured window should attribute its ring hops; enable
        # BEFORE the step is traced (jit caches don't key on the flag)
        trace.enable()

    injector = None
    if args.chaos:
        from repro.core import faultinject as FI
        injector = FI.parse_chaos(args.chaos)
        print(f"chaos: seed={injector.seed} events="
              f"{[f'{e.kind}@{e.step}' for e in injector.events]}")

    shape = tuple(int(x) for x in args.mesh.split(","))
    life = LM.MeshLifecycle(*shape)
    mesh, axes = life.build()
    cfg = preset_config(get_config(args.arch), args.preset)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16

    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=dtype)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={shape} devices={mesh.devices.size}")

    pspecs = spec_tree_to_pspecs(specs)
    params = ST.device_put_tree(mesh, params, pspecs)
    if args.zero3:
        gs = GradSyncConfig(zero3=True, prefetch=args.zero3_prefetch,
                            bucket_mb=args.dp_bucket_mb)
    elif args.zero:
        gs = GradSyncConfig(zero=True, bucket_mb=args.dp_bucket_mb)
    else:
        gs = GradSyncConfig()
    topts = ST.TrainOptions(overdecompose=args.overdecompose, dtype=dtype,
                            gradsync=gs)
    tools = (ST.make_gradsync_tools(cfg, mesh, axes, topts)
             if gs.state_sharded else None)
    state = tools.init(params) if gs.state_sharded else init_state(params)
    if gs.zero3:
        # the step's params argument IS the 1/G_data shard tree from
        # here on; working copies are streamed per layer inside the step
        params = tools.shard_params(params)
    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                      total_steps=args.steps)
    step_fn, _, _ = ST.make_train_step(cfg, mesh, axes, opt, topts)

    def save_checkpoint(at_step: int) -> None:
        if gs.state_sharded:
            # sharded opt state (and, under zero3, the param shards)
            # travels in the replicated per-leaf layout so the run can
            # resume under a different g_data
            full_p = (tools.unshard_params(params) if gs.zero3
                      else params)
            ckpt.save_sharded(args.ckpt, jax.tree.map(np.asarray, full_p),
                              state, tools.gather, step=at_step,
                              pspecs=pspecs,
                              extra={"dp_bucket_mb": args.dp_bucket_mb,
                                     "zero3": gs.zero3,
                                     "mesh": list(life.factors)})
        else:
            ckpt.save(args.ckpt, jax.tree.map(np.asarray, params),
                      jax.tree.map(np.asarray, jax.device_get(state)),
                      step=at_step, pspecs=pspecs)

    start_step = 0
    if args.resume:
        if not args.ckpt:
            raise SystemExit("--resume needs --ckpt")
        snap = _ckpt_snapshot(args.ckpt, cfg, axes, topts)
        params, state = ST.restore_state(snap, cfg, mesh, axes, tools,
                                         topts)
        start_step = snap["step"] + 1
        print(f"resumed {args.ckpt} at step {snap['step']} "
              f"(mesh {life.factors})")

    data = SyntheticText(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))

    pred = None
    if calib_hw is not None:
        # the α-β model's step time for THIS run, priced with the --calib
        # profile: seeds the drift monitor and the end-of-run print
        from repro.core import comm_model as CM
        hw = dataclasses.replace(
            calib_hw, bytes_per_elem=float(jnp.dtype(dtype).itemsize))
        pred = CM.predict_step_time(
            list(cfg.comm_layers()), args.batch * args.seq,
            CM.Decomposition(*shape), hw, gradsync=gs,
            microbatches=args.overdecompose)

    run_name = f"{cfg.name}-{time.strftime('%Y%m%d-%H%M%S')}"
    telem = None
    if args.telemetry or args.log_file:
        from repro.core import comm_model as CM
        from repro.launch import telemetry as TL
        telem = TL.Telemetry(
            run_name, path=args.log_file or None,
            tokens_per_step=args.batch * args.seq,
            flops_per_token=CM.model_flops_per_token(cfg),
            peak_flops_per_device=(calib_hw.flops if calib_hw is not None
                                   else CM.TPU_V5E.flops),
            n_devices=int(mesh.devices.size),
            drift=(TL.DriftMonitor(pred.total)
                   if pred is not None and pred.total > 0 else None),
            meta={"arch": cfg.name, "mesh": list(shape),
                  "n_devices": int(mesh.devices.size), "batch": args.batch,
                  "seq": args.seq, "dtype": args.dtype,
                  "calib": args.calib})

    probes = watchdog = None
    PRB = None
    if args.probe_every > 0 or injector is not None:
        # chaos mode always arms the probes/watchdog (the timeout events
        # need something to classify them); with both off nothing here
        # is built and the training step's HLO stays byte-identical
        from repro.launch import probes as PRB
        probes = PRB.CollectiveProbes(mesh, axes, calib_hw,
                                      injector=injector)
        watchdog = PRB.Watchdog(probes)

    # SIGTERM/SIGINT flip a flag; the loop drains the in-flight step,
    # writes a final checkpoint, and closes telemetry cleanly
    stop = {"sig": None}

    def _on_signal(signum, frame):
        stop["sig"] = signum
    old_handlers = {s: signal.signal(s, _on_signal)
                    for s in (signal.SIGTERM, signal.SIGINT)}

    log = []
    t0 = time.time()
    t_warm = None  # set after the compile step (excluded from timing)
    t_step = None  # previous step's end — the per-step telemetry clock
    prof_on = False
    done = 0       # completed steps this process (compile = done 0)
    step = start_step
    while step < args.steps:
        if stop["sig"] is not None:
            sig_name = signal.Signals(stop["sig"]).name
            print(f"caught {sig_name}: shutting down after step "
                  f"{step - 1}", flush=True)
            if telem is not None:
                telem.event(step, "shutdown", sig=sig_name,
                            generation=life.generation)
            break

        if injector is not None:
            rank_loss = None
            rank_recover = None
            for ev in injector.events_at(step):
                if ev.kind == "ckpt_corrupt":
                    target = args.ckpt or ""
                    if target and not os.path.exists(target):
                        target += ".npz"
                    if target and os.path.exists(target):
                        from repro.core import faultinject as FI
                        detail = FI.corrupt_checkpoint(
                            target, seed=injector.seed, step=step,
                            mode=ev.get("mode", "bitflip"))
                        print(f"chaos: ckpt_corrupt@{step}: {detail}",
                              flush=True)
                        if telem is not None:
                            telem.event(step, "ckpt_corrupt",
                                        detail=detail)
                    else:
                        print(f"chaos: ckpt_corrupt@{step}: no "
                              f"checkpoint to corrupt, skipped",
                              flush=True)
                        if telem is not None:
                            telem.event(step, "ckpt_corrupt",
                                        detail="skipped: no checkpoint")
                elif ev.kind == "rank_loss":
                    rank_loss = ev
                elif ev.kind == "rank_recover":
                    rank_recover = ev
            if rank_loss is not None:
                # ---- recovery: shrink the mesh, re-shard, continue ----
                n = int(rank_loss.get("n", "1"))
                via = rank_loss.get("via", "online")
                print(f"chaos: rank_loss@{step}: losing {n} device(s), "
                      f"recover via={via}", flush=True)
                if telem is not None:
                    telem.event(step, "rank_loss", n=n, via=via,
                                generation=life.generation)
                life.mark_failed(n)
                snap = None
                if via == "ckpt" and args.ckpt:
                    try:
                        snap = _ckpt_snapshot(args.ckpt, cfg, axes, topts)
                        print(f"recovering from checkpoint {args.ckpt} "
                              f"(step {snap['step']})", flush=True)
                    except (ckpt.CheckpointError, KeyError, ValueError,
                            OSError) as err:
                        print(f"checkpoint unusable ({err}); falling "
                              f"back to the in-memory snapshot",
                              flush=True)
                        if telem is not None:
                            telem.event(step, "ckpt_unusable",
                                        detail=str(err)[:300])
                if snap is None:
                    snap = ST.snapshot_state(params, state, tools, topts,
                                             step=step - 1)
                es = life.reshard(cfg, topts, snap,
                                  global_batch=args.batch)
                mesh, axes, tools = es.mesh, es.axes, es.tools
                params, state = es.params, es.opt_state
                step_fn, _, _ = ST.make_train_step(cfg, mesh, axes, opt,
                                                   topts)
                if probes is not None:
                    probes = PRB.CollectiveProbes(mesh, axes, calib_hw,
                                                  injector=injector)
                    watchdog = PRB.Watchdog(probes)
                if telem is not None:
                    telem.event(step, "resharded",
                                generation=life.generation,
                                g_data=life.g_data,
                                devices=int(mesh.devices.size))
                print(f"resharded: generation {life.generation}, mesh "
                      f"{life.factors}, {mesh.devices.size} devices",
                      flush=True)
                step = snap["step"] + 1
                done = 0  # the rebuilt step recompiles; re-warm timing
                continue
            if rank_recover is not None:
                # ---- recovery: grow the mesh back, re-shard, continue --
                print(f"chaos: rank_recover@{step}: failed capacity "
                      f"returned, growing g_data back", flush=True)
                if telem is not None:
                    telem.event(step, "rank_recover",
                                generation=life.generation)
                life.mark_recovered()
                snap = ST.snapshot_state(params, state, tools, topts,
                                         step=step - 1)
                es = life.reshard(cfg, topts, snap,
                                  global_batch=args.batch)
                mesh, axes, tools = es.mesh, es.axes, es.tools
                params, state = es.params, es.opt_state
                step_fn, _, _ = ST.make_train_step(cfg, mesh, axes, opt,
                                                   topts)
                if probes is not None:
                    probes = PRB.CollectiveProbes(mesh, axes, calib_hw,
                                                  injector=injector)
                    watchdog = PRB.Watchdog(probes)
                if telem is not None:
                    telem.event(step, "resharded",
                                generation=life.generation,
                                g_data=life.g_data,
                                devices=int(mesh.devices.size))
                print(f"resharded: generation {life.generation}, mesh "
                      f"{life.factors}, {mesh.devices.size} devices",
                      flush=True)
                step = snap["step"] + 1
                done = 0  # the rebuilt step recompiles; re-warm timing
                continue

        if profile_steps and step == profile_steps[0]:
            prof_dir = os.path.join("runs", "profiles", run_name)
            jax.profiler.start_trace(prof_dir)
            prof_on = True
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, step, data, dtype=np.float32).items()}
        if dtype == jnp.bfloat16:
            batch = {k: (v.astype(jnp.bfloat16) if v.dtype == jnp.float32
                         else v) for k, v in batch.items()}
        params, state, metrics = step_fn(params, state, batch)
        if injector is not None:
            stall_s = injector.step_stall(step)
            if stall_s > 0:
                jax.block_until_ready(metrics["loss"])
                time.sleep(stall_s)  # the simulated hung collective
        if done == 0:
            jax.block_until_ready(metrics["loss"])
            t_step = t_warm = time.time()
        elif telem is not None or watchdog is not None:
            # per-step wall time needs the step's result on host; the
            # telemetry-off path keeps the async dispatch loop untouched
            jax.block_until_ready(metrics["loss"])
            now = time.time()
            step_s = now - t_step
            if telem is not None:
                telem.train_step(step, step_s,
                                 loss=float(metrics["loss"]),
                                 grad_norm=float(metrics["grad_norm"]))
            if watchdog is not None:
                if watchdog.stalled(step_s):
                    verdict = watchdog.classify(step)
                    print(f"watchdog: step {step} took {step_s * 1e3:.1f}"
                          f" ms (baseline {watchdog.baseline_s * 1e3:.1f}"
                          f" ms) -> {verdict['verdict']}"
                          f" suspects={verdict['suspects']}", flush=True)
                    if telem is not None:
                        telem.event(step, "stalled_step",
                                    step_s=step_s,
                                    baseline_s=watchdog.baseline_s,
                                    verdict=verdict["verdict"],
                                    suspects=verdict["suspects"])
                        for r in verdict["results"].values():
                            telem.probe(step, r)
                    now = time.time()  # classify fired the probes
                else:
                    # a stalled step must not drag the baseline up
                    watchdog.observe(step_s)
            t_step = now
        if (probes is not None and args.probe_every > 0 and done > 0
                and step % args.probe_every == 0):
            for r in probes.run(step).values():
                if telem is not None:
                    telem.probe(step, r)
            t_step = time.time()  # probe time is not step time
        if prof_on and step == profile_steps[1]:
            if telem is None and done > 0:
                jax.block_until_ready(metrics["loss"])
            jax.profiler.stop_trace()
            prof_on = False
            print(f"profile: steps {profile_steps[0]}..{profile_steps[1]} "
                  f"-> runs/profiles/{run_name}", flush=True)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            if done == 0:
                # the compile step's clock is dominated by tracing +
                # lowering; report as-is
                tok_s = args.batch * args.seq / max(time.time() - t0, 1e-9)
            else:
                # warm clock over the steps since the last (re)compile —
                # dividing by the t0 window would fold compile into
                # steady-state throughput and understate it
                tok_s = (done * args.batch * args.seq
                         / max(time.time() - t_warm, 1e-9))
            print(f"step {step:5d} loss {loss:.4f} gnorm {gn:.3f} "
                  f"{tok_s:,.0f} tok/s", flush=True)
            log.append({"step": step, "loss": loss, "grad_norm": gn,
                        "tok_s": tok_s})
            assert np.isfinite(loss), "NaN loss"
        if (args.ckpt and args.ckpt_every > 0 and step > 0
                and step % args.ckpt_every == 0):
            save_checkpoint(step)
        done += 1
        step += 1
    jax.block_until_ready(params)
    t_end = time.time()  # before the checkpoint write pollutes the clock
    if prof_on:
        # the window ran off the end of the run (B >= steps)
        jax.profiler.stop_trace()
    for s, h in old_handlers.items():
        signal.signal(s, h)

    if args.ckpt and done > 0:
        save_checkpoint(step - 1)
        print("saved", args.ckpt)
    if pred is not None and done > 1:
        # predicted-vs-measured validation line: the α-β model priced
        # with the --calib profile against this run's wall clock
        measured_s = (t_end - t_warm) / (done - 1)
        print(f"calib[{args.calib}]: predicted step "
              f"{pred.total * 1e3:.2f} ms (compute {pred.compute * 1e3:.2f}"
              f" + exposed {pred.exposed_comm * 1e3:.2f}), measured "
              f"{measured_s * 1e3:.2f} ms/step")
    if telem is not None:
        telem.close()
    if args.calib:
        # fold measured/predicted verdicts back into the profile
        # (probes only — the fitted constants stay untouched)
        from repro.core import calibrate as CB
        prof = CB.resolve(args.calib)
        merged = []
        if prof is not None:
            if (telem is not None and telem.drift is not None
                    and telem.drift.n):
                prof = CB.merge_drift(prof, telem.drift.record(
                    workload=f"{cfg.name}@{args.mesh}"))
                merged.append("drift")
            if probes is not None and probes.records():
                prof = CB.merge_probes(prof, probes.records())
                merged.append("probes")
            if merged:
                path = (CB.default_path() if args.calib == "auto"
                        else args.calib)
                prof.save(path)
                print(f"{'+'.join(merged)} record merged into {path}")
    if log:
        print("final loss:", log[-1]["loss"])


if __name__ == "__main__":
    main()
