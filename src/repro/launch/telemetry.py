"""Runtime telemetry: per-step metrics, MFU, and predicted-vs-measured
drift monitoring (docs/telemetry.md).

One :class:`Telemetry` recorder is shared by ``train.py``, ``serve.py``
and ``benchmarks/serving.py``. It appends schema'd JSONL records to
``runs/telemetry/<run>.jsonl`` (``--log-file`` overrides the path) and
prints a human summary table at exit. Record kinds:

  * ``meta``        — run header (arch, mesh, device count, the MFU
                      denominator constants, the predicted step time);
  * ``train_step``  — wall time (warmup-excluded), EMA, tokens/s, MFU,
                      loss/grad-norm, peak device bytes, drift ratio;
  * ``serve_step``  — one engine iteration: step kind (mixed/decode),
                      new tokens, queue depth, active slots, page-pool
                      utilization, cumulative preemptions;
  * ``drift``       — the rolling predicted-vs-measured verdict
                      (:meth:`DriftMonitor.record`) —
                      ``core.calibrate.merge_drift`` folds it back into
                      the calibration profile;
  * ``probe``       — one per-collective-class health probe firing
                      (``launch.probes``): measured vs α-β-predicted
                      time plus the jump over the class's own rolling
                      baseline;
  * ``event``       — a lifecycle/chaos event (rank loss, re-shard,
                      checkpoint corruption detected, watchdog verdict,
                      graceful shutdown) — the recovery audit trail;
  * ``summary``     — aggregates (p50/p99 step time, tokens/s, MFU,
                      peak bytes) written once at :meth:`Telemetry.close`.

**MFU** is ``model_flops_per_token(cfg) * tokens/s`` over the mesh's
aggregate peak FLOP/s — *model* flops (``6 * N_active`` per trained
token), not HLO flops, so remat recompute does not inflate it; the peak
is the calibration profile's measured GEMM throughput when ``--calib``
is given (TPU-v5e paper constants otherwise). Step timing blocks on the
step's metrics each iteration, so enabling telemetry serializes the
host loop with the device — a per-step cost the async default never
pays; the degenerate path (no ``--telemetry``) is unchanged.

**Drift** is the rolling median of measured/predicted step time, priced
by the ``--calib`` profile's ``comm_model.predict_step_time``. A ratio
drifting out of band means the analytic model no longer describes this
machine (new kernel mix, thermal throttling, a sick link) — the
ROADMAP's "collective health probes feeding the calibration profile"
direction starts here.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

SCHEMA_VERSION = 1
DEFAULT_DIR = os.path.join("runs", "telemetry")

#: required numeric fields per record kind (beyond the envelope
#: ``v``/``run``/``kind`` every record carries). Nullable fields —
#: present but possibly None — are listed separately.
SCHEMA: Dict[str, tuple] = {
    "meta": (),
    "train_step": ("step", "step_s", "ema_s", "tok_s"),
    "serve_step": ("step", "step_s", "new_tokens", "queue_depth",
                   "active", "page_util", "preemptions"),
    "drift": ("predicted_s", "measured_p50_s", "ratio", "n"),
    "probe": ("step", "measured_s", "predicted_s", "ratio", "jump"),
    "event": ("step",),
    "summary": ("steps", "wall_s"),
}
NULLABLE: Dict[str, tuple] = {
    "train_step": ("mfu", "loss", "grad_norm", "peak_bytes", "drift"),
    "probe": ("injected_s",),
}


def validate_record(rec: dict) -> None:
    """Raise ValueError unless ``rec`` is a valid telemetry record."""
    for key in ("v", "run", "kind"):
        if key not in rec:
            raise ValueError(f"record missing envelope field {key!r}: {rec}")
    if rec["v"] != SCHEMA_VERSION:
        raise ValueError(f"unknown schema version {rec['v']!r}")
    kind = rec["kind"]
    if kind not in SCHEMA:
        raise ValueError(f"unknown record kind {kind!r}")
    for field in SCHEMA[kind]:
        if field not in rec:
            raise ValueError(f"{kind} record missing {field!r}: {rec}")
        if not isinstance(rec[field], (int, float)):
            raise ValueError(
                f"{kind}.{field} must be numeric, got {rec[field]!r}")
    for field in NULLABLE.get(kind, ()):
        if field in rec and rec[field] is not None \
                and not isinstance(rec[field], (int, float)):
            raise ValueError(
                f"{kind}.{field} must be numeric or null, got "
                f"{rec[field]!r}")


def validate_file(path: str) -> int:
    """Validate every line of a telemetry JSONL file; returns the record
    count (CI asserts on this)."""
    n = 0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            validate_record(json.loads(line))
            n += 1
    if n == 0:
        raise ValueError(f"{path}: no telemetry records")
    return n


def peak_memory_bytes() -> Optional[int]:
    """Max ``peak_bytes_in_use`` over local devices, or None when the
    backend keeps no memory stats (host CPU does not)."""
    import jax
    best = None
    for d in jax.local_devices():
        try:
            stats = d.memory_stats()
        except Exception:
            stats = None
        v = (stats or {}).get("peak_bytes_in_use")
        if v is not None:
            best = v if best is None else max(best, v)
    return best


class DriftMonitor:
    """Rolling measured/predicted step-time ratio with an out-of-band
    warning (docs/telemetry.md §Drift).

    ``ratio`` is the rolling median over the last ``window`` steps —
    median, not mean, so one GC pause or checkpoint write cannot trip
    the alarm. Out of band means outside ``[1/(1+band), 1+band]`` after
    ``min_steps`` samples; :meth:`check` returns the warning message
    exactly once per excursion."""

    def __init__(self, predicted_s: float, *, window: int = 32,
                 band: float = 0.5, min_steps: int = 5):
        if predicted_s <= 0:
            raise ValueError(f"predicted_s must be > 0, got {predicted_s}")
        self.predicted_s = float(predicted_s)
        self.band = float(band)
        self.min_steps = int(min_steps)
        self.ratios: collections.deque = collections.deque(maxlen=window)
        self.n = 0
        self.warned = False

    def update(self, measured_s: float) -> float:
        """Record one measured step; returns the rolling ratio."""
        self.ratios.append(float(measured_s) / self.predicted_s)
        self.n += 1
        return self.ratio

    @property
    def ratio(self) -> float:
        if not self.ratios:
            return float("nan")
        return float(np.median(list(self.ratios)))

    @property
    def out_of_band(self) -> bool:
        if self.n < self.min_steps:
            return False
        r = self.ratio
        return r > 1.0 + self.band or r < 1.0 / (1.0 + self.band)

    def check(self) -> Optional[str]:
        """Warning message when newly out of band, else None."""
        if not self.out_of_band:
            self.warned = False
            return None
        if self.warned:
            return None
        self.warned = True
        return (f"drift: measured/predicted step time "
                f"{self.ratio:.2f}x is outside the "
                f"[{1.0 / (1.0 + self.band):.2f}, "
                f"{1.0 + self.band:.2f}] band "
                f"(predicted {self.predicted_s * 1e3:.2f} ms) — "
                f"recalibrate (python -m benchmarks.calibrate) or merge "
                f"this run's drift record (core.calibrate.merge_drift)")

    def record(self, *, workload: str = "step") -> dict:
        """The drift payload ``core.calibrate.merge_drift`` consumes."""
        return {
            "workload": workload,
            "predicted_s": self.predicted_s,
            "measured_p50_s": self.ratio * self.predicted_s,
            "ratio": self.ratio,
            "n": self.n,
            "band": self.band,
            "out_of_band": self.out_of_band,
        }


@dataclasses.dataclass
class _StepStats:
    """Warmup-excluded accumulators over one run."""
    times: List[float] = dataclasses.field(default_factory=list)
    ema_s: Optional[float] = None
    tokens: int = 0

    def push(self, step_s: float, tokens: int, alpha: float) -> float:
        self.times.append(step_s)
        self.tokens += tokens
        self.ema_s = (step_s if self.ema_s is None
                      else alpha * step_s + (1.0 - alpha) * self.ema_s)
        return self.ema_s

    def percentile(self, q: float) -> float:
        if not self.times:
            return float("nan")
        return float(np.percentile(self.times, q))


class Telemetry:
    """JSONL telemetry sink + aggregator (one instance per run).

    ``flops_per_token`` / ``peak_flops_per_device`` / ``n_devices``
    parameterize MFU (any of them 0 disables it); ``tokens_per_step``
    is the training global batch in tokens; ``drift`` is an optional
    :class:`DriftMonitor` priced from the ``--calib`` profile."""

    def __init__(self, run: str, *, path: Optional[str] = None,
                 out_dir: str = DEFAULT_DIR, tokens_per_step: int = 0,
                 flops_per_token: float = 0.0,
                 peak_flops_per_device: float = 0.0, n_devices: int = 1,
                 drift: Optional[DriftMonitor] = None, ema: float = 0.1,
                 meta: Optional[dict] = None, verbose: bool = True):
        self.run = run
        self.path = path or os.path.join(out_dir, f"{run}.jsonl")
        self.tokens_per_step = int(tokens_per_step)
        self.flops_per_token = float(flops_per_token)
        self.peak_flops = float(peak_flops_per_device) * int(n_devices)
        self.drift = drift
        self.ema_alpha = float(ema)
        self.verbose = verbose
        self.stats = _StepStats()
        self.serve_tokens = 0
        self.serve_steps = 0
        self._t0 = time.time()
        self._closed = False
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        self._f = open(self.path, "w")
        head = {"tokens_per_step": self.tokens_per_step,
                "flops_per_token": self.flops_per_token,
                "peak_flops": self.peak_flops,
                "predicted_step_s": (drift.predicted_s if drift else None),
                "t0_unix": self._t0}
        head.update(meta or {})
        self._emit("meta", head)

    # ------------------------------------------------------------------ #
    def _emit(self, kind: str, fields: dict) -> dict:
        rec = {"v": SCHEMA_VERSION, "run": self.run, "kind": kind}
        rec.update(fields)
        validate_record(rec)
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        return rec

    def mfu(self, tok_s: float) -> Optional[float]:
        if self.flops_per_token <= 0 or self.peak_flops <= 0:
            return None
        return self.flops_per_token * tok_s / self.peak_flops

    # ------------------------------------------------------------------ #
    def train_step(self, step: int, step_s: float, *,
                   loss: Optional[float] = None,
                   grad_norm: Optional[float] = None) -> dict:
        """Record one warm optimizer step (callers exclude step 0: its
        wall time is compile, not steady state)."""
        ema = self.stats.push(step_s, self.tokens_per_step, self.ema_alpha)
        tok_s = self.tokens_per_step / max(step_s, 1e-12)
        ratio = None
        if self.drift is not None:
            self.drift.update(step_s)
            ratio = self.drift.ratio
            msg = self.drift.check()
            if msg and self.verbose:
                print(f"WARNING [{self.run}] {msg}", flush=True)
        return self._emit("train_step", {
            "step": step, "step_s": step_s, "ema_s": ema, "tok_s": tok_s,
            "mfu": self.mfu(tok_s), "loss": loss, "grad_norm": grad_norm,
            "peak_bytes": peak_memory_bytes(), "drift": ratio})

    def probe(self, step: int, result) -> dict:
        """Record one collective-probe firing (``launch.probes
        .ProbeResult``)."""
        return self._emit("probe", {
            "step": int(step), "cls": result.cls,
            "collective": result.kind,
            "p": int(result.p), "elems": int(result.elems),
            "measured_s": float(result.measured_s),
            "predicted_s": float(result.predicted_s),
            "ratio": float(result.ratio), "jump": float(result.jump),
            "injected_s": (float(result.injected_s)
                           if result.injected_s else None)})

    def event(self, step: int, event: str, **fields) -> dict:
        """Record a lifecycle/chaos event (free-form string/number
        fields beyond the required ``step``) — the recovery audit
        trail chaos tests and operators read back."""
        return self._emit("event", dict({"step": int(step),
                                         "event": str(event)}, **fields))

    def serve_step(self, step: int, step_s: float, *, new_tokens: int,
                   queue_depth: int, active: int, page_util: float,
                   preemptions: int, step_kind: str = "decode") -> dict:
        """Record one engine iteration (``preemptions`` cumulative)."""
        self.stats.push(step_s, new_tokens, self.ema_alpha)
        self.serve_tokens += int(new_tokens)
        self.serve_steps += 1
        return self._emit("serve_step", {
            "step": step, "step_s": step_s, "step_kind": step_kind,
            "new_tokens": int(new_tokens), "queue_depth": int(queue_depth),
            "active": int(active), "page_util": float(page_util),
            "preemptions": int(preemptions)})

    # ------------------------------------------------------------------ #
    def close(self, extra: Optional[dict] = None) -> dict:
        """Write the drift + summary records, print the human table, and
        close the file. ``extra`` fields override the computed summary
        (the serving callers pass the engine's own tokens/s so the JSONL
        and runs/perf/serving.csv agree by construction)."""
        if self._closed:
            return {}
        self._closed = True
        wall = time.time() - self._t0
        n = len(self.stats.times)
        p50, p99 = self.stats.percentile(50), self.stats.percentile(99)
        tok_s = (self.stats.tokens / sum(self.stats.times)
                 if self.stats.times and sum(self.stats.times) > 0 else None)
        summary = {
            "steps": n, "wall_s": wall, "step_p50_s": p50,
            "step_p99_s": p99, "ema_s": self.stats.ema_s,
            "tok_s": tok_s, "mfu": self.mfu(tok_s) if tok_s else None,
            "peak_bytes": peak_memory_bytes(),
        }
        drift_rec = None
        if self.drift is not None and self.drift.n:
            drift_rec = self.drift.record()
            self._emit("drift", drift_rec)
            summary["drift"] = drift_rec["ratio"]
        summary.update(extra or {})
        rec = self._emit("summary", summary)
        self._f.close()
        if self.verbose:
            self._print_table(summary)
        return rec

    def _print_table(self, s: dict) -> None:
        def fmt(k, v):
            if v is None:
                return "-"
            if k == "tok_s":
                return f"{v:,.0f}"
            if k.endswith("_s") and k != "steps":
                return f"{v * 1e3:,.2f} ms"
            if k == "mfu":
                return f"{v * 100:.2f}%"
            if k == "peak_bytes":
                return f"{v / 2**20:,.1f} MiB"
            if isinstance(v, float):
                return f"{v:,.3f}"
            return str(v)
        print(f"telemetry [{self.run}] -> {self.path}")
        for k in ("steps", "step_p50_s", "step_p99_s", "ema_s", "tok_s",
                  "mfu", "peak_bytes", "drift"):
            if k in s:
                print(f"  {k:<12} {fmt(k, s[k])}")
