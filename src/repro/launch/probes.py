"""Per-collective health probes + a hung-step watchdog.

PR 8's :class:`launch.telemetry.DriftMonitor` watches the *whole-step*
measured/predicted ratio; this module drops to per-collective-class
granularity — one tiny jitted probe program per ``comm_model``
collective class actually present on the mesh:

  * ``z_ring``   — the z-axis weight ring (``ring_all_gather``);
  * ``xy_ar``    — the activation all-reduce over the wider of x/y;
  * ``seq_ring`` — the context-parallel KV circulation
                   (``ring_exchange`` hops over the seq axis);
  * ``dp_rs_ag`` — the ZeRO data-axis round trip (reduce-scatter then
                   all-gather over the flattened data ring).

Each class carries two independent judgments:

  * a **DriftMonitor** against ``comm_model.collective_time`` priced by
    the ``--calib`` profile — the absolute calibrated verdict, merged
    into ``profile.probes`` as ``drift:collective:<class>`` via
    ``calibrate.merge_drift`` (see :meth:`CollectiveProbes.merge_into`);
  * a **rolling self-baseline** (median of this run's own probe times)
    — the relative verdict the :class:`Watchdog` uses to classify a
    stalled step as hung-collective vs slow-compute, meaningful even on
    an uncalibrated host where the absolute ratios are off by design.

The probe programs are separate jitted computations and never touch
``core.trace`` state, so the training step's HLO is byte-identical
whether probes run or not; with probes off nothing here is even built.

Fault injection: ``core.faultinject.FaultInjector.probe_delay`` sleeps
*inside* a probe's timed window, simulating a hung collective the same
way a sick link would surface — as that class's wall time, nothing
else's.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core import comm_model as CM
from repro.core import mesh as M
from repro.core.compat import shard_map
from repro.launch.telemetry import DriftMonitor

PROBE_CLASSES = ("z_ring", "xy_ar", "seq_ring", "dp_rs_ag")


@dataclasses.dataclass(frozen=True)
class ProbeResult:
    """One probe firing: absolute (vs the α-β model) and relative (vs
    this run's own history) views of a collective class's health."""

    cls: str
    kind: str            # comm_model collective kind
    p: int               # ring size
    elems: int           # buffer elements (comm_model conventions)
    measured_s: float
    predicted_s: float
    ratio: float         # rolling measured/predicted (DriftMonitor)
    jump: float          # measured / rolling self-baseline median
    injected_s: float    # simulated stall included in measured_s


def _axis_p(axes: M.MeshAxes, logical: str) -> int:
    return {"data": axes.dp, "x": axes.gx, "y": axes.gy, "z": axes.gz,
            "seq": axes.gseq}[logical]


class CollectiveProbes:
    """Builds and times one probe program per collective class present
    on ``(mesh, axes)``; classes whose ring size is 1 are skipped."""

    def __init__(self, mesh, axes: M.MeshAxes, hw: CM.HardwareParams = None,
                 *, elems: int = 1 << 14, window: int = 16,
                 band: float = 1.0, min_steps: int = 2, injector=None):
        from jax.sharding import PartitionSpec as P
        self.axes = axes
        self.hw = hw if hw is not None else CM.TPU_V5E
        self.injector = injector
        self._fns: Dict[str, Callable] = {}
        self._bufs: Dict[str, np.ndarray] = {}
        self.meta: Dict[str, dict] = {}      # cls -> kind/p/elems
        self.monitors: Dict[str, DriftMonitor] = {}
        self._hist: Dict[str, collections.deque] = {}
        self._warm = False

        def wrap(body, in_spec, out_spec):
            return jax.jit(shard_map(body, mesh=mesh, in_specs=(in_spec,),
                                     out_specs=out_spec, check_vma=False))

        def add(cls, kind, axis, p, fn, in_spec, out_spec, n, pred):
            if p <= 1 or pred <= 0:
                return
            self._fns[cls] = wrap(fn, in_spec, out_spec)
            self._bufs[cls] = np.arange(n, dtype=np.float32)
            self.meta[cls] = dict(kind=kind, p=p, elems=n)
            self.monitors[cls] = DriftMonitor(pred, window=window,
                                              band=band,
                                              min_steps=min_steps)
            self._hist[cls] = collections.deque(maxlen=window)

        # z ring: the weight-gather class (paper §3.2)
        p = _axis_p(axes, "z")
        if p > 1:
            n = -(-elems // p) * p
            add("z_ring", "all_gather", axes.z, p,
                lambda v: M.ring_all_gather(v, axes.z, dim=0),
                P(axes.z), P(None), n,
                CM.collective_time("all_gather", p, n, self.hw))
        # x/y all-reduce: the activation-reduction class; probe the
        # wider of the two rings (the one that dominates the model)
        ax = "x" if _axis_p(axes, "x") >= _axis_p(axes, "y") else "y"
        p = _axis_p(axes, ax)
        if p > 1:
            axis = axes.axis(ax)
            add("xy_ar", "all_reduce", axis, p,
                lambda v: M.ring_all_reduce(v, axis, dim=0),
                P(None), P(None), elems,
                CM.collective_time("all_reduce", p, elems, self.hw))
        # seq KV ring: each rank's block circulates all p-1 hops
        p = _axis_p(axes, "seq")
        if p > 1:
            axis = axes.seq
            block = -(-elems // p)

            def seq_ring(v, _axis=axis, _p=p):
                cur, acc = v, v
                for _ in range(_p - 1):
                    cur = M.ppermute_ring(cur, _axis)
                    acc = acc + cur
                return acc
            add("seq_ring", "ring_exchange", axis, p, seq_ring,
                P(axis), P(axis), block * p,
                CM.collective_time("ring_exchange", p, block, self.hw))
        # DP reduce-scatter + all-gather: the ZeRO round trip over the
        # flattened data ring
        p = axes.dp
        if p > 1:
            axis = axes.data
            n = -(-elems // p) * p

            def rs_ag(v, _axis=axis):
                s = M.ring_reduce_scatter(v, _axis, dim=0)
                return M.ring_all_gather(s, _axis, dim=0)
            add("dp_rs_ag", "reduce_scatter", axis, p, rs_ag,
                P(None), P(None), n,
                CM.collective_time("reduce_scatter", p, n, self.hw)
                + CM.collective_time("all_gather", p, n, self.hw))

    @property
    def classes(self) -> List[str]:
        return list(self._fns)

    def warmup(self) -> None:
        """Compile every probe (excluded from the monitors/baselines)."""
        for cls, fn in self._fns.items():
            jax.block_until_ready(fn(self._bufs[cls]))
        self._warm = True

    def run(self, step: int = 0) -> Dict[str, ProbeResult]:
        """Time every probe once; feeds the monitors and baselines."""
        if not self._warm:
            self.warmup()
        out: Dict[str, ProbeResult] = {}
        for cls, fn in self._fns.items():
            delay = (self.injector.probe_delay(step, cls)
                     if self.injector is not None else 0.0)
            t0 = time.perf_counter()
            res = fn(self._bufs[cls])
            if delay > 0:
                time.sleep(delay)  # the simulated hung collective
            jax.block_until_ready(res)
            measured = time.perf_counter() - t0
            mon = self.monitors[cls]
            ratio = mon.update(measured)
            hist = self._hist[cls]
            base = float(np.median(list(hist))) if hist else measured
            hist.append(measured)
            out[cls] = ProbeResult(
                cls=cls, measured_s=measured, ratio=ratio,
                predicted_s=mon.predicted_s,
                jump=measured / max(base, 1e-12), injected_s=delay,
                **self.meta[cls])
        return out

    def records(self) -> List[dict]:
        """Per-class drift payloads for ``calibrate.merge_drift``, keyed
        ``collective:<class>``."""
        return [mon.record(workload=f"collective:{cls}")
                for cls, mon in self.monitors.items() if mon.n]

    def merge_into(self, profile):
        """Fold every class's verdict into ``profile.probes``
        (``drift:collective:<class>`` keys)."""
        from repro.core import calibrate as CB
        return CB.merge_probes(profile, self.records())


class Watchdog:
    """Classifies a stalled training step: hung collective or just slow
    compute?

    ``observe`` feeds warm step times; a step is *stalled* when it
    exceeds ``factor`` x the rolling median. ``classify`` then fires
    every collective probe and blames the classes whose own time jumped
    by ``factor`` over their self-baseline — a hung collective stalls
    its class's probe the same way it stalls the step, while slow
    compute (thermal throttling, a noisy neighbor on the host) leaves
    the tiny probe programs untouched.
    """

    def __init__(self, probes: Optional[CollectiveProbes] = None, *,
                 factor: float = 3.0, window: int = 32,
                 min_steps: int = 3):
        self.probes = probes
        self.factor = float(factor)
        self.min_steps = int(min_steps)
        self.times: collections.deque = collections.deque(maxlen=window)

    def observe(self, step_s: float) -> None:
        self.times.append(float(step_s))

    @property
    def baseline_s(self) -> float:
        if not self.times:
            return float("nan")
        return float(np.median(list(self.times)))

    def stalled(self, step_s: float) -> bool:
        if len(self.times) < self.min_steps:
            return False
        return float(step_s) > self.factor * self.baseline_s

    def classify(self, step: int = 0) -> dict:
        """Verdict for a stalled step. Returns ``{"verdict":
        "hung_collective"|"slow_compute", "suspects": [cls...],
        "results": {cls: ProbeResult}}``."""
        if self.probes is None:
            return {"verdict": "slow_compute", "suspects": [],
                    "results": {}}
        results = self.probes.run(step)
        suspects = [cls for cls, r in results.items()
                    if r.jump > self.factor]
        return {"verdict": ("hung_collective" if suspects
                            else "slow_compute"),
                "suspects": suspects, "results": results}
