"""Production mesh factories.

``make_production_mesh`` is the assignment-mandated mesh: 16x16
(data, model) per pod, 2x16x16 (pod, data, model) multi-pod. On it the
``model`` axis is bound to the logical ``x`` axis — the Megatron-LM
degenerate point of the paper's algorithm (1D TP), which doubles as the
paper's own baseline.

``make_production_mesh_4d`` factors the same 256/512 devices into
(pod,) data x x x y x z for the paper's 4D decomposition. The factors
default to the communication-model optimum for the given architecture.

Importing this module never touches jax device state: both are functions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core import mesh as M
from repro.core import compat as C


def _mk(shape, names):
    return C.make_mesh(shape, names,
                       axis_types=C.default_axis_types(len(names)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def bind_production(mesh, cfg=None) -> M.MeshAxes:
    """Bind the (pod,) data/model mesh to logical axes at the Megatron-LM
    degenerate point: the text's "G_c = G_tensor makes it identical to
    Megatron-LM" — our y = model, x = z = 1. QKV becomes column-parallel,
    the out/down projections row-parallel (all-reduce over y), vocab
    sharded over y: exactly Megatron's schedule.

    Architectures whose head counts cannot use a 16-way y axis (whisper's
    12 heads, xlstm's 4) fall back to the x-degenerate 1D point
    (G_r = G_tensor): feature-sharded weights, all-reduce over x — the
    other corner of the paper's Fig. 5 sweep."""
    data = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    axes_y = M.bind_axes(mesh, data=data, y="model")
    if cfg is None or cfg.axes_ok(axes_y) is None:
        return axes_y
    axes_x = M.bind_axes(mesh, data=data, x="model")
    if cfg.axes_ok(axes_x) is None:
        return axes_x
    raise ValueError(f"{cfg.name}: no 1D binding fits the production mesh "
                     f"({cfg.axes_ok(axes_y)}; {cfg.axes_ok(axes_x)})")


def make_production_mesh_4d(g_data: int, g_x: int, g_y: int, g_z: int,
                            g_seq: int = 1, *, multi_pod: bool = False):
    """(pod,) data x x x y x z (x seq) with the same device counts
    (256 / 512). ``g_seq`` joins the product (context parallelism is a
    5th factor of the same budget) and only appears as a mesh axis when
    > 1, so every 4-factor caller keeps its exact old mesh."""
    per_pod = g_data * g_x * g_y * g_z * g_seq
    assert per_pod == 256, \
        f"4D factors must multiply to 256 per pod, got {per_pod}"
    shape: Tuple[int, ...] = (g_data, g_x, g_y, g_z)
    names: Tuple[str, ...] = ("data", "x", "y", "z")
    if g_seq > 1:
        shape += (g_seq,)
        names += ("seq",)
    if multi_pod:
        return _mk((2,) + shape, ("pod",) + names)
    return _mk(shape, names)


def bind_4d(mesh) -> M.MeshAxes:
    seq = "seq" if "seq" in mesh.axis_names else None
    if "pod" in mesh.axis_names:
        return M.bind_axes(mesh, data=("pod", "data"), x="x", y="y", z="z",
                           seq=seq)
    return M.bind_axes(mesh, data=("data",), x="x", y="y", z="z", seq=seq)


def make_smoke_mesh(shape: Tuple[int, ...] = (2, 2, 2, 1),
                    names=("data", "x", "y", "z")):
    """Small host-device mesh for CPU tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller)."""
    return _mk(shape, names)


def optimal_4d_factors(cfg, shape, g: int = 256,
                       min_tensor: int = 1) -> Tuple[int, int, int, int]:
    """Pick (g_data, g_x, g_y, g_z) by the paper's communication model."""
    from repro.core import comm_model as CM
    cons = cfg.tp_constraints(shape.global_batch)
    cons = CM.Constraints(
        global_batch=cons.global_batch, x_divides=cons.x_divides,
        y_divides=cons.y_divides, min_tensor=min_tensor)
    tokens = shape.global_batch * shape.seq_len
    best = CM.optimize_decomposition(list(cfg.comm_layers()), tokens, g,
                                     cons, top_k=1)[0][0]
    return best.g_data, best.g_x, best.g_y, best.g_z
