"""Production mesh factories.

``make_production_mesh`` is the assignment-mandated mesh: 16x16
(data, model) per pod, 2x16x16 (pod, data, model) multi-pod. On it the
``model`` axis is bound to the logical ``x`` axis — the Megatron-LM
degenerate point of the paper's algorithm (1D TP), which doubles as the
paper's own baseline.

``make_production_mesh_4d`` factors the same 256/512 devices into
(pod,) data x x x y x z for the paper's 4D decomposition. The factors
default to the communication-model optimum for the given architecture.

``MeshLifecycle`` wraps the same factories in an elastic lifecycle:
device discovery, 6-factor binding, failure tracking, and online
re-sharding of the data axis between steps (grow/shrink ``g_data``
without a process restart — docs/fault_tolerance.md).

Importing this module never touches jax device state: everything is a
function or a lazily-building object.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import mesh as M
from repro.core import compat as C


def _mk(shape, names):
    return C.make_mesh(shape, names,
                       axis_types=C.default_axis_types(len(names)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def bind_production(mesh, cfg=None) -> M.MeshAxes:
    """Bind the (pod,) data/model mesh to logical axes at the Megatron-LM
    degenerate point: the text's "G_c = G_tensor makes it identical to
    Megatron-LM" — our y = model, x = z = 1. QKV becomes column-parallel,
    the out/down projections row-parallel (all-reduce over y), vocab
    sharded over y: exactly Megatron's schedule.

    Architectures whose head counts cannot use a 16-way y axis (whisper's
    12 heads, xlstm's 4) fall back to the x-degenerate 1D point
    (G_r = G_tensor): feature-sharded weights, all-reduce over x — the
    other corner of the paper's Fig. 5 sweep."""
    data = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    axes_y = M.bind_axes(mesh, data=data, y="model")
    if cfg is None or cfg.axes_ok(axes_y) is None:
        return axes_y
    axes_x = M.bind_axes(mesh, data=data, x="model")
    if cfg.axes_ok(axes_x) is None:
        return axes_x
    raise ValueError(f"{cfg.name}: no 1D binding fits the production mesh "
                     f"({cfg.axes_ok(axes_y)}; {cfg.axes_ok(axes_x)})")


def make_production_mesh_4d(g_data: int, g_x: int, g_y: int, g_z: int,
                            g_seq: int = 1, g_expert: int = 1, *,
                            multi_pod: bool = False):
    """(pod,) data x x x y x z (x seq) (x expert) with the same device
    counts (256 / 512). ``g_seq`` and ``g_expert`` join the product
    (context and expert parallelism are 5th/6th factors of the same
    budget) and only appear as mesh axes when > 1, so every 4-factor
    caller keeps its exact old mesh."""
    per_pod = g_data * g_x * g_y * g_z * g_seq * g_expert
    assert per_pod == 256, \
        f"4D factors must multiply to 256 per pod, got {per_pod}"
    shape: Tuple[int, ...] = (g_data, g_x, g_y, g_z)
    names: Tuple[str, ...] = ("data", "x", "y", "z")
    if g_seq > 1:
        shape += (g_seq,)
        names += ("seq",)
    if g_expert > 1:
        shape += (g_expert,)
        names += ("expert",)
    if multi_pod:
        return _mk((2,) + shape, ("pod",) + names)
    return _mk(shape, names)


def bind_4d(mesh) -> M.MeshAxes:
    seq = "seq" if "seq" in mesh.axis_names else None
    expert = "expert" if "expert" in mesh.axis_names else None
    if "pod" in mesh.axis_names:
        return M.bind_axes(mesh, data=("pod", "data"), x="x", y="y", z="z",
                           seq=seq, expert=expert)
    return M.bind_axes(mesh, data=("data",), x="x", y="y", z="z", seq=seq,
                       expert=expert)


def make_smoke_mesh(shape: Tuple[int, ...] = (2, 2, 2, 1),
                    names=("data", "x", "y", "z")):
    """Small host-device mesh for CPU tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller)."""
    return _mk(shape, names)


# ---------------------------------------------------------------------- #
# elastic mesh lifecycle
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ElasticState:
    """What :meth:`MeshLifecycle.reshard` hands back to the train loop:
    the rebuilt mesh/axes plus the run state re-sharded onto them (in the
    layout the step function of the run's ``TrainOptions`` expects)."""

    mesh: Any
    axes: M.MeshAxes
    tools: Any          # launch.steps.GradSyncTools (None when unsharded)
    params: Any
    opt_state: Any


class MeshLifecycle:
    """Owns the device pool and the 6-factor mesh across a run's life.

    States::

        init --build()--> active --mark_failed()--> degraded
        degraded/active --reshard()/rebuild()--> active   (generation+1)
        any --stop()--> stopped

    The lifecycle only ever changes **g_data**: the tensor factors
    (g_x, g_y, g_z, g_seq, g_expert) shard *within* a model replica
    (the expert axis holds a share of the expert bank), so losing a
    rank of a replica kills the whole replica — the natural elastic
    move is dropping (or re-adding) data-parallel replicas.
    :meth:`replan` picks the largest ``g_data`` that fits the surviving
    devices and keeps the global batch divisible by
    ``batch_shards x overdecompose``; :meth:`reshard` then rebuilds the
    mesh over the surviving device prefix and re-shards a host
    replicated-layout snapshot (``launch.steps.snapshot_state``) onto
    it through the exact path checkpoints use — so the online re-shard
    is bitwise-equal to a save/restore round trip by construction.

    Generation 0 on an intact pool builds the byte-identical mesh of
    ``make_smoke_mesh``/``make_production_mesh_4d``: swapping a fixed
    mesh for a lifecycle changes no HLO until a failure actually fires.
    """

    STATES = ("init", "active", "degraded", "resharding", "stopped")

    def __init__(self, g_data: int, g_x: int, g_y: int, g_z: int,
                 g_seq: int = 1, g_expert: int = 1, *,
                 devices: Optional[Sequence] = None):
        self.g_data, self.g_x, self.g_y, self.g_z, self.g_seq = \
            int(g_data), int(g_x), int(g_y), int(g_z), int(g_seq)
        self.g_expert = int(g_expert)
        self._devices = list(devices) if devices is not None else None
        self._failed: set = set()            # device ids marked lost
        self.state = "init"
        self.generation = 0
        self.mesh = None
        self.axes: Optional[M.MeshAxes] = None
        self.log: List[Dict[str, Any]] = []  # lifecycle event records

    # -- device pool ---------------------------------------------------- #

    @property
    def devices(self) -> List:
        if self._devices is None:
            self._devices = list(jax.devices())  # discovery, once
        return self._devices

    @property
    def surviving(self) -> List:
        return [d for d in self.devices if d.id not in self._failed]

    @property
    def failed_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(self._failed))

    @property
    def factors(self) -> Tuple[int, int, int, int, int, int]:
        return (self.g_data, self.g_x, self.g_y, self.g_z, self.g_seq,
                self.g_expert)

    @property
    def required(self) -> int:
        return (self.g_data * self.g_x * self.g_y * self.g_z * self.g_seq
                * self.g_expert)

    @property
    def tensor(self) -> int:
        """Devices per model replica (the factors a rank loss cannot
        shrink)."""
        return self.g_x * self.g_y * self.g_z * self.g_seq * self.g_expert

    def _event(self, event: str, **kw) -> None:
        self.log.append(dict(event=event, state=self.state,
                             generation=self.generation,
                             factors=list(self.factors),
                             surviving=len(self.surviving), **kw))

    # -- state transitions ---------------------------------------------- #

    def build(self):
        """(Re)build the mesh over the surviving device prefix; returns
        ``(mesh, axes)`` and moves to ``active``."""
        if self.state == "stopped":
            raise RuntimeError("MeshLifecycle is stopped")
        need, surv = self.required, self.surviving
        if len(surv) < need:
            raise RuntimeError(
                f"mesh {self.factors} needs {need} devices; only "
                f"{len(surv)} of {len(self.devices)} surviving "
                f"(failed ids: {self.failed_ids})")
        shape: Tuple[int, ...] = (self.g_data, self.g_x, self.g_y, self.g_z)
        names: Tuple[str, ...] = ("data", "x", "y", "z")
        if self.g_seq > 1:
            shape += (self.g_seq,)
            names += ("seq",)
        if self.g_expert > 1:
            shape += (self.g_expert,)
            names += ("expert",)
        if not self._failed and need == len(self.devices) \
                and self._devices is not None:
            # intact pool covering every device: the legacy factory path,
            # so generation 0 is byte-identical to make_smoke_mesh
            self.mesh = _mk(shape, names)
        else:
            self.mesh = C.make_mesh(
                shape, names, axis_types=C.default_axis_types(len(names)),
                devices=surv[:need])
        self.axes = bind_4d(self.mesh)
        self.generation += 1
        self.state = "active"
        self._event("build")
        return self.mesh, self.axes

    def mark_failed(self, n: int = 1, *, ids: Optional[Sequence[int]] = None
                    ) -> Tuple[int, ...]:
        """Record device loss: explicit ``ids``, or the last ``n``
        surviving devices (deterministic, keeps the surviving prefix
        stable). Moves to ``degraded``; the mesh itself is rebuilt by
        the next :meth:`reshard`/:meth:`build`."""
        if ids is None:
            surv = self.surviving
            ids = [d.id for d in surv[len(surv) - int(n):]]
        before = set(self._failed)
        self._failed.update(int(i) for i in ids)
        self.state = "degraded"
        self._event("mark_failed", ids=sorted(set(self._failed) - before))
        return tuple(sorted(set(self._failed) - before))

    def mark_recovered(self, ids: Optional[Sequence[int]] = None) -> None:
        """Clear failure marks (device replaced / transient loss healed);
        the pool can then grow back via :meth:`reshard`."""
        if ids is None:
            self._failed.clear()
        else:
            self._failed.difference_update(int(i) for i in ids)
        if self.mesh is not None and len(self.surviving) >= self.required:
            self.state = "active"
        self._event("mark_recovered")

    def stop(self) -> None:
        self.state = "stopped"
        self._event("stop")

    # -- elastic replanning --------------------------------------------- #

    def replan(self, *, global_batch: Optional[int] = None,
               overdecompose: int = 1) -> Dict[str, int]:
        """Largest feasible ``g_data`` for the surviving device count.

        Feasible means ``g_data x tensor <= surviving`` and — when
        ``global_batch`` is given — the overdecompose divisibility rule
        holds: ``global_batch % (g_data x g_z x g_expert x
        overdecompose) == 0`` (each data x z x expert batch shard splits
        into ``overdecompose`` microbatches;
        ``core.overdecompose.split_batch``)."""
        cap = len(self.surviving) // self.tensor
        if cap < 1:
            raise RuntimeError(
                f"{len(self.surviving)} surviving devices cannot hold one "
                f"model replica (tensor factors x*y*z*seq*expert = "
                f"{self.tensor})")
        for gd in range(cap, 0, -1):
            shards = gd * self.g_z * self.g_expert * overdecompose
            if global_batch is None or global_batch % shards == 0:
                return dict(g_data=gd, g_x=self.g_x, g_y=self.g_y,
                            g_z=self.g_z, g_seq=self.g_seq,
                            g_expert=self.g_expert)
        raise RuntimeError(
            f"no g_data in 1..{cap} divides global batch {global_batch} "
            f"by g_data x g_z({self.g_z}) x overdecompose({overdecompose})")

    def reshard(self, cfg, opts, snapshot, *,
                global_batch: Optional[int] = None,
                overdecompose: Optional[int] = None) -> ElasticState:
        """Online elastic re-shard: replan ``g_data`` for the surviving
        devices, rebuild the mesh, and restore ``snapshot`` (a host
        replicated-layout snapshot from ``launch.steps.snapshot_state``)
        onto it — the in-memory equivalent of a
        ``ckpt.save_sharded``/``restore_sharded`` round trip, bitwise.

        ``cfg``/``opts`` are the run's ArchConfig and TrainOptions; the
        caller rebuilds its jitted step function against the returned
        mesh/axes (a new g_data is a new program either way)."""
        from repro.launch import steps as ST  # lazy: keep import light
        od = (opts.overdecompose if overdecompose is None
              else int(overdecompose))
        new = self.replan(global_batch=global_batch, overdecompose=od)
        old = self.g_data
        self.state = "resharding"
        self._event("reshard", g_data_from=old, g_data_to=new["g_data"])
        self.g_data = new["g_data"]
        mesh, axes = self.build()
        tools = (ST.make_gradsync_tools(cfg, mesh, axes, opts)
                 if opts.gradsync.state_sharded else None)
        params, opt_state = ST.restore_state(snapshot, cfg, mesh, axes,
                                             tools, opts)
        return ElasticState(mesh=mesh, axes=axes, tools=tools,
                            params=params, opt_state=opt_state)


def optimal_4d_factors(cfg, shape, g: int = 256,
                       min_tensor: int = 1) -> Tuple[int, int, int, int]:
    """Pick (g_data, g_x, g_y, g_z) by the paper's communication model."""
    from repro.core import comm_model as CM
    cons = cfg.tp_constraints(shape.global_batch)
    cons = CM.Constraints(
        global_batch=cons.global_batch, x_divides=cons.x_divides,
        y_divides=cons.y_divides, min_tensor=min_tensor)
    tokens = shape.global_batch * shape.seq_len
    best = CM.optimize_decomposition(list(cfg.comm_layers()), tokens, g,
                                     cons, top_k=1)[0][0]
    return best.g_data, best.g_x, best.g_y, best.g_z
