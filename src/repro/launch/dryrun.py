import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
against the production meshes, proving the 4D sharding config is coherent
without hardware — then extract the roofline terms (launch.roofline).

Meshes:
  * baseline-1d : the assignment-mandated 16x16 ("data","model") mesh at
    the Megatron-LM degenerate point (the paper's baseline),
  * tensor4d    : the same 256 devices factored (data, x, y, z) by the
    paper's communication model (launch.mesh.optimal_4d_factors),
and each optionally with the leading pod axis (2x... = 512 devices).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--mesh tensor4d]
Results append to runs/dryrun/results.jsonl (one JSON record per combo).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, SHAPES, get_config, skip_reason
from repro.core import calibrate as CB
from repro.core.gradsync import GradSyncConfig
from repro.core.overlap import OverlapConfig
from repro.core.partition import spec_tree_to_pspecs
from repro.launch import mesh as LM
from repro.launch import roofline as RL
from repro.launch import steps as ST
from repro.models import decoder as D
from repro.models import encdec as ED
from repro.optim import adamw as OPT


def _sharded_struct(mesh, struct, spec):
    return jax.ShapeDtypeStruct(struct.shape, struct.dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_structs(mesh, tree_with_specs):
    """(struct, spec) tree -> sharded ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda t: _sharded_struct(mesh, t[0], t[1]), tree_with_specs,
        is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
        and isinstance(t[0], jax.ShapeDtypeStruct))


def input_specs(cfg, axes, mesh, shape, *, seqshard=False):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    kind = shape.kind
    if kind == "train":
        bt = ST.batch_struct(cfg, axes, shape.global_batch, shape.seq_len,
                             kind="train")
        return _tree_structs(mesh, bt)
    if kind == "prefill":
        bt = ST.batch_struct(cfg, axes, shape.global_batch, shape.seq_len,
                             kind="prefill")
        return _tree_structs(mesh, bt)
    # decode: one token + full cache
    tok_spec = (P(None, None) if seqshard
                else axes.pspec(axes.batch_axes(), None))
    toks = _sharded_struct(
        mesh, jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        tok_spec)
    if cfg.arch_type == "audio":
        ct = ED.encdec_cache_specs(cfg, axes, shape.global_batch,
                                   shape.seq_len)
    else:
        ct = D.decoder_cache_specs(cfg, axes, shape.global_batch,
                                   shape.seq_len, seqshard=seqshard)
    caches = _tree_structs(mesh, ct)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"tokens": toks, "caches": caches, "pos": pos}


def _make_lowered(cfg, shape, mesh, axes, *, unroll: bool,
                  overdecompose: int, xent_chunks: int, seqshard: bool,
                  remat_policy: str = "full",
                  overlap: OverlapConfig = OverlapConfig(),
                  gradsync: GradSyncConfig = GradSyncConfig()):
    """Lower the step for this shape kind; returns the Lowered object."""
    ins = input_specs(cfg, axes, mesh, shape, seqshard=seqshard)
    if shape.kind == "train":
        topts = ST.TrainOptions(overdecompose=overdecompose,
                                xent_chunks=xent_chunks,
                                unroll_layers=unroll,
                                remat_policy=remat_policy, overlap=overlap,
                                gradsync=gradsync)
        step, pspecs, spspecs = ST.make_train_step(
            cfg, mesh, axes, OPT.AdamWConfig(), topts)
        # params in the layout the step expects (ZeRO-3 shard tree vs
        # replicated-over-data)
        pstructs, _ = ST.abstract_params(cfg, axes, topts)
        params = jax.tree.map(lambda st, sp: _sharded_struct(mesh, st, sp),
                              pstructs, pspecs)
        # the state layout (ZeRO-sharded buckets vs per-leaf replicated)
        # follows the gradsync config
        state = ST.abstract_opt_state(cfg, axes, topts)
        sstructs = jax.tree.map(
            lambda st, sp: _sharded_struct(mesh, st, sp), state, spspecs)
        return step.lower(params, sstructs, ins)
    if shape.kind == "prefill":
        build, pspecs = ST.make_prefill_step(cfg, mesh, axes, unroll=unroll,
                                             overlap=overlap)
        fn, bt, ct = build(shape.global_batch, shape.seq_len, shape.seq_len)
        params, _ = ST.init_model(cfg, axes, abstract=True)
        params = jax.tree.map(lambda st, sp: _sharded_struct(mesh, st, sp),
                              params, pspecs)
        caches = _tree_structs(mesh, ct)
        return fn.lower(params, caches, ins)
    build, pspecs = ST.make_decode_step(cfg, mesh, axes, seqshard=seqshard,
                                        unroll=unroll, overlap=overlap)
    fn, ct = build(shape.global_batch, shape.seq_len)
    params, _ = ST.init_model(cfg, axes, abstract=True)
    params = jax.tree.map(lambda st, sp: _sharded_struct(mesh, st, sp),
                          params, pspecs)
    return fn.lower(params, ins["caches"], ins["tokens"], ins["pos"])


def _tree_bytes_per_rank(mesh, structs, pspecs) -> int:
    """Per-device persistent bytes of a (struct, PartitionSpec) tree —
    the param/optimizer-state accounting the ZeRO modes move (replicated
    vs ZeRO-1 vs ZeRO-3; EXPERIMENTS.md §Memory)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat_s = jax.tree.leaves(structs)
    flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    total = 0
    for st, sp in zip(flat_s, flat_p):
        div = 1
        for entry in tuple(sp):
            if entry is None:
                continue
            for nm in (entry if isinstance(entry, tuple) else (entry,)):
                div *= sizes.get(nm, 1)
        n = 1
        for d in st.shape:
            n *= int(d)
        total += (n // div) * jnp.dtype(st.dtype).itemsize
    return int(total)


def _raw_terms(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    stats = RL.parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "hbm": float(cost.get("bytes accessed", 0.0)),
            "coll": dict(stats.bytes_by_kind),
            "counts": dict(stats.counts)}


def _probe_plan(cfg):
    """(probe_cfgs, expansion) for exact linear extrapolation of HLO costs
    to full depth: total = base + sum_j mult_j * (probe_j - base)."""
    if cfg.arch_type == "audio":
        one = dataclasses.replace(
            cfg, n_layers=1,
            encoder=dataclasses.replace(cfg.encoder, n_layers=1))
        two = dataclasses.replace(
            cfg, n_layers=2,
            encoder=dataclasses.replace(cfg.encoder, n_layers=2))
        return one, [(two, cfg.n_layers - 1)]
    segs = cfg.segments()
    base = cfg.with_segment_counts(tuple(1 for _ in segs))
    probes = []
    for j, (_, n_j) in enumerate(segs):
        if n_j > 1:
            counts = tuple(2 if i == j else 1 for i in range(len(segs)))
            probes.append((cfg.with_segment_counts(counts), n_j - 1))
    return base, probes


def _combine(base, deltas):
    out = {"flops": base["flops"], "hbm": base["hbm"],
           "coll": dict(base["coll"]), "counts": dict(base["counts"])}
    for probe, mult in deltas:
        out["flops"] += mult * (probe["flops"] - base["flops"])
        out["hbm"] += mult * (probe["hbm"] - base["hbm"])
        for k in set(probe["coll"]) | set(base["coll"]):
            out["coll"][k] = out["coll"].get(k, 0.0) + mult * (
                probe["coll"].get(k, 0.0) - base["coll"].get(k, 0.0))
        for k in set(probe["counts"]) | set(base["counts"]):
            out["counts"][k] = out["counts"].get(k, 0) + mult * (
                probe["counts"].get(k, 0) - base["counts"].get(k, 0))
    return out


def lower_one(arch: str, shape_name: str, mesh_kind: str, *,
              multi_pod: bool = False, xent_chunks: int = 0,
              overdecompose: int = 1, factors=None, probe: bool = True,
              remat_policy: str = "full", cache_gather: bool = False,
              overlap: bool = False, z_chunks: int = 1, ar_chunks: int = 1,
              zero: bool = False, zero3: bool = False,
              zero3_prefetch: bool = False, dp_bucket_mb: float = 4.0,
              objective: str = "auto", calib: str = "",
              seq_parallel: bool = False, g_seq: int = 0,
              expert_parallel: bool = False, g_expert: int = 0):
    # chunk knobs only mean something on the ring paths; normalize so the
    # record (and the resume cache key built from it) never claims a
    # config the lowering didn't use
    z_chunks = z_chunks if overlap else 1
    ar_chunks = ar_chunks if overlap else 1
    # context parallelism is a train-path knob; g_seq (0 = let the
    # chooser pick) only means something with --seq-parallel
    seq_parallel = seq_parallel and SHAPES[shape_name].kind == "train"
    g_seq = g_seq if seq_parallel else 0
    # expert parallelism is a train-path knob and needs an MoE arch
    expert_parallel = (expert_parallel
                       and SHAPES[shape_name].kind == "train"
                       and get_config(arch).moe is not None)
    g_expert = g_expert if expert_parallel else 0
    zero = zero and not zero3          # zero3 supersedes the ZeRO-1 path
    zero3_prefetch = zero3_prefetch if zero3 else False
    dp_bucket_mb = dp_bucket_mb if (zero or zero3) else 0.0
    ov = (OverlapConfig.all_on(z_chunks=z_chunks, ar_chunks=ar_chunks,
                               cache_weight_gather=cache_gather)
          if overlap else OverlapConfig(cache_weight_gather=cache_gather))
    if zero3:
        gs = GradSyncConfig(zero3=True, prefetch=zero3_prefetch,
                            bucket_mb=dp_bucket_mb)
    elif zero:
        gs = GradSyncConfig(zero=True, bucket_mb=dp_bucket_mb)
    else:
        gs = GradSyncConfig()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    seqshard = shape.seqshard
    # measured hardware constants (core/calibrate.py) — the TPU_V5E
    # guesses when uncalibrated, so calib="" changes nothing
    hw = CB.resolve_hw(calib or None)

    if mesh_kind == "baseline-1d":
        mesh = LM.make_production_mesh(multi_pod=multi_pod)
        axes = LM.bind_production(mesh, cfg)
        factors = (int(axes.dp // (2 if multi_pod else 1)),
                   int(axes.gx), int(axes.gy), 1)
    else:
        if factors is None:
            factors = choose_factors(cfg, shape,
                                     pods=2 if multi_pod else 1,
                                     overlap=ov if overlap else None,
                                     objective=objective, hw=hw,
                                     seq_parallel=seq_parallel, g_seq=g_seq,
                                     expert_parallel=expert_parallel,
                                     g_expert=g_expert)
        mesh = LM.make_production_mesh_4d(*factors, multi_pod=multi_pod)
        axes = LM.bind_4d(mesh)
    cfg.validate_axes(axes)

    if xent_chunks == 0:
        xent_chunks = 4 if cfg.vocab_size >= 100_000 else 1
    n_dev = mesh.devices.size
    kw = dict(overdecompose=overdecompose, xent_chunks=xent_chunks,
              seqshard=seqshard, remat_policy=remat_policy, overlap=ov,
              gradsync=gs)

    # (1) the REAL scan-based program: must lower+compile; memory analysis
    t0 = time.time()
    lowered = _make_lowered(cfg, shape, mesh, axes, unroll=False, **kw)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = RL.memory_summary(compiled)
    if shape.kind == "train":
        # per-rank persistent param + optimizer bytes (what the ZeRO
        # levels shrink: replicated -> /G_data opt -> /G_data params too)
        topts = ST.TrainOptions(overdecompose=overdecompose,
                                xent_chunks=xent_chunks,
                                remat_policy=remat_policy, overlap=ov,
                                gradsync=gs)
        (pst, pps), (ost, ops) = ST.state_layouts(cfg, axes, topts)
        mem["param_bytes_per_rank"] = _tree_bytes_per_rank(mesh, pst, pps)
        mem["opt_bytes_per_rank"] = _tree_bytes_per_rank(mesh, ost, ops)
        mem["param_opt_bytes_per_rank"] = (mem["param_bytes_per_rank"]
                                           + mem["opt_bytes_per_rank"])
        # the transient (activation/workspace) side of the per-rank
        # budget — what context parallelism shrinks by ~1/g_seq (the
        # seq-shard memory check of benchmarks/hillclimb.py)
        if "temp_size_in_bytes" in mem:
            mem["activation_bytes_per_rank"] = mem["temp_size_in_bytes"]

    # (2) depth probes (unrolled, exact HLO costs) -> linear extrapolation.
    # XLA's cost model counts a scan body once regardless of trip count, so
    # the scanned program's terms undercount depth; the probes are exact.
    if probe:
        base_cfg, probe_list = _probe_plan(cfg)
        t1 = time.time()
        base = _raw_terms(_make_lowered(base_cfg, shape, mesh, axes,
                                        unroll=True, **kw).compile())
        deltas = []
        for pcfg, mult in probe_list:
            pt = _raw_terms(_make_lowered(pcfg, shape, mesh, axes,
                                          unroll=True, **kw).compile())
            deltas.append((pt, mult))
        terms = _combine(base, deltas)
        probe_s = time.time() - t1
    else:
        terms = _raw_terms(compiled)
        probe_s = 0.0

    coll_total = sum(terms["coll"].values())
    ct = terms["flops"] / hw.flops
    mt = terms["hbm"] / RL.HBM_BW
    lt = coll_total / hw.link_bw
    dom = max((("compute", ct), ("memory", mt), ("collective", lt)),
              key=lambda x: x[1])[0]
    mf = RL.model_flops_per_device(cfg, shape, n_dev)
    # overlap-aware step-time estimate: collective-permute traffic (the
    # ring-decomposed z collectives) hides under compute, the rest is
    # exposed (launch/roofline.step_time_estimate); priced with the
    # calibrated constants when --calib gave any
    est = RL.step_time_estimate(terms["flops"], terms["coll"], hw=hw)
    roof = {
        "flops": terms["flops"], "hbm_bytes": terms["hbm"],
        "collective_bytes": coll_total,
        "compute_t": ct, "memory_t": mt, "collective_t": lt,
        "exposed_collective_t": est.exposed_comm,
        "hidden_collective_t": est.hidden_comm,
        "step_time_est": est.total,
        "dominant": dom, "model_flops": mf,
        "useful_ratio": (mf / terms["flops"] if terms["flops"] else 0.0),
        "collectives": terms["coll"],
        "collective_counts": terms["counts"],
    }
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "multi_pod": multi_pod, "devices": int(n_dev),
        "factors": {"g_data": factors[0], "g_x": factors[1],
                    "g_y": factors[2], "g_z": factors[3],
                    "g_seq": factors[4] if len(factors) > 4 else 1,
                    "g_expert": factors[5] if len(factors) > 5 else 1},
        "seq_parallel": seq_parallel,
        "g_seq": int(factors[4]) if len(factors) > 4 else 1,
        "g_seq_req": g_seq,   # the requested pin (0 = auto) — resume key
        "expert_parallel": expert_parallel,
        "g_expert": int(factors[5]) if len(factors) > 5 else 1,
        "g_expert_req": g_expert,
        "overdecompose": overdecompose,
        "remat_policy": remat_policy, "cache_gather": cache_gather,
        "overlap": overlap, "z_chunks": z_chunks, "ar_chunks": ar_chunks,
        "zero": zero, "zero3": zero3, "zero3_prefetch": zero3_prefetch,
        "dp_bucket_mb": dp_bucket_mb, "objective": objective,
        "calib": calib or "",
        "compile_s": round(compile_s, 1), "probe_s": round(probe_s, 1),
        "memory": mem,
        "roofline": roof,
    }
    return rec, compiled


def _feasible(cfg, factors, multi_pod=False):
    """Cheap feasibility probe: abstract init under these factors."""
    try:
        mesh = LM.make_production_mesh_4d(*factors, multi_pod=multi_pod)
        axes = LM.bind_4d(mesh)
        cfg.validate_axes(axes)
        ST.init_model(cfg, axes, abstract=True)
        return True
    except Exception:
        return False


def choose_factors(cfg, shape, pods: int = 1,
                   overlap: OverlapConfig = None,
                   objective: str = "auto", hw=None,
                   seq_parallel: bool = False, g_seq: int = 0,
                   expert_parallel: bool = False, g_expert: int = 0):
    """Communication-model-optimal (g_data, g_x, g_y, g_z, g_seq,
    g_expert) for this pair.

    With ``seq_parallel`` the enumeration opens the 5th (context) factor
    — ``g_seq`` jointly chosen with the others by the same objective
    (the KV ring_exchange class prices it), or pinned when ``g_seq`` > 0.
    ``expert_parallel`` opens the 6th (expert) factor the same way: the
    all_to_all class prices the MoE dispatch/combine, ``g_expert`` > 0
    pins it.

    ``objective='auto'`` (the default) ranks by the α-β overlap-aware
    ``predict_step_time`` whenever ``overlap`` is set (ring-hidden z
    traffic makes z-heavier factors cheaper) and by the paper's volume
    model otherwise; ``'time'`` / ``'volume'`` force either — the
    ``--objective volume`` escape hatch back to the pure Eq. 5
    criterion. ``hw`` (a ``--calib``-loaded ``HardwareParams``) prices
    the time objective with measured constants. Validate a chosen
    ranking against measured step times with ``benchmarks.run --only
    fig5_measured`` (it reports the predicted-vs-measured best
    decomposition AND the rank correlation over the whole grid).

    long_500k (global_batch=1, cache seq-sharded over data) lifts the
    batch-divisibility constraint; decode shapes fix g_z=1 (the z axis is
    a *training* trade — weight AG/RS vs gradient traffic — and decode has
    no weight gradients to amortize it against)."""
    import dataclasses as _dc
    from repro.core import comm_model as CM
    sh = shape
    if shape.seqshard:
        sh = _dc.replace(shape, global_batch=0)
    # pods extend data parallelism: per-pod batch must still divide
    gb = sh.global_batch // pods if sh.global_batch else 0
    cons = cfg.tp_constraints(gb)
    z_div = () if shape.kind == "train" else (1,)  # force g_z = 1
    # seq parallelism is a train-only trade (ring attention has no decode
    # analogue here) and needs g_seq | seq_len for the striped layout
    max_seq_f = 1
    if seq_parallel and shape.kind == "train":
        max_seq_f = g_seq if g_seq > 0 else sh.seq_len
    # expert parallelism is likewise train-only and needs an MoE config
    # (g_expert must divide the expert count; the y co-divisibility is
    # caught by the _feasible probe)
    max_expert_f = 1
    if expert_parallel and shape.kind == "train" and cfg.moe is not None:
        max_expert_f = g_expert if g_expert > 0 else cfg.moe.n_experts
    cons = CM.Constraints(global_batch=cons.global_batch,
                          x_divides=cons.x_divides,
                          y_divides=cons.y_divides,
                          z_divides=z_div,
                          min_tensor=_min_tensor(cfg, shape),
                          max_seq=max_seq_f,
                          seq_divides=(sh.seq_len,) if max_seq_f > 1 else (),
                          max_expert=max_expert_f,
                          expert_divides=(cfg.moe.n_experts,)
                          if max_expert_f > 1 else ())
    # tokens processed per step: full sequence for train AND prefill
    # (a prefill forward is one fwd pass over B*S tokens); decode is one
    # token per sequence. (Mis-pricing prefill as B tokens made the model
    # pick z-heavy factors whose weight all-gathers dwarfed the step —
    # §Perf pair 2, iteration 1.)
    tokens = max(sh.global_batch, 1) * (
        sh.seq_len if shape.kind in ("train", "prefill") else 1)
    # inference shapes have no gradient all-reduce: drop the data-parallel
    # term so the model maximizes dp (subject to the memory floor) instead
    # of being penalized for it (§Perf pair 2/3 iteration)
    if objective == "auto":
        objective = ("time" if overlap is not None and overlap.any_enabled
                     else "volume")
    obj = {}
    if objective == "time":
        obj = dict(objective="time", overlap=overlap, hw=hw)
    ranked = CM.optimize_decomposition(
        list(cfg.comm_layers()), tokens, 256, cons,
        top_k=64 if max_seq_f <= 1 and max_expert_f <= 1 else 512,
        include_data_parallel=(shape.kind == "train"), **obj)
    if g_seq > 0:
        pinned = [t for t in ranked if t[0].g_seq == g_seq]
        if not pinned:
            raise ValueError(
                f"no feasible decomposition with g_seq={g_seq} for "
                f"{cfg.name} x {shape.name}")
        ranked = pinned
    if g_expert > 0 and max_expert_f > 1:
        pinned = [t for t in ranked if t[0].g_expert == g_expert]
        if not pinned:
            raise ValueError(
                f"no feasible decomposition with g_expert={g_expert} for "
                f"{cfg.name} x {shape.name}")
        ranked = pinned
    for d, _ in ranked:
        f = (d.g_data, d.g_x, d.g_y, d.g_z, d.g_seq, d.g_expert)
        if _feasible(cfg, f, multi_pod=(pods > 1)):
            return f
    d = ranked[0][0]
    return d.g_data, d.g_x, d.g_y, d.g_z, d.g_seq, d.g_expert


def _min_tensor(cfg, shape) -> int:
    """Memory floor for G_tensor: fit params (+opt state if training)
    into ~10 GB/chip of the 16 GB HBM."""
    n = cfg.param_count()
    bytes_per = 14 if shape.kind == "train" else 2  # bf16 + fp32 m/v/master
    need = n * bytes_per / 10e9
    t = 1
    while t < need and t < 256:
        t *= 2
    return min(t, 256)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.dryrun",
        description="Lower + compile every (arch x shape x mesh) against "
                    "the production meshes and extract roofline terms.")
    ap.add_argument("--arch", default=None,
                    help="one assigned architecture (default: all when "
                         "--all)")
    ap.add_argument("--shape", default=None,
                    help="one input shape from configs.SHAPES")
    ap.add_argument("--mesh", default="both",
                    choices=["baseline-1d", "tensor4d", "both"],
                    help="production mesh kind")
    ap.add_argument("--multi-pod", action="store_true",
                    help="add the leading 2-pod axis (512 devices)")
    ap.add_argument("--both-pods", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) combo")
    ap.add_argument("--overdecompose", type=int, default=1,
                    help="microbatch count of the overdecompose loop "
                         "(paper §4.2)")
    ap.add_argument("--overlap", action="store_true",
                    help="ring-decomposed collective matmuls: overlapped "
                         "z-axis weight collectives AND x/y activation "
                         "all-reduce rings")
    ap.add_argument("--z-chunks", type=int, default=1,
                    help="sub-rings per z-axis weight block "
                         "(with --overlap)")
    ap.add_argument("--ar-chunks", type=int, default=1,
                    help="sub-rings per scattered block of the x/y "
                         "activation all-reduces (with --overlap)")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO-sharded data-parallel sync: bucketed "
                         "gradient reduce-scatter rings streamed through "
                         "the overdecompose loop + AdamW state sharded "
                         "over the data axis (core/gradsync.py)")
    ap.add_argument("--zero3", action="store_true",
                    help="ZeRO-3 param-shard streaming: params live as "
                         "1/G_data shards, each layer's working copy is "
                         "ring-all-gathered just-in-time inside the layer "
                         "scan and released after use; AdamW state "
                         "sharded as with --zero (core/gradsync.py)")
    ap.add_argument("--zero3-prefetch", action="store_true",
                    help="with --zero3: gather layer i+1's shards during "
                         "layer i's compute and retain the working copy "
                         "for the backward (no re-gather; param memory "
                         "returns to ~full)")
    ap.add_argument("--dp-bucket-mb", type=float, default=4.0,
                    help="fp32 gradient bucket size bound in MiB "
                         "(with --zero/--zero3)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="context parallelism: open the 5th (seq) mesh "
                         "factor — the sequence dim shards striped over "
                         "it and attention runs as a KV ppermute ring "
                         "(train shapes only)")
    ap.add_argument("--g-seq", type=int, default=0,
                    help="pin the seq factor (with --seq-parallel; "
                         "0 = let the communication model choose it "
                         "jointly with g_data/g_x/g_y/g_z)")
    ap.add_argument("--expert-parallel", action="store_true",
                    help="expert parallelism: open the 6th (expert) mesh "
                         "factor — the MoE expert bank shards over it and "
                         "dispatch/combine runs as an all-to-all priced "
                         "by the communication model (MoE train shapes "
                         "only)")
    ap.add_argument("--g-expert", type=int, default=0,
                    help="pin the expert factor (with --expert-parallel; "
                         "0 = let the communication model choose it "
                         "jointly with the other factors)")
    ap.add_argument("--objective", default="auto",
                    choices=["auto", "time", "volume"],
                    help="factor-chooser objective: auto = the α-β "
                         "overlap-aware time model whenever --overlap is "
                         "set, the paper's volume model otherwise; "
                         "'volume' is the escape hatch back to Eq. 5")
    ap.add_argument("--calib", default="",
                    help="hardware calibration profile: a JSON path from "
                         "benchmarks.calibrate, or 'auto' for "
                         "runs/calib/<backend>.json; prices the factor "
                         "chooser and roofline with measured α/β/flops "
                         "instead of the TPU_V5E guesses")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip depth-probe lowerings (multi-pod pass: the "
                         "compile proof only, roofline terms from the "
                         "scanned program)")
    ap.add_argument("--out", default="runs/dryrun/results.jsonl",
                    help="JSONL record sink (also the resume cache)")
    return ap


def main():
    args = build_parser().parse_args()

    archs = list(ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = (["baseline-1d", "tensor4d"] if args.mesh == "both"
              else [args.mesh])
    pods = [False, True] if args.both_pods else [args.multi_pod]
    z_chunks = args.z_chunks if args.overlap else 1  # inert without ring
    ar_chunks = args.ar_chunks if args.overlap else 1
    zero = args.zero and not args.zero3
    zero3_prefetch = args.zero3_prefetch if args.zero3 else False
    dp_bucket_mb = args.dp_bucket_mb if (zero or args.zero3) else 0.0
    g_seq_arg = args.g_seq if args.seq_parallel else 0
    g_expert_arg = args.g_expert if args.expert_parallel else 0

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r["multi_pod"], r.get("overdecompose", 1),
                              r.get("overlap", False),
                              r.get("z_chunks", 1),
                              r.get("ar_chunks", 1),
                              r.get("zero", False),
                              r.get("zero3", False),
                              r.get("zero3_prefetch", False),
                              r.get("dp_bucket_mb", 0.0),
                              r.get("objective", "auto"),
                              r.get("calib", ""),
                              r.get("seq_parallel", False),
                              r.get("g_seq_req", 0),
                              r.get("expert_parallel", False),
                              r.get("g_expert_req", 0)))
                except Exception:
                    pass

    for arch in archs:
        for shape in shapes:
            reason = skip_reason(arch, shape)
            if reason:
                print(f"SKIP {arch} {shape}: {reason}")
                continue
            for mk in meshes:
                for mp in pods:
                    key = (arch, shape, mk, mp, args.overdecompose,
                           args.overlap, z_chunks, ar_chunks,
                           zero, args.zero3, zero3_prefetch, dp_bucket_mb,
                           args.objective, args.calib,
                           args.seq_parallel, g_seq_arg,
                           args.expert_parallel, g_expert_arg)
                    if key in done:
                        print(f"cached {key}")
                        continue
                    print(f"=== {arch} {shape} {mk} multi_pod={mp} "
                          f"overlap={args.overlap} zero={zero} "
                          f"zero3={args.zero3}",
                          flush=True)
                    try:
                        rec, compiled = lower_one(
                            arch, shape, mk, multi_pod=mp,
                            overdecompose=args.overdecompose,
                            overlap=args.overlap, z_chunks=z_chunks,
                            ar_chunks=ar_chunks, zero=zero,
                            zero3=args.zero3,
                            zero3_prefetch=zero3_prefetch,
                            dp_bucket_mb=args.dp_bucket_mb,
                            objective=args.objective, calib=args.calib,
                            seq_parallel=args.seq_parallel,
                            g_seq=g_seq_arg,
                            expert_parallel=args.expert_parallel,
                            g_expert=g_expert_arg,
                            probe=not args.no_probe)
                        r = rec["roofline"]
                        print(f"  ok compile={rec['compile_s']}s "
                              f"flops={r['flops']:.3e} "
                              f"coll={r['collective_bytes']:.3e}B "
                              f"dom={r['dominant']}")
                        print("  memory:", rec["memory"].get(
                            "total_per_device_bytes"),
                              "param+opt/rank:", rec["memory"].get(
                            "param_opt_bytes_per_rank"))
                    except Exception as e:
                        rec = {"arch": arch, "shape": shape, "mesh": mk,
                               "multi_pod": mp,
                               "overdecompose": args.overdecompose,
                               "overlap": args.overlap,
                               "z_chunks": z_chunks,
                               "ar_chunks": ar_chunks,
                               "zero": zero,
                               "zero3": args.zero3,
                               "zero3_prefetch": zero3_prefetch,
                               "dp_bucket_mb": dp_bucket_mb,
                               "calib": args.calib,
                               "seq_parallel": args.seq_parallel,
                               "g_seq_req": g_seq_arg,
                               "expert_parallel": args.expert_parallel,
                               "g_expert_req": g_expert_arg,
                               "error": f"{type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()[-2000:]}
                        print(f"  FAILED: {type(e).__name__}: {e}")
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
