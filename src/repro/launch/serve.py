"""Batched serving driver: prefill a batch of prompts, then decode.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.serve --arch qwen3-1.7b --batch 4 \\
      --prompt-len 32 --gen 16 --mesh 2,2,2,1
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.overlap import OverlapConfig
from repro.core.partition import spec_tree_to_pspecs
from repro.launch import mesh as LM
from repro.launch import steps as ST


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="Batched serving: prefill a batch of prompts, then "
                    "decode, on the current host devices.")
    ap.add_argument("--arch", required=True,
                    help="architecture name (repro.configs)")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"],
                    help="model-size preset")
    ap.add_argument("--batch", type=int, default=4,
                    help="concurrent sequences")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="prefill length (tokens)")
    ap.add_argument("--gen", type=int, default=16,
                    help="decode steps after prefill")
    ap.add_argument("--mesh", default="2,2,2,1",
                    help="g_data,g_x,g_y,g_z over host devices")
    ap.add_argument("--overlap", action="store_true",
                    help="ring-decomposed collective matmuls in the "
                         "prefill/decode steps (core/overlap.py: "
                         "overlapped z weight gathers + x/y activation "
                         "all-reduce rings)")
    ap.add_argument("--z-chunks", type=int, default=1,
                    help="sub-rings per z weight block (with --overlap)")
    ap.add_argument("--ar-chunks", type=int, default=1,
                    help="sub-rings per activation all-reduce block "
                         "(with --overlap)")
    return ap


def main():
    args = build_parser().parse_args()

    mesh = LM.make_smoke_mesh(tuple(int(x) for x in args.mesh.split(",")),
                              ("data", "x", "y", "z"))
    axes = LM.bind_4d(mesh)
    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    dtype = jnp.float32

    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=dtype)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))

    S_max = args.prompt_len + args.gen
    ov = (OverlapConfig.all_on(z_chunks=args.z_chunks,
                               ar_chunks=args.ar_chunks)
          if args.overlap else OverlapConfig())
    pre_build, _ = ST.make_prefill_step(cfg, mesh, axes, dtype=dtype,
                                        overlap=ov)
    pre_fn, bt, ct = pre_build(args.batch, args.prompt_len, S_max)
    dec_build, _ = ST.make_decode_step(cfg, mesh, axes, dtype=dtype,
                                       overlap=ov)
    dec_fn, _ = dec_build(args.batch, S_max)

    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jnp.asarray(rng.randn(
            args.batch, cfg.encoder.n_ctx, cfg.encoder.input_dim),
            jnp.float32)
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(rng.randn(
            args.batch, cfg.encoder.n_ctx, cfg.d_model), jnp.float32)

    # warmup: run prefill + one decode step on throwaway caches so the
    # timed numbers below exclude XLA compile time
    warm = ST.zeros_caches(mesh, ct)
    t0 = time.time()
    wl, warm = pre_fn(params, warm, batch)
    wt = jnp.argmax(wl[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    wl, warm = dec_fn(params, warm, wt, jnp.int32(args.prompt_len))
    jax.block_until_ready(wl)
    print(f"warmup (compile) in {time.time()-t0:.2f}s")
    del warm

    caches = ST.zeros_caches(mesh, ct)
    t0 = time.time()
    logits, caches = pre_fn(params, caches, batch)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    print(f"prefill {args.batch}x{args.prompt_len} in "
          f"{time.time()-t0:.2f}s")

    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, caches = dec_fn(params, caches, tok, pos)
        # greedy over the local vocab shard (full argmax needs a psum-max
        # merge across y; for the demo we keep it shard-local)
        tok = jnp.argmax(logits[:, 0, :], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print("generated ids:\n", gen)
    print(f"decode: {args.gen - 1} steps x batch {args.batch} = "
          f"{(args.gen - 1) * args.batch / dt:,.1f} tok/s")
    assert np.isfinite(np.asarray(logits)).all()
    print("SERVE OK")


if __name__ == "__main__":
    main()
