"""Serving driver: continuous batching over the paged KV cache (default)
or the legacy fixed-batch prefill/decode loop (``--mode fixed``).

Continuous mode (the production path, docs/serving.md) runs the
``launch/serving`` engine: requests admit/evict at every decode step,
prompts prefill in chunks that ride the same compiled step as decode,
and the KV cache is a paged pool sharded over the tensor axes.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.serve --arch qwen3-1.7b --mode continuous \\
      --requests 16 --rate 200 --slots 8 --gen 16 --mesh 2,2,2,1

Fixed mode keeps the PR-0 behavior — one prefill of a uniform batch,
then lockstep decode:

  python -m repro.launch.serve --arch qwen3-1.7b --mode fixed \\
      --batch 4 --prompt-len 32 --gen 16 --mesh 2,2,2,1

The mesh is the 4-tuple g_data,g_x,g_y,g_z — serving requires g_seq == 1
(ring attention is training-only; see ROADMAP 'seq-parallel serving').
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.overlap import OverlapConfig
from repro.core.partition import spec_tree_to_pspecs
from repro.launch import mesh as LM
from repro.launch import steps as ST


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="Serving on the current host devices: continuous "
                    "batching over a paged KV cache (default), or the "
                    "fixed-batch prefill/decode loop (--mode fixed).")
    ap.add_argument("--arch", required=True,
                    help="architecture name (repro.configs)")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"],
                    help="model-size preset")
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "fixed"],
                    help="continuous: paged-KV continuous batching; "
                         "fixed: uniform-batch prefill then lockstep "
                         "decode")
    ap.add_argument("--mesh", default="2,2,2,1",
                    help="g_data,g_x,g_y,g_z over host devices (serving "
                         "needs g_seq == 1)")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="prompt length in tokens (uniform)")
    ap.add_argument("--gen", type=int, default=16,
                    help="tokens to generate per request/sequence")
    # fixed-mode knobs
    ap.add_argument("--batch", type=int, default=4,
                    help="concurrent sequences (--mode fixed)")
    # continuous-mode knobs
    ap.add_argument("--requests", type=int, default=16,
                    help="synthetic requests to serve (--mode continuous)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in requests/s "
                         "(0 = all requests arrive at t=0)")
    ap.add_argument("--slots", type=int, default=8,
                    help="concurrent request slots R (multiple of "
                         "g_data*g_z)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page")
    ap.add_argument("--pages", type=int, default=64,
                    help="physical KV pages per batch shard (incl. the "
                         "reserved null page)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk rows per mixed step")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed")
    ap.add_argument("--telemetry", action="store_true",
                    help="per-step scheduler counters (queue depth, page "
                         "utilization, preemptions) + tokens/s as "
                         "schema'd JSONL under runs/telemetry/ "
                         "(--mode continuous; docs/telemetry.md)")
    ap.add_argument("--log-file", default=None,
                    help="telemetry JSONL path (implies --telemetry; "
                         "default runs/telemetry/<run>.jsonl)")
    ap.add_argument("--overlap", action="store_true",
                    help="ring-decomposed collective matmuls in the "
                         "prefill/decode steps (core/overlap.py: "
                         "overlapped z weight gathers + x/y activation "
                         "all-reduce rings)")
    ap.add_argument("--z-chunks", type=int, default=1,
                    help="sub-rings per z weight block (with --overlap)")
    ap.add_argument("--ar-chunks", type=int, default=1,
                    help="sub-rings per activation all-reduce block "
                         "(with --overlap)")
    return ap


def _setup(args):
    mesh = LM.make_smoke_mesh(tuple(int(x) for x in args.mesh.split(",")),
                              ("data", "x", "y", "z"))
    axes = LM.bind_4d(mesh)
    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    dtype = jnp.float32
    params, specs = ST.init_model(cfg, axes, jax.random.PRNGKey(0),
                                  dtype=dtype)
    params = ST.device_put_tree(mesh, params, spec_tree_to_pspecs(specs))
    ov = (OverlapConfig.all_on(z_chunks=args.z_chunks,
                               ar_chunks=args.ar_chunks)
          if args.overlap else OverlapConfig())
    return cfg, mesh, axes, params, dtype, ov


def run_fixed(args) -> None:
    cfg, mesh, axes, params, dtype, ov = _setup(args)
    S_max = args.prompt_len + args.gen
    pre_build, _ = ST.make_prefill_step(cfg, mesh, axes, dtype=dtype,
                                        overlap=ov)
    pre_fn, bt, ct = pre_build(args.batch, args.prompt_len, S_max)
    dec_build, _ = ST.make_decode_step(cfg, mesh, axes, dtype=dtype,
                                       overlap=ov)
    dec_fn, _ = dec_build(args.batch, S_max)

    rng = np.random.RandomState(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jnp.asarray(rng.randn(
            args.batch, cfg.encoder.n_ctx, cfg.encoder.input_dim),
            jnp.float32)
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(rng.randn(
            args.batch, cfg.encoder.n_ctx, cfg.d_model), jnp.float32)

    # warmup: run prefill + one decode step on throwaway caches so the
    # timed numbers below exclude XLA compile time
    warm = ST.zeros_caches(mesh, ct)
    t0 = time.time()
    wl, warm = pre_fn(params, warm, batch)
    wt = jnp.argmax(wl[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    wl, warm = dec_fn(params, warm, wt, jnp.int32(args.prompt_len))
    jax.block_until_ready(wl)
    print(f"warmup (compile) in {time.time()-t0:.2f}s")
    del warm

    caches = ST.zeros_caches(mesh, ct)
    t0 = time.time()
    logits, caches = pre_fn(params, caches, batch)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    print(f"prefill {args.batch}x{args.prompt_len} in "
          f"{time.time()-t0:.2f}s")

    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, caches = dec_fn(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, 0, :], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print("generated ids:\n", gen)
    print(f"decode: {args.gen - 1} steps x batch {args.batch} = "
          f"{(args.gen - 1) * args.batch / dt:,.1f} tok/s")
    assert np.isfinite(np.asarray(logits)).all()
    print("SERVE OK")


def run_continuous(args) -> None:
    from repro.launch.serving import PagedEngine, Request, ServeConfig

    cfg, mesh, axes, params, dtype, ov = _setup(args)
    scfg = ServeConfig(slots=args.slots, page_size=args.page_size,
                       pages_per_shard=args.pages, chunk=args.chunk)
    engine = PagedEngine(cfg, mesh, axes, params, scfg, dtype=dtype,
                         overlap=ov)
    t0 = time.time()
    engine.warmup()
    print(f"warmup (compile) in {time.time()-t0:.2f}s")

    rng = np.random.RandomState(args.seed)
    t = 0.0
    reqs = []
    for i in range(args.requests):
        if args.rate > 0:
            t += float(rng.exponential(1.0 / args.rate))
        reqs.append(Request(
            rid=i,
            prompt=rng.randint(1, cfg.vocab_size,
                               size=(args.prompt_len,)).astype(np.int32),
            max_new=args.gen, arrival=t))
    telem = None
    if args.telemetry or args.log_file:
        from repro.core import comm_model as CM
        from repro.launch import telemetry as TL
        run_name = f"serve-{cfg.name}-{time.strftime('%Y%m%d-%H%M%S')}"
        telem = TL.Telemetry(
            run_name, path=args.log_file,
            tokens_per_step=0,  # serve steps carry their own new_tokens
            flops_per_token=CM.model_flops_per_token(cfg, "serve"),
            peak_flops_per_device=CM.TPU_V5E.flops,
            n_devices=int(mesh.devices.size),
            meta={"arch": cfg.name, "mesh": args.mesh, "mode": "continuous",
                  "slots": args.slots, "pages": args.pages,
                  "requests": args.requests, "rate": args.rate})
    stats = engine.run(reqs, telemetry=telem)
    if telem is not None:
        # summary tok_s comes from the engine's open-loop wall clock so
        # the JSONL agrees with the printed stats (and the perf CSV)
        telem.close(extra={
            "tok_s": stats.tokens_per_s, "wall_s": stats.wall_s,
            "steps": stats.n_steps, "tokens": stats.total_new_tokens,
            "preemptions": stats.n_preemptions,
            "ttft_p50_ms": stats.ttft_p50_ms,
            "ttft_p99_ms": stats.ttft_p99_ms,
            "latency_p50_ms": stats.latency_p50_ms,
            "latency_p99_ms": stats.latency_p99_ms})
    for r in reqs[: min(4, len(reqs))]:
        print(f"req {r.rid}: {np.asarray(r.generated, np.int32)}")
    print(f"served {stats.n_requests} requests / "
          f"{stats.total_new_tokens} tokens in {stats.wall_s:.2f}s "
          f"({stats.n_steps} steps, {stats.n_preemptions} preemptions)")
    print(f"tokens/s {stats.tokens_per_s:,.1f}  "
          f"latency p50/p99 {stats.latency_p50_ms:.1f}/"
          f"{stats.latency_p99_ms:.1f} ms  "
          f"ttft p50/p99 {stats.ttft_p50_ms:.1f}/"
          f"{stats.ttft_p99_ms:.1f} ms")
    print("SERVE OK")


def main():
    args = build_parser().parse_args()
    if args.mode == "fixed":
        run_fixed(args)
    else:
        run_continuous(args)


if __name__ == "__main__":
    main()
