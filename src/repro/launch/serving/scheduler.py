"""Continuous-batching request scheduler.

State machine per request (docs/serving.md):

    queued --admit--> prefill --chunks--> decode --max_new--> done
       ^                                    |
       +---------- preempted (pages exhausted; recompute) ----+

Every iteration the scheduler emits a :class:`Plan` — the dense (R, T)
row block the paged step consumes: slot r's rows ``0..q_len[r]-1`` carry
its next prefill chunk (or its single decode token) at its own global
positions. Admission and eviction happen BETWEEN steps, never inside
them, so one compiled program serves an arbitrarily churning request
mix: that is the whole point of continuous batching.

Preemption is by *recompute* (vLLM's default): when a shard's page pool
is exhausted, the youngest-admitted victim releases all its pages and
goes back to the queue with its generated tokens folded into the prompt
— re-prefilling is cheap exactly because chunked prefill rides the same
step as decode.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.launch.serving.pages import PageAllocator


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (L,) int32 prompt tokens
    max_new: int                    # tokens to generate
    arrival: float = 0.0            # seconds since bench start (open loop)
    # -- runtime state, owned by the scheduler --
    state: str = "queued"           # queued | prefill | decode | done
    slot: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0                    # tokens already written to the KV pool
    generated: List[int] = dataclasses.field(default_factory=list)
    admit_seq: int = -1             # admission order (preemption victims)
    preemptions: int = 0
    t_first: float = -1.0           # first generated token (TTFT end)
    t_done: float = -1.0

    @property
    def target(self) -> int:
        """Tokens the KV pool must hold before decoding can continue —
        prompt plus anything generated before a preemption."""
        return len(self.prompt) + len(self.generated)

    def full_seq(self) -> np.ndarray:
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])


@dataclasses.dataclass
class Plan:
    """One iteration's device inputs (host arrays; shard_map splits them)."""
    kind: str                       # 'mixed' (T=chunk) | 'decode' (T=1)
    tokens: np.ndarray              # (R, T) int32
    positions: np.ndarray           # (R, T) int32
    q_len: np.ndarray               # (R,) int32, 0 = idle slot
    table: np.ndarray               # (R, max_pages) int32 local page ids
    steps: List[tuple] = dataclasses.field(default_factory=list)
    # steps: (slot, Request, n_rows) for every slot that ran this iteration

    @property
    def n_active(self) -> int:
        return len(self.steps)


class Scheduler:
    def __init__(self, *, n_slots: int, page_size: int, max_pages: int,
                 allocators: List[PageAllocator]):
        if n_slots % len(allocators):
            raise ValueError(f"n_slots={n_slots} must divide evenly over "
                             f"{len(allocators)} batch shards")
        self.n_slots = n_slots
        self.page_size = page_size
        self.max_pages = max_pages
        self.allocators = allocators
        self.slots_per_shard = n_slots // len(allocators)
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.table = np.zeros((n_slots, max_pages), np.int32)
        self._admit_seq = 0
        self.n_preemptions = 0

    # ------------------------------------------------------------------ #
    # request intake
    # ------------------------------------------------------------------ #

    def submit(self, req: Request) -> None:
        cap = self.max_pages * self.page_size
        if len(req.prompt) + req.max_new > cap:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds per-request capacity {cap} "
                f"(= max_pages {self.max_pages} x page {self.page_size})")
        self.queue.append(req)

    def shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def admit(self, now: float) -> int:
        """Move arrived queued requests into free slots. Returns count."""
        n = 0
        while self.queue and self.queue[0].arrival <= now:
            slot = next((i for i, r in enumerate(self.slots) if r is None),
                        None)
            if slot is None:
                break
            req = self.queue.popleft()
            req.state, req.slot, req.pos = "prefill", slot, 0
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.slots[slot] = req
            n += 1
        return n

    @property
    def active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def all_done(self) -> bool:
        return not self.queue and not self.active

    # ------------------------------------------------------------------ #
    # paging
    # ------------------------------------------------------------------ #

    def _ensure_pages(self, req: Request, upto: int) -> bool:
        """Grow req's page list to cover positions [0, upto); False when
        the shard pool is dry (caller preempts and retries)."""
        alloc = self.allocators[self.shard_of(req.slot)]
        need = -(-upto // self.page_size)
        while len(req.pages) < need:
            p = alloc.alloc()
            if p is None:
                return False
            req.pages.append(p)
            self.table[req.slot, len(req.pages) - 1] = p
        return True

    def _release(self, req: Request) -> None:
        if req.pages:
            self.allocators[self.shard_of(req.slot)].free(req.pages)
        self.table[req.slot, :] = 0
        req.pages = []

    def preempt(self, req: Request) -> None:
        """Recompute-style preemption: drop the KV pages, fold generated
        tokens into the work to re-prefill, rejoin the queue at the
        front (it was here first)."""
        self._release(req)
        self.slots[req.slot] = None
        req.state, req.slot, req.pos = "queued", -1, 0
        req.preemptions += 1
        # arrival stays put (it already passed — the request was admitted
        # once), so re-admission is immediate and latency stays honest
        self.queue.appendleft(req)
        self.n_preemptions += 1

    def _pages_or_preempt(self, req: Request, upto: int) -> bool:
        """Allocate, preempting youngest-admitted victims on the same
        shard until it fits or nobody is left to evict."""
        while not self._ensure_pages(req, upto):
            shard = self.shard_of(req.slot)
            victims = [r for r in self.active
                       if r is not req and self.shard_of(r.slot) == shard]
            if not victims:
                return False
            self.preempt(max(victims, key=lambda r: r.admit_seq))
        return True

    # ------------------------------------------------------------------ #
    # per-iteration planning
    # ------------------------------------------------------------------ #

    def plan(self, chunk: int) -> Optional[Plan]:
        """Build the next iteration's row block, or None when idle."""
        active = self.active
        if not active:
            return None
        prefilling = any(r.state == "prefill" for r in active)
        T = chunk if prefilling else 1
        R = self.n_slots
        tokens = np.zeros((R, T), np.int32)
        positions = np.zeros((R, T), np.int32)
        q_len = np.zeros((R,), np.int32)
        steps: List[tuple] = []
        for req in list(self.active):      # preemption mutates self.slots
            if req.slot < 0:
                continue                   # preempted by an earlier slot
            if req.state == "prefill":
                cl = min(T, req.target - req.pos)
            else:
                cl = 1
            if not self._pages_or_preempt(req, req.pos + cl):
                continue                   # pool dry even after evictions
            if req.slot < 0:
                continue                   # lost its own pages — requeued
            seq = req.full_seq()
            rows = seq[req.pos:req.pos + cl]
            tokens[req.slot, :cl] = rows
            positions[req.slot] = np.minimum(
                req.pos + np.arange(T), self.max_pages * self.page_size - 1)
            q_len[req.slot] = cl
            steps.append((req.slot, req, cl))
        # a victim preempted by a LATER slot's allocation may already be
        # planned: its pages are gone, so drop it from this iteration
        # (it re-prefills from the queue — nothing is lost but the rows)
        steps = [(s, r, c) for (s, r, c) in steps
                 if r.slot == s and self.slots[s] is r]
        live = {s for s, _, _ in steps}
        for s in range(R):
            if s not in live:
                q_len[s] = 0
        if not steps:
            return None
        return Plan(kind="mixed" if prefilling else "decode",
                    tokens=tokens, positions=positions, q_len=q_len,
                    table=self.table.copy(), steps=steps)

    def commit(self, plan: Plan, sampled: np.ndarray, now: float) -> int:
        """Apply one executed plan: advance positions, collect each
        completed slot's sampled token, retire finished requests.
        Returns the number of new tokens generated this iteration."""
        new_tokens = 0
        for slot, req, cl in plan.steps:
            req.pos += cl
            emitted = False
            if req.state == "prefill":
                if req.pos >= req.target:
                    req.state = "decode"
                    emitted = True       # last prompt row predicts token 1
            else:
                emitted = True
            if emitted:
                req.generated.append(int(sampled[slot]))
                new_tokens += 1
                if req.t_first < 0:
                    req.t_first = now
                if len(req.generated) >= req.max_new:
                    req.state, req.t_done = "done", now
                    self._release(req)
                    self.slots[slot] = None
                    req.slot = -1
        return new_tokens
