"""Serving engine: compiled paged steps + the scheduler loop.

The engine owns the device-resident state (params stay wherever the
caller put them; the KV page pools are donated through every step) and
compiles the paged step at exactly TWO row widths:

  * T = chunk  — iterations carrying prefill work (decode slots ride
    along with q_len = 1, so prefill never stalls decode);
  * T = 1      — pure-decode iterations, the steady-state hot path.

Everything else — admission, chunking, paging, preemption — is host-side
bookkeeping between steps, which is what keeps the compiled program
count at two regardless of traffic.

Sampling is greedy argmax over the full (padded-vocab) logits; the
fixed-batch baseline in benchmarks/serving.py samples identically, which
is what makes paged-vs-dense token parity assertable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import mesh as M
from repro.core.overlap import OverlapConfig
from repro.launch import steps as ST
from repro.launch.serving.pages import PageAllocator
from repro.launch.serving.scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs (docs/serving.md has the sizing guidance)."""
    slots: int = 8             # R: concurrent requests (multiple of shards)
    page_size: int = 16        # tokens per KV page
    pages_per_shard: int = 64  # physical pages per batch shard (incl. null)
    chunk: int = 32            # prefill chunk rows (T of the mixed step)

    @property
    def max_pages(self) -> int:
        """Page-table width = whole per-shard pool (a single request may
        legitimately hold every allocatable page)."""
        return self.pages_per_shard - 1


@dataclasses.dataclass
class ServeStats:
    """Aggregates over one :meth:`PagedEngine.run`."""
    n_requests: int
    total_new_tokens: int
    wall_s: float
    latency_p50_ms: float
    latency_p99_ms: float
    ttft_p50_ms: float
    ttft_p99_ms: float
    n_steps: int
    n_preemptions: int

    @property
    def tokens_per_s(self) -> float:
        return self.total_new_tokens / max(self.wall_s, 1e-9)


def percentiles(xs: List[float]) -> tuple:
    if not xs:
        return (float("nan"), float("nan"))
    return (float(np.percentile(xs, 50)), float(np.percentile(xs, 99)))


class PagedEngine:
    def __init__(self, cfg, mesh, axes: M.MeshAxes, params,
                 scfg: ServeConfig = ServeConfig(), *,
                 dtype=jnp.float32,
                 overlap: OverlapConfig = OverlapConfig()):
        shards = axes.batch_shards
        if scfg.slots % shards:
            raise ValueError(
                f"slots={scfg.slots} must be a multiple of the batch "
                f"shards g_data*g_z={shards} (slots shard over data x z)")
        self.cfg, self.mesh, self.axes = cfg, mesh, axes
        self.scfg = scfg
        self.params = params
        build, _ = ST.make_paged_step(cfg, mesh, axes, dtype=dtype,
                                      overlap=overlap)
        n_pages_global = shards * scfg.pages_per_shard
        self.step_fn, ct = build(n_pages_global, scfg.page_size)
        self.pools = ST.zeros_caches(mesh, ct)
        self.sched = Scheduler(
            n_slots=scfg.slots, page_size=scfg.page_size,
            max_pages=scfg.max_pages,
            allocators=[PageAllocator(scfg.pages_per_shard)
                        for _ in range(shards)])

    # ------------------------------------------------------------------ #

    def _run_plan(self, plan):
        logits, self.pools = self.step_fn(
            self.params, self.pools, jnp.asarray(plan.tokens),
            jnp.asarray(plan.positions), jnp.asarray(plan.q_len),
            jnp.asarray(plan.table))
        return np.asarray(jnp.argmax(logits[:, 0, :], axis=-1),
                          np.int32)

    def warmup(self) -> None:
        """Compile both step widths on a throwaway request so timed runs
        never pay compile cost. Pools are zeros again afterwards."""
        s = self.sched
        L = min(2 * self.scfg.chunk, s.max_pages * s.page_size - 2)
        req = Request(rid=-1, prompt=np.ones((L,), np.int32), max_new=2)
        s.submit(req)
        s.admit(now=0.0)
        while not s.all_done():
            plan = s.plan(self.scfg.chunk)
            s.commit(plan, self._run_plan(plan), now=0.0)
        # the warmup request's pages were freed on completion; its stale
        # pool data is masked by q_len/table for every future request, so
        # no zeroing is needed — the stale-page guarantee the tests pin.

    def run(self, requests: List[Request], *,
            time_fn=time.time, telemetry=None) -> ServeStats:
        """Serve ``requests`` (arrival-sorted, ``arrival`` in seconds
        relative to start) to completion; open-loop: the clock keeps
        running whether or not the engine keeps up.

        ``telemetry`` (a ``launch.telemetry.Telemetry``) receives one
        ``serve_step`` record per executed plan: step kind, new tokens,
        queue depth, active slots, page-pool utilization and the
        cumulative preemption count. ``_run_plan`` already syncs on the
        sampled host tokens, so the per-step clock costs nothing extra."""
        s = self.sched
        for r in sorted(requests, key=lambda r: r.arrival):
            s.submit(r)
        t0 = time_fn()
        n_steps = 0
        total_new = 0
        page_cap = sum(a.n_pages - 1 for a in s.allocators)
        while not s.all_done():
            now = time_fn() - t0
            s.admit(now)
            plan = s.plan(self.scfg.chunk)
            if plan is None:
                # queue is non-empty but nothing has arrived yet
                next_t = s.queue[0].arrival
                time.sleep(min(max(next_t - now, 0.0), 0.01))
                continue
            t_plan = time_fn()
            sampled = self._run_plan(plan)
            n_steps += 1
            new = s.commit(plan, sampled, now=time_fn() - t0)
            total_new += new
            if telemetry is not None:
                telemetry.serve_step(
                    n_steps - 1, time_fn() - t_plan, new_tokens=new,
                    queue_depth=len(s.queue), active=plan.n_active,
                    page_util=(sum(a.n_used for a in s.allocators)
                               / max(page_cap, 1)),
                    preemptions=s.n_preemptions, step_kind=plan.kind)
        wall = time_fn() - t0
        lat = [r.t_done - r.arrival for r in requests]
        ttft = [r.t_first - r.arrival for r in requests]
        l50, l99 = percentiles([x * 1e3 for x in lat])
        f50, f99 = percentiles([x * 1e3 for x in ttft])
        return ServeStats(
            n_requests=len(requests), total_new_tokens=total_new,
            wall_s=wall, latency_p50_ms=l50, latency_p99_ms=l99,
            ttft_p50_ms=f50, ttft_p99_ms=f99, n_steps=n_steps,
            n_preemptions=s.n_preemptions)
