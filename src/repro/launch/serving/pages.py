"""Physical KV-page allocator for one batch shard.

Every batch shard (one (data, z) mesh coordinate) owns an independent
pool of ``n_pages`` physical pages per attention layer; requests sharded
onto it draw pages from this free list and their page tables hold the
resulting shard-LOCAL ids. Page 0 is the reserved **null page**: it is
never handed out, and the paged attention kernel routes every invalid
write (chunk padding, idle slots) to it — so a table entry of 0 always
means "unallocated" and stale data there is provably never read
(masked scores contribute exact zeros; see docs/serving.md).

tests/test_serving.py churns admit/evict cycles against the invariants
``check`` pins: conservation (free + used == n_pages - 1), no double
allocation, null page never allocated, no foreign frees.
"""
from __future__ import annotations

from typing import List


class PageAllocator:
    """LIFO free-list allocator over pages ``1 .. n_pages - 1``."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(
                f"a pool needs >= 2 pages (one reserved null + one "
                f"allocatable), got {n_pages}")
        self.n_pages = n_pages
        # LIFO keeps recently-freed (cache-warm) pages hot
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._used: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._used)

    def alloc(self):
        """One free page id, or None when the pool is exhausted (the
        scheduler then preempts — it never fails hard on memory)."""
        if not self._free:
            return None
        p = self._free.pop()
        self._used.add(p)
        return p

    def free(self, pages) -> None:
        for p in pages:
            if p == 0:
                raise ValueError("null page 0 is reserved and never "
                                 "allocated; freeing it is a table bug")
            if p not in self._used:
                raise ValueError(f"double/foreign free of page {p}")
            self._used.remove(p)
            self._free.append(p)

    def check(self) -> None:
        """Assert the pool invariants (test hook)."""
        assert 0 not in self._used and 0 not in self._free
        assert not self._used.intersection(self._free)
        assert len(self._free) + len(self._used) == self.n_pages - 1, \
            (len(self._free), len(self._used), self.n_pages)
