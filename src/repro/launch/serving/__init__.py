"""Continuous-batching serving over the paged, tensor-sharded KV cache.

The package splits the way a production stack does (docs/serving.md):

  * :mod:`pages` — per-batch-shard physical page allocator (free list,
    reserved null page 0);
  * :mod:`scheduler` — request lifecycle (queued → prefill → decode →
    done, preemption-by-recompute back to queued) and per-iteration
    plans: which slot runs which rows at which positions against which
    pages;
  * :mod:`engine` — compiles the paged step (launch/steps.py
    ``make_paged_step``) at two row widths, owns the device pools, and
    drives the scheduler loop, measuring p50/p99 latency and tokens/s.

``core.comm_model.serve_capacity`` predicts what this engine measures.
"""
from repro.launch.serving.pages import PageAllocator
from repro.launch.serving.scheduler import Plan, Request, Scheduler
from repro.launch.serving.engine import PagedEngine, ServeConfig, ServeStats

__all__ = [
    "PageAllocator", "PagedEngine", "Plan", "Request", "Scheduler",
    "ServeConfig", "ServeStats",
]
