"""Meshes, step builders, dry-run, training and serving drivers."""
