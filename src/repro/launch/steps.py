"""Step builders: jitted, shard_map'ped train / prefill / decode steps.

This is the runtime core every entry point shares (smoke tests, the
dry-run, the training driver, the serving driver). Everything inside the
mapped functions is *manual* SPMD: local shards + the paper's explicit
collectives (core.parallel); the specs computed here are the single source
of truth for how global arrays are laid out.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import gradsync as GS
from repro.core import mesh as M
from repro.core.compat import shard_map
from repro.core import parallel as PP
from repro.core.gradsync import GradSyncConfig
from repro.core.overdecompose import split_batch
from repro.core.overlap import OverlapConfig
from repro.core.partition import ParamSpec, expert_reduce_grads, \
    spec_tree_to_pspecs, unbox, z_reduce_grads
from repro.models import decoder as D
from repro.models import encdec as ED
from repro.models.base import ArchConfig
from repro.optim import adamw as OPT


# ---------------------------------------------------------------------- #
# model init (boxed -> (params, specs))
# ---------------------------------------------------------------------- #

def init_model(cfg: ArchConfig, axes: M.MeshAxes, key=None, *,
               dtype=jnp.bfloat16, abstract: bool = False):
    if key is None:
        key = jax.random.PRNGKey(0)
    if cfg.arch_type == "audio":
        boxed = ED.encdec_init(key, cfg, axes, dtype=dtype,
                               abstract=abstract)
    else:
        boxed = D.decoder_init(key, cfg, axes, dtype=dtype,
                               abstract=abstract)
    return unbox(boxed)


# ---------------------------------------------------------------------- #
# batch specs
# ---------------------------------------------------------------------- #

def batch_struct(cfg: ArchConfig, axes: M.MeshAxes, global_batch: int,
                 seq: int, *, kind: str = "train",
                 dtype=jnp.bfloat16):
    """GLOBAL ShapeDtypeStructs + PartitionSpecs for one batch."""
    bax = axes.batch_axes()
    bspec = axes.pspec(bax, None)
    # training tokens/labels also shard their seq dim over the context-
    # parallel axis (None when unmapped — same spec as before). The
    # global array must be fed in the *striped* layout: stripe_batch
    # below / core.mesh.stripe_seq, so rank r's contiguous shard holds
    # global positions {r, r + g_seq, ...} for causal load balance.
    tspec = axes.pspec(bax, axes.seq) if kind == "train" else bspec
    toks = jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)
    out: Dict[str, Tuple[Any, P]] = {"tokens": (toks, tspec)}
    if kind == "train":
        out["labels"] = (toks, tspec)
    if cfg.arch_type == "vlm" and kind in ("train", "prefill"):
        ec = cfg.encoder
        out["image_embeds"] = (
            jax.ShapeDtypeStruct((global_batch, ec.n_ctx, ec.input_dim),
                                 dtype), axes.pspec(bax, None, None))
    if cfg.arch_type == "audio" and kind in ("train", "prefill"):
        ec = cfg.encoder
        out["frames"] = (
            jax.ShapeDtypeStruct((global_batch, ec.n_ctx, cfg.d_model),
                                 dtype), axes.pspec(bax, None, axes.x))
    return out


def stripe_batch(batch, axes: M.MeshAxes):
    """Host-side striping of a global train batch for context
    parallelism: permutes tokens/labels along seq so the contiguous
    per-rank shards of ``batch_struct``'s specs carry the striped
    layout decoder_hidden expects. No-op when seq is unmapped; the
    LM loss is a per-token mean, so the permutation is loss-neutral."""
    p = axes.gseq
    if p <= 1:
        return batch
    out = dict(batch)
    for k in ("tokens", "labels"):
        if k in out:
            out[k] = M.stripe_seq(out[k], p, dim=1)
    return out


def _structs(tree):
    return jax.tree.map(lambda t: t[0], tree,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 2)


def _pspecs(tree):
    return jax.tree.map(lambda t: t[1], tree,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 2)


# ---------------------------------------------------------------------- #
# train step
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class TrainOptions:
    overdecompose: int = 2      # paper §4.2 (2 batch-shards); 1 = off
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs)
    xent_chunks: int = 1
    dtype: Any = jnp.bfloat16
    unroll_layers: bool = False  # exact HLO costs for the dry-run
    mtp_weight: float = 0.0      # DeepSeek MTP loss weight (0 = off)
    # ring-decomposed collective matmuls + weight-gather caching
    # (core/overlap.py; rides down to the layers via axes.with_overlap)
    overlap: OverlapConfig = OverlapConfig()
    # data-parallel gradient sync: bucketed ring reduce-scatter streamed
    # through the overdecompose loop, optionally with ZeRO-1 data-axis
    # sharding of the AdamW state (core/gradsync.py)
    gradsync: GradSyncConfig = GradSyncConfig()


def _loss_fn(cfg: ArchConfig, axes: M.MeshAxes, opts: TrainOptions,
             pstream=None):
    if cfg.arch_type == "audio":
        assert pstream is None  # zero3 is gated to the decoder families
        def f(params, batch):
            return ED.encdec_loss(params, cfg, axes, batch["frames"],
                                  batch["tokens"], batch["labels"],
                                  unroll=opts.unroll_layers)
        return f

    def f(params, batch):
        return D.lm_loss(params, cfg, axes, batch["tokens"],
                         batch["labels"],
                         image_embeds=batch.get("image_embeds"),
                         remat=opts.remat, xent_chunks=opts.xent_chunks,
                         unroll=opts.unroll_layers,
                         remat_policy=opts.remat_policy,
                         mtp_weight=opts.mtp_weight, pstream=pstream)
    return f


def _stack_of(path, local_shape) -> int:
    """Scan-stack detector for the ZeRO-3 leaf plan: every leaf under
    the decoder's ``segments`` subtree is stacked ``(n_periods, ...)``
    for the layer scan — its shard must keep that leading dim so the
    scan can slice per-layer shard rows."""
    keys = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
    if keys and keys[0] == "segments" and len(local_shape) > 0:
        return int(local_shape[0])
    return 1


def _zero3_plan(structs, specs, axes: M.MeshAxes):
    return GS.make_leaf_plan(structs, specs, axes,
                             no_decay=OPT._no_decay, stack_of=_stack_of)


def make_train_step(cfg: ArchConfig, mesh: Mesh, axes: M.MeshAxes,
                    opt_cfg: OPT.AdamWConfig,
                    opts: TrainOptions = TrainOptions()):
    """Returns (jitted_step, param_pspecs, state_pspecs).

    jitted_step(params, opt_state, batch) -> (params, opt_state, metrics).
    """
    axes = axes.with_overlap(opts.overlap)
    structs, specs = init_model(cfg, axes, abstract=True, dtype=opts.dtype)
    pspecs = spec_tree_to_pspecs(specs)
    gs = opts.gradsync
    if axes.gexpert > 1 and gs.enabled:
        raise NotImplementedError(
            "expert parallelism with sharded grad sync (--zero/--zero3/"
            "stream) is not wired yet: the bucket shards lose the "
            "per-param specs the expert-axis reduction needs")
    pstream = None
    if gs.zero3:
        if cfg.arch_type == "audio":
            raise NotImplementedError(
                "gradsync.zero3 (param-shard streaming) is wired for the "
                "decoder families; audio encdec supports zero (ZeRO-1)")
        # ZeRO-3: params live as 1/G_data shards (one stack-aware bucket
        # per leaf); the step's params argument/output IS the shard tree
        plan = _zero3_plan(structs, specs, axes)
        pspecs = GS.param_shard_pspecs(plan, axes)
        spspecs = GS.sharded_state_pspecs(plan, axes)
        pstream = GS.ParamStreamer(plan=plan, axes=axes, ring=gs.ring,
                                   prefetch=gs.prefetch)
    else:
        plan = (GS.make_plan(structs, specs, axes, gs.bucket_bytes,
                             no_decay=OPT._no_decay)
                if gs.enabled else None)
        spspecs = (GS.sharded_state_pspecs(plan, axes) if gs.zero
                   else OPT.state_pspecs(pspecs))
    loss_fn = _loss_fn(cfg, axes, opts, pstream=pstream)

    def scalar_loss(params, batch):
        loss, metrics = loss_fn(params, batch)
        return loss, metrics

    def step(params, opt_state, batch):
        vg = jax.value_and_grad(scalar_loss, has_aux=True)
        n = opts.overdecompose
        stream = gs.enabled and not gs.zero3 and gs.stream
        shards = None
        if n > 1:
            mb = split_batch(batch, n, axes=axes)
            loss = metrics = grads = None
            for i in range(n):
                sub = jax.tree.map(lambda x: x[i], mb)
                (li, mi), gi = vg(params, sub)
                loss = li if loss is None else loss + li
                metrics = mi if metrics is None else jax.tree.map(
                    jnp.add, metrics, mi)
                if stream:
                    # bucket i's reduce-scatter launches here; microbatch
                    # i+1's backward (next vg call) has no data dependency
                    # on these ring hops, so the latency-hiding scheduler
                    # can run the DP rings under its GEMMs — the same
                    # overlap window the x/y/z rings use. fp32 shard
                    # accumulation doubles as the mixed-precision fix.
                    si = GS.reduce_scatter_grads(gi, plan, axes,
                                                 ring=gs.ring)
                    shards = (si if shards is None
                              else [a + b for a, b in zip(shards, si)])
                elif gs.zero3:
                    # zero3: gi is already in the shard layout — each
                    # leaf's gradient came out of the gather's transpose
                    # as a ring reduce-scatter over data, streamed per
                    # layer through this microbatch's own backward
                    si = [g.astype(jnp.float32)
                          for g in jax.tree.leaves(gi)]
                    shards = (si if shards is None
                              else [a + b for a, b in zip(shards, si)])
                else:
                    # accumulate in fp32: bf16 running sums lose ~1 ulp
                    # per add, which compounds as overdecompose grows
                    grads = (jax.tree.map(
                        lambda g: g.astype(jnp.float32), gi)
                        if grads is None else jax.tree.map(
                            lambda a, g: a + g.astype(jnp.float32),
                            grads, gi))
            loss = loss / n
            metrics = jax.tree.map(lambda v: v / n, metrics)
            if shards is not None:
                shards = [s / n for s in shards]
            else:
                grads = jax.tree.map(lambda g: g / n, grads)
        else:
            (loss, metrics), grads = vg(params, batch)

        if axes.gseq > 1:
            # params are replicated over seq; each seq-rank's grads hold
            # only its own tokens' contributions (the KV ring transposes
            # back to the local shard), so sum them like a second DP axis
            if shards is not None:
                shards = [M.psum(s, axes.seq) for s in shards]
            elif grads is not None:
                grads = jax.tree.map(lambda g: M.psum(g, axes.seq), grads)

        if axes.gexpert > 1 and grads is not None:
            # expert is a second data axis for dense params (sum like DP)
            # but shards the expert bank (each rank's grad already holds
            # exactly its own experts' contributions): spec-aware
            grads = expert_reduce_grads(grads, specs, axes, M.psum)

        if gs.zero3:
            if shards is None:
                shards = [g.astype(jnp.float32)
                          for g in jax.tree.leaves(grads)]
            shards = GS.tensor_reduce_shards(shards, plan, axes)
            # the new params ARE the cast master shards (rebuild=False):
            # no param rebroadcast — next step's per-layer gathers
            # re-assemble working copies just in time
            params, opt_state, om = OPT.apply_updates_sharded(
                shards, opt_state, plan, axes, opt_cfg, ring=gs.ring,
                rebuild=False)
        elif gs.enabled:
            # bucketed data-parallel sync (core/gradsync.py): scattered
            # fp32 shards + whole-bucket y/z reductions in place of the
            # per-leaf blocking psums
            if shards is None:
                shards = GS.reduce_scatter_grads(grads, plan, axes,
                                                 ring=gs.ring)
            shards = GS.tensor_reduce_shards(shards, plan, axes)
            if gs.zero:
                params, opt_state, om = OPT.apply_updates_sharded(
                    shards, opt_state, plan, axes, opt_cfg, ring=gs.ring)
            else:
                grads = GS.all_gather_grads(shards, plan, axes,
                                            ring=gs.ring)
                params, opt_state, om = OPT.apply_updates(
                    params, grads, opt_state, specs, axes, opt_cfg)
        else:
            # data-parallel gradient all-reduce (paper §3.1) + z reduction
            # for params whose grads are not already z-reduced by their
            # custom vjp
            grads = jax.tree.map(lambda g: M.psum(g, axes.data), grads)
            grads = z_reduce_grads(grads, specs, axes, M.psum)
            params, opt_state, om = OPT.apply_updates(
                params, grads, opt_state, specs, axes, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    bstruct = batch_struct(cfg, axes, 1, 1)  # spec shapes don't matter here
    bpspecs = _pspecs(bstruct)
    mspec = P()
    mkeys = ["loss", "grad_norm", "lr", "xent"]
    if cfg.arch_type != "audio":
        mkeys.append("aux")
        if opts.mtp_weight > 0 and cfg.mtp_depth > 0:
            mkeys.append("mtp")
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, spspecs, bpspecs),
        out_specs=(pspecs, spspecs, {k: mspec for k in mkeys}),
        check_vma=False)
    return jax.jit(mapped, donate_argnums=(0, 1)), pspecs, spspecs


# ---------------------------------------------------------------------- #
# optimizer-state builders (replicated AdamW vs ZeRO-1 data-sharded)
# ---------------------------------------------------------------------- #

def abstract_opt_state(cfg: ArchConfig, axes: M.MeshAxes,
                       opts: TrainOptions = TrainOptions()):
    """GLOBAL-shaped ShapeDtypeStructs of the optimizer state the train
    step of ``opts`` expects — the sharded-bucket layout under
    ``gradsync.zero``, the replicated per-leaf layout otherwise. The
    dry-run pairs this with ``make_train_step``'s ``spspecs``."""
    axes = axes.with_overlap(opts.overlap)
    structs, specs = init_model(cfg, axes, abstract=True, dtype=opts.dtype)
    gs = opts.gradsync
    if gs.zero3:
        return GS.abstract_sharded_state(_zero3_plan(structs, specs, axes),
                                         axes)
    if gs.zero:
        plan = GS.make_plan(structs, specs, axes, gs.bucket_bytes,
                            no_decay=OPT._no_decay)
        return GS.abstract_sharded_state(plan, axes)
    return OPT.init_state(structs, abstract=True)


def abstract_params(cfg: ArchConfig, axes: M.MeshAxes,
                    opts: TrainOptions = TrainOptions()):
    """(GLOBAL-shaped param structs, PartitionSpecs) in the layout the
    train step of ``opts`` expects: the ZeRO-3 shard tree under
    ``gradsync.zero3``, the replicated-over-data layout otherwise (the
    dry-run pairs this with ``make_train_step``'s param pspecs)."""
    axes = axes.with_overlap(opts.overlap)
    structs, specs = init_model(cfg, axes, abstract=True, dtype=opts.dtype)
    if opts.gradsync.zero3:
        plan = _zero3_plan(structs, specs, axes)
        return GS.abstract_param_shards(plan, axes), \
            GS.param_shard_pspecs(plan, axes)
    return structs, spec_tree_to_pspecs(specs)


def state_layouts(cfg: ArchConfig, axes: M.MeshAxes,
                  opts: TrainOptions = TrainOptions()):
    """((param structs, pspecs), (opt-state structs, pspecs)) of the
    train step of ``opts`` — the persistent per-rank state the ZeRO
    levels shrink; the dry-run prices it per rank for the replicated vs
    ZeRO-1 vs ZeRO-3 memory accounting. One abstract init + one plan
    serves all four trees."""
    axes = axes.with_overlap(opts.overlap)
    structs, specs = init_model(cfg, axes, abstract=True, dtype=opts.dtype)
    pspecs = spec_tree_to_pspecs(specs)
    gs = opts.gradsync
    if gs.zero3:
        plan = _zero3_plan(structs, specs, axes)
        return ((GS.abstract_param_shards(plan, axes),
                 GS.param_shard_pspecs(plan, axes)),
                (GS.abstract_sharded_state(plan, axes),
                 GS.sharded_state_pspecs(plan, axes)))
    if gs.zero:
        plan = GS.make_plan(structs, specs, axes, gs.bucket_bytes,
                            no_decay=OPT._no_decay)
        return ((structs, pspecs),
                (GS.abstract_sharded_state(plan, axes),
                 GS.sharded_state_pspecs(plan, axes)))
    return ((structs, pspecs),
            (OPT.init_state(structs, abstract=True),
             OPT.state_pspecs(pspecs)))


@dataclasses.dataclass(frozen=True)
class GradSyncTools:
    """Jitted companions of a ZeRO-sharded train step.

    ``init(params)`` builds the scattered fp32 state from full
    (replicated-over-data) params; ``gather(state)`` /
    ``scatter(full_state)`` convert to/from the replicated per-leaf
    layout (the checkpoint format — ckpt.py save_sharded/
    restore_sharded); ``plan`` / ``state_pspecs`` are the bucket layout
    and shard_map specs the step was built with. Under ``zero3`` the
    params themselves are sharded too: ``shard_params(full)`` /
    ``unshard_params(shards)`` convert the param tree to/from the shard
    layout (checkpoints stay replicated so g_data can change across
    resume), and ``param_pspecs`` are the shard tree's specs."""

    plan: Any
    state_pspecs: Any
    init: Callable
    gather: Callable
    scatter: Callable
    param_pspecs: Any = None
    shard_params: Optional[Callable] = None
    unshard_params: Optional[Callable] = None


def make_gradsync_tools(cfg: ArchConfig, mesh: Mesh, axes: M.MeshAxes,
                        opts: TrainOptions = TrainOptions()
                        ) -> GradSyncTools:
    """Build the ZeRO state helpers for the same (cfg, mesh, axes, opts)
    a train step was made with (the bucket plan must match)."""
    axes = axes.with_overlap(opts.overlap)
    structs, specs = init_model(cfg, axes, abstract=True, dtype=opts.dtype)
    pspecs = spec_tree_to_pspecs(specs)
    gs = opts.gradsync
    if gs.zero3:
        plan = _zero3_plan(structs, specs, axes)
    else:
        plan = GS.make_plan(structs, specs, axes, gs.bucket_bytes,
                            no_decay=OPT._no_decay)
    sspecs = GS.sharded_state_pspecs(plan, axes)
    fullspecs = OPT.state_pspecs(pspecs)
    init = shard_map(lambda p: GS.init_sharded_state(p, plan, axes),
                     mesh=mesh, in_specs=(pspecs,), out_specs=sspecs,
                     check_vma=False)
    gather = shard_map(lambda s: GS.gather_sharded_state(s, plan, axes),
                       mesh=mesh, in_specs=(sspecs,), out_specs=fullspecs,
                       check_vma=False)
    scatter = shard_map(lambda s: GS.scatter_full_state(s, plan, axes),
                        mesh=mesh, in_specs=(fullspecs,), out_specs=sspecs,
                        check_vma=False)
    extra = {}
    if gs.zero3:
        ppspecs = GS.param_shard_pspecs(plan, axes)
        shard_p = shard_map(lambda p: GS.shard_params(p, plan, axes),
                            mesh=mesh, in_specs=(pspecs,),
                            out_specs=ppspecs, check_vma=False)
        unshard_p = shard_map(
            lambda s: GS.unshard_params(s, plan, axes), mesh=mesh,
            in_specs=(ppspecs,), out_specs=pspecs, check_vma=False)
        extra = dict(param_pspecs=ppspecs,
                     shard_params=jax.jit(shard_p),
                     unshard_params=jax.jit(unshard_p))
    return GradSyncTools(plan=plan, state_pspecs=sspecs,
                         init=jax.jit(init), gather=jax.jit(gather),
                         scatter=jax.jit(scatter), **extra)


# ---------------------------------------------------------------------- #
# elastic snapshot / restore (host replicated layout == checkpoint layout)
# ---------------------------------------------------------------------- #

def snapshot_state(params, opt_state, tools: Optional[GradSyncTools],
                   opts: TrainOptions, *, step: int = 0) -> dict:
    """Host snapshot of the run state in the REPLICATED per-leaf layout.

    This is byte-for-byte the tree ``ckpt.save_sharded`` persists (params
    unsharded under zero3, optimizer state gathered through the same
    jitted ``tools.gather``), kept in memory instead of written to disk —
    the currency of ``MeshLifecycle.reshard``. The plan fingerprint rides
    along so ``restore_state`` can reject a rebuild whose tensor
    partitioning (not just g_data) changed.
    """
    gs = opts.gradsync
    fp = None
    if gs.state_sharded:
        assert tools is not None, "sharded state needs GradSyncTools"
        full_p = tools.unshard_params(params) if gs.zero3 else params
        full_s = tools.gather(opt_state)
        fp = GS.plan_fingerprint(tools.plan)
    else:
        full_p, full_s = params, opt_state
    return {"params": jax.tree.map(np.asarray, jax.device_get(full_p)),
            "opt_state": jax.tree.map(np.asarray, jax.device_get(full_s)),
            "step": int(step), "fingerprint": fp}


def restore_state(snapshot: dict, cfg: ArchConfig, mesh: Mesh,
                  axes: M.MeshAxes, tools: Optional[GradSyncTools],
                  opts: TrainOptions):
    """Re-shard a :func:`snapshot_state` snapshot onto ``(mesh, axes)``.

    Returns ``(params, opt_state)`` in the layout the train step of
    ``opts`` expects on that mesh — sharded through the new mesh's own
    ``scatter``/``shard_params`` tools, i.e. the exact converters
    ``ckpt.restore_sharded`` would use, so restoring from the in-memory
    snapshot and restoring from a checkpoint of the same step are
    bitwise identical.
    """
    axes = axes.with_overlap(opts.overlap)
    structs, specs = init_model(cfg, axes, abstract=True, dtype=opts.dtype)
    pspecs = spec_tree_to_pspecs(specs)
    gs = opts.gradsync
    params = device_put_tree(mesh, snapshot["params"], pspecs)
    if gs.state_sharded:
        assert tools is not None, "sharded state needs GradSyncTools"
        want = snapshot.get("fingerprint")
        if want is not None:
            have = GS.plan_fingerprint(tools.plan)
            if have != want:
                raise ValueError(
                    f"elastic restore: bucket-plan fingerprint {have} != "
                    f"snapshot's {want} — the rebuild changed the tensor "
                    f"partitioning, not just the data axis; the snapshot "
                    f"cannot be re-sharded onto this mesh")
        opt_state = tools.scatter(snapshot["opt_state"])
        if gs.zero3:
            params = tools.shard_params(params)
    else:
        opt_state = device_put_tree(mesh, snapshot["opt_state"],
                                    OPT.state_pspecs(pspecs))
    return params, opt_state


# ---------------------------------------------------------------------- #
# serve steps
# ---------------------------------------------------------------------- #

def make_decode_step(cfg: ArchConfig, mesh: Mesh, axes: M.MeshAxes, *,
                     seqshard: bool = False, dtype=jnp.bfloat16,
                     unroll: bool = False,
                     overlap: OverlapConfig = OverlapConfig()):
    """jitted(params, caches, tokens, pos) -> (logits, caches)."""
    axes = axes.with_overlap(overlap)
    _, specs = init_model(cfg, axes, abstract=True, dtype=dtype)
    pspecs = spec_tree_to_pspecs(specs)
    bspec = axes.pspec(axes.batch_axes(), None)
    if seqshard:
        bspec = P(None, None)  # batch 1: tokens replicated

    if cfg.arch_type == "audio":
        def step(params, caches, tokens, pos):
            return ED.encdec_decode_step(params, cfg, axes, tokens, caches,
                                         pos, unroll=unroll)
    else:
        def step(params, caches, tokens, pos):
            return D.decode_step(params, cfg, axes, tokens, caches, pos,
                                 seqshard=seqshard, unroll=unroll)

    def cspecs(batch_global, seq):
        if cfg.arch_type == "audio":
            return ED.encdec_cache_specs(cfg, axes, batch_global, seq,
                                         dtype=dtype)
        return D.decoder_cache_specs(cfg, axes, batch_global, seq,
                                     seqshard=seqshard, dtype=dtype)

    def build(batch_global, seq):
        ct = cspecs(batch_global, seq)
        cache_pspecs = _pspecs(ct)
        logits_spec = (axes.pspec(axes.batch_axes(), None, axes.y)
                       if not seqshard else axes.pspec(None, None, axes.y))
        mapped = shard_map(
            step, mesh=mesh,
            in_specs=(pspecs, cache_pspecs, bspec, P()),
            out_specs=(logits_spec, cache_pspecs),
            check_vma=False)
        return jax.jit(mapped, donate_argnums=(1,)), ct

    return build, pspecs


def make_paged_step(cfg: ArchConfig, mesh: Mesh, axes: M.MeshAxes, *,
                    dtype=jnp.bfloat16,
                    overlap: OverlapConfig = OverlapConfig()):
    """jitted(params, pools, tokens, positions, q_len, table) ->
    (logits, pools) — the continuous-batching serving step over the
    paged KV cache (launch/serving, docs/serving.md).

    ``build(n_pages_global, page_size)`` returns (fn, pool_tree). Slot
    rows shard over data x z like any batch (their page tables hold each
    shard's LOCAL page ids); KV pools shard pages over data x z and
    heads over y. The engine compiles the same fn at two row widths —
    T = chunk for iterations carrying prefill work, T = 1 for pure
    decode — both against the SAME pool buffers (donated)."""
    axes = axes.with_overlap(overlap)
    _, specs = init_model(cfg, axes, abstract=True, dtype=dtype)
    pspecs = spec_tree_to_pspecs(specs)
    bspec1 = axes.pspec(axes.batch_axes())
    bspec2 = axes.pspec(axes.batch_axes(), None)

    def step(params, pools, tokens, positions, q_len, table):
        return D.paged_step(params, cfg, axes, tokens, pools, positions,
                            q_len, table)

    def build(n_pages_global, page_size):
        ct = D.decoder_paged_cache_specs(cfg, axes, n_pages_global,
                                         page_size, dtype=dtype)
        cache_pspecs = _pspecs(ct)
        logits_spec = axes.pspec(axes.batch_axes(), None, axes.y)
        mapped = shard_map(
            step, mesh=mesh,
            in_specs=(pspecs, cache_pspecs, bspec2, bspec2, bspec1,
                      bspec2),
            out_specs=(logits_spec, cache_pspecs),
            check_vma=False)
        return jax.jit(mapped, donate_argnums=(1,)), ct

    return build, pspecs


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, axes: M.MeshAxes, *,
                      dtype=jnp.bfloat16, unroll: bool = False,
                      overlap: OverlapConfig = OverlapConfig()):
    """jitted(params, caches, batch) -> (last_logits, caches)."""
    axes = axes.with_overlap(overlap)
    _, specs = init_model(cfg, axes, abstract=True, dtype=dtype)
    pspecs = spec_tree_to_pspecs(specs)

    def step(params, caches, batch):
        if cfg.arch_type == "audio":
            enc = ED.encoder_apply(params, cfg, axes, batch["frames"],
                                   unroll=unroll)
            return ED.decoder_apply(params, cfg, axes, batch["tokens"],
                                    enc, mode="prefill", caches=caches,
                                    unroll=unroll)
        return D.prefill(params, cfg, axes, batch["tokens"], caches,
                         image_embeds=batch.get("image_embeds"),
                         unroll=unroll)

    def build(batch_global, seq, cache_seq):
        bt = batch_struct(cfg, axes, batch_global, seq, kind="prefill",
                          dtype=dtype)
        if cfg.arch_type == "audio":
            ct = ED.encdec_cache_specs(cfg, axes, batch_global, cache_seq,
                                       dtype=dtype)
        else:
            ct = D.decoder_cache_specs(cfg, axes, batch_global, cache_seq,
                                       dtype=dtype)
        logits_spec = axes.pspec(axes.batch_axes(), None, axes.y)
        mapped = shard_map(
            step, mesh=mesh,
            in_specs=(pspecs, _pspecs(ct), _pspecs(bt)),
            out_specs=(logits_spec, _pspecs(ct)),
            check_vma=False)
        return jax.jit(mapped, donate_argnums=(1,)), bt, ct

    return build, pspecs


# ---------------------------------------------------------------------- #
# materialization helpers (host -> device with the right shardings)
# ---------------------------------------------------------------------- #

def device_put_tree(mesh: Mesh, values, pspec_tree):
    return jax.tree.map(
        lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
        values, pspec_tree)


def zeros_caches(mesh: Mesh, cache_tree):
    """Materialize zero-filled caches from a (struct, spec) tree."""
    def one(t):
        st, sp = t
        return jax.device_put(jnp.zeros(st.shape, st.dtype),
                              NamedSharding(mesh, sp))
    return jax.tree.map(one, cache_tree,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 2
                        and isinstance(t[0], jax.ShapeDtypeStruct))
