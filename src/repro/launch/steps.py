"""Step builders: jitted, shard_map'ped train / prefill / decode steps.

This is the runtime core every entry point shares (smoke tests, the
dry-run, the training driver, the serving driver). Everything inside the
mapped functions is *manual* SPMD: local shards + the paper's explicit
collectives (core.parallel); the specs computed here are the single source
of truth for how global arrays are laid out.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import mesh as M
from repro.core.compat import shard_map
from repro.core import parallel as PP
from repro.core.overdecompose import split_batch
from repro.core.overlap import OverlapConfig
from repro.core.partition import ParamSpec, spec_tree_to_pspecs, unbox, \
    z_reduce_grads
from repro.models import decoder as D
from repro.models import encdec as ED
from repro.models.base import ArchConfig
from repro.optim import adamw as OPT


# ---------------------------------------------------------------------- #
# model init (boxed -> (params, specs))
# ---------------------------------------------------------------------- #

def init_model(cfg: ArchConfig, axes: M.MeshAxes, key=None, *,
               dtype=jnp.bfloat16, abstract: bool = False):
    if key is None:
        key = jax.random.PRNGKey(0)
    if cfg.arch_type == "audio":
        boxed = ED.encdec_init(key, cfg, axes, dtype=dtype,
                               abstract=abstract)
    else:
        boxed = D.decoder_init(key, cfg, axes, dtype=dtype,
                               abstract=abstract)
    return unbox(boxed)


# ---------------------------------------------------------------------- #
# batch specs
# ---------------------------------------------------------------------- #

def batch_struct(cfg: ArchConfig, axes: M.MeshAxes, global_batch: int,
                 seq: int, *, kind: str = "train",
                 dtype=jnp.bfloat16):
    """GLOBAL ShapeDtypeStructs + PartitionSpecs for one batch."""
    bax = axes.batch_axes()
    bspec = axes.pspec(bax, None)
    toks = jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)
    out: Dict[str, Tuple[Any, P]] = {"tokens": (toks, bspec)}
    if kind == "train":
        out["labels"] = (toks, bspec)
    if cfg.arch_type == "vlm" and kind in ("train", "prefill"):
        ec = cfg.encoder
        out["image_embeds"] = (
            jax.ShapeDtypeStruct((global_batch, ec.n_ctx, ec.input_dim),
                                 dtype), axes.pspec(bax, None, None))
    if cfg.arch_type == "audio" and kind in ("train", "prefill"):
        ec = cfg.encoder
        out["frames"] = (
            jax.ShapeDtypeStruct((global_batch, ec.n_ctx, cfg.d_model),
                                 dtype), axes.pspec(bax, None, axes.x))
    return out


def _structs(tree):
    return jax.tree.map(lambda t: t[0], tree,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 2)


def _pspecs(tree):
    return jax.tree.map(lambda t: t[1], tree,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 2)


# ---------------------------------------------------------------------- #
# train step
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class TrainOptions:
    overdecompose: int = 2      # paper §4.2 (2 batch-shards); 1 = off
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs)
    xent_chunks: int = 1
    dtype: Any = jnp.bfloat16
    unroll_layers: bool = False  # exact HLO costs for the dry-run
    mtp_weight: float = 0.0      # DeepSeek MTP loss weight (0 = off)
    # ring-decomposed collective matmuls + weight-gather caching
    # (core/overlap.py; rides down to the layers via axes.with_overlap)
    overlap: OverlapConfig = OverlapConfig()


def _loss_fn(cfg: ArchConfig, axes: M.MeshAxes, opts: TrainOptions):
    if cfg.arch_type == "audio":
        def f(params, batch):
            return ED.encdec_loss(params, cfg, axes, batch["frames"],
                                  batch["tokens"], batch["labels"],
                                  unroll=opts.unroll_layers)
        return f

    def f(params, batch):
        return D.lm_loss(params, cfg, axes, batch["tokens"],
                         batch["labels"],
                         image_embeds=batch.get("image_embeds"),
                         remat=opts.remat, xent_chunks=opts.xent_chunks,
                         unroll=opts.unroll_layers,
                         remat_policy=opts.remat_policy,
                         mtp_weight=opts.mtp_weight)
    return f


def make_train_step(cfg: ArchConfig, mesh: Mesh, axes: M.MeshAxes,
                    opt_cfg: OPT.AdamWConfig,
                    opts: TrainOptions = TrainOptions()):
    """Returns (jitted_step, param_pspecs, state_pspecs).

    jitted_step(params, opt_state, batch) -> (params, opt_state, metrics).
    """
    axes = axes.with_overlap(opts.overlap)
    _, specs = init_model(cfg, axes, abstract=True, dtype=opts.dtype)
    pspecs = spec_tree_to_pspecs(specs)
    spspecs = OPT.state_pspecs(pspecs)
    loss_fn = _loss_fn(cfg, axes, opts)

    def scalar_loss(params, batch):
        loss, metrics = loss_fn(params, batch)
        return loss, metrics

    def step(params, opt_state, batch):
        vg = jax.value_and_grad(scalar_loss, has_aux=True)
        if opts.overdecompose > 1:
            shards = split_batch(batch, opts.overdecompose)
            loss = metrics = grads = None
            for i in range(opts.overdecompose):
                sub = jax.tree.map(lambda x: x[i], shards)
                (li, mi), gi = vg(params, sub)
                loss = li if loss is None else loss + li
                metrics = mi if metrics is None else jax.tree.map(
                    jnp.add, metrics, mi)
                grads = gi if grads is None else jax.tree.map(
                    jnp.add, grads, gi)
            n = opts.overdecompose
            loss = loss / n
            metrics = jax.tree.map(lambda v: v / n, metrics)
            grads = jax.tree.map(lambda g: g / n, grads)
        else:
            (loss, metrics), grads = vg(params, batch)

        # data-parallel gradient all-reduce (paper §3.1) + z reduction for
        # params whose grads are not already z-reduced by their custom vjp
        grads = jax.tree.map(lambda g: M.psum(g, axes.data), grads)
        grads = z_reduce_grads(grads, specs, axes, M.psum)
        params, opt_state, om = OPT.apply_updates(params, grads, opt_state,
                                                  specs, axes, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    bstruct = batch_struct(cfg, axes, 1, 1)  # spec shapes don't matter here
    bpspecs = _pspecs(bstruct)
    mspec = P()
    mkeys = ["loss", "grad_norm", "lr", "xent"]
    if cfg.arch_type != "audio":
        mkeys.append("aux")
        if opts.mtp_weight > 0 and cfg.mtp_depth > 0:
            mkeys.append("mtp")
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, spspecs, bpspecs),
        out_specs=(pspecs, spspecs, {k: mspec for k in mkeys}),
        check_vma=False)
    return jax.jit(mapped, donate_argnums=(0, 1)), pspecs, spspecs


# ---------------------------------------------------------------------- #
# serve steps
# ---------------------------------------------------------------------- #

def make_decode_step(cfg: ArchConfig, mesh: Mesh, axes: M.MeshAxes, *,
                     seqshard: bool = False, dtype=jnp.bfloat16,
                     unroll: bool = False,
                     overlap: OverlapConfig = OverlapConfig()):
    """jitted(params, caches, tokens, pos) -> (logits, caches)."""
    axes = axes.with_overlap(overlap)
    _, specs = init_model(cfg, axes, abstract=True, dtype=dtype)
    pspecs = spec_tree_to_pspecs(specs)
    bspec = axes.pspec(axes.batch_axes(), None)
    if seqshard:
        bspec = P(None, None)  # batch 1: tokens replicated

    if cfg.arch_type == "audio":
        def step(params, caches, tokens, pos):
            return ED.encdec_decode_step(params, cfg, axes, tokens, caches,
                                         pos, unroll=unroll)
    else:
        def step(params, caches, tokens, pos):
            return D.decode_step(params, cfg, axes, tokens, caches, pos,
                                 seqshard=seqshard, unroll=unroll)

    def cspecs(batch_global, seq):
        if cfg.arch_type == "audio":
            return ED.encdec_cache_specs(cfg, axes, batch_global, seq,
                                         dtype=dtype)
        return D.decoder_cache_specs(cfg, axes, batch_global, seq,
                                     seqshard=seqshard, dtype=dtype)

    def build(batch_global, seq):
        ct = cspecs(batch_global, seq)
        cache_pspecs = _pspecs(ct)
        logits_spec = (axes.pspec(axes.batch_axes(), None, axes.y)
                       if not seqshard else axes.pspec(None, None, axes.y))
        mapped = shard_map(
            step, mesh=mesh,
            in_specs=(pspecs, cache_pspecs, bspec, P()),
            out_specs=(logits_spec, cache_pspecs),
            check_vma=False)
        return jax.jit(mapped, donate_argnums=(1,)), ct

    return build, pspecs


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, axes: M.MeshAxes, *,
                      dtype=jnp.bfloat16, unroll: bool = False,
                      overlap: OverlapConfig = OverlapConfig()):
    """jitted(params, caches, batch) -> (last_logits, caches)."""
    axes = axes.with_overlap(overlap)
    _, specs = init_model(cfg, axes, abstract=True, dtype=dtype)
    pspecs = spec_tree_to_pspecs(specs)

    def step(params, caches, batch):
        if cfg.arch_type == "audio":
            enc = ED.encoder_apply(params, cfg, axes, batch["frames"],
                                   unroll=unroll)
            return ED.decoder_apply(params, cfg, axes, batch["tokens"],
                                    enc, mode="prefill", caches=caches,
                                    unroll=unroll)
        return D.prefill(params, cfg, axes, batch["tokens"], caches,
                         image_embeds=batch.get("image_embeds"),
                         unroll=unroll)

    def build(batch_global, seq, cache_seq):
        bt = batch_struct(cfg, axes, batch_global, seq, kind="prefill",
                          dtype=dtype)
        if cfg.arch_type == "audio":
            ct = ED.encdec_cache_specs(cfg, axes, batch_global, cache_seq,
                                       dtype=dtype)
        else:
            ct = D.decoder_cache_specs(cfg, axes, batch_global, cache_seq,
                                       dtype=dtype)
        logits_spec = axes.pspec(axes.batch_axes(), None, axes.y)
        mapped = shard_map(
            step, mesh=mesh,
            in_specs=(pspecs, _pspecs(ct), _pspecs(bt)),
            out_specs=(logits_spec, _pspecs(ct)),
            check_vma=False)
        return jax.jit(mapped, donate_argnums=(1,)), bt, ct

    return build, pspecs


# ---------------------------------------------------------------------- #
# materialization helpers (host -> device with the right shardings)
# ---------------------------------------------------------------------- #

def device_put_tree(mesh: Mesh, values, pspec_tree):
    return jax.tree.map(
        lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
        values, pspec_tree)


def zeros_caches(mesh: Mesh, cache_tree):
    """Materialize zero-filled caches from a (struct, spec) tree."""
    def one(t):
        st, sp = t
        return jax.device_put(jnp.zeros(st.shape, st.dtype),
                              NamedSharding(mesh, sp))
    return jax.tree.map(one, cache_tree,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 2
                        and isinstance(t[0], jax.ShapeDtypeStruct))
