"""Roofline analysis from compiled dry-run artifacts.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device / ICI_bw_per_chip

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-partition SPMD
program == per device). Collective bytes are NOT in cost_analysis; we parse
the optimized HLO (``compiled.as_text()``) and sum effective per-device
wire bytes of every collective op with the bandwidth-optimal factors
(all-reduce 2(p-1)/p, all-gather/reduce-scatter (p-1)/p, all-to-all
(p-1)/p, collective-permute 1).

Hardware constants (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core import comm_model as CM

# single source of truth for the DEFAULT chip constants is
# comm_model.TPU_V5E (deriving here keeps the analytic model and the HLO
# roofline in lockstep); measured replacements come from
# core/calibrate.py profiles — pass their hardware_params() as ``hw`` to
# analyze()/step_time_estimate() (the dryrun --calib flag does)
PEAK_FLOPS = CM.TPU_V5E.flops
HBM_BW = 819e9
ICI_BW = CM.TPU_V5E.link_bw

# what the compiled-HLO step-time estimate treats as overlappable: the
# ring-decomposed collectives — z weight AG/RS rings, the x/y activation
# all-reduce (RS+AG) rings AND the data-parallel gradient bucket rings of
# core/gradsync.py — all lower to collective-permute chains whose hops
# interleave with compute (per-chunk GEMMs / the next microbatch's
# backward); everything else blocks
OVERLAPPABLE_COLLECTIVES = ("collective-permute",)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# the result type may be a tuple containing `/*index=N*/` comments
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*(\([^)]*\)|\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d,]+\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    return 2  # conservative default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]   # effective per-device wire bytes

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction of the optimized HLO: op kind, replica
    group size, raw result bytes, and the bandwidth-optimal effective
    per-device wire bytes. ``group_size`` lets callers attribute an op to
    a mesh axis (e.g. the dp_sync benchmark asserting no all-reduce of
    data-axis group size remains on the gradient path)."""

    kind: str
    group_size: int
    raw_bytes: int
    wire_bytes: float


def parse_collective_ops(hlo_text: str) -> List[CollectiveOp]:
    """Every collective op of the HLO as a :class:`CollectiveOp` (ops
    with group size <= 1 are dropped; ``-done`` halves of async pairs are
    skipped — the ``-start`` carries the shape)."""
    out: List[CollectiveOp] = []
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:
            continue
        p = _group_size(line)
        if p <= 1:
            continue
        nbytes = _shape_bytes(type_str)
        if kind == "all-reduce":
            eff = 2.0 * (p - 1) / p * nbytes
        elif kind in ("all-gather",):
            eff = (p - 1) / p * nbytes          # result-shaped
        elif kind in ("reduce-scatter",):
            eff = (p - 1) * nbytes               # result is the 1/p shard
        elif kind == "all-to-all":
            eff = (p - 1) / p * nbytes
        else:  # collective-permute
            eff = float(nbytes)
        out.append(CollectiveOp(kind, p, nbytes, eff))
    return out


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    vol: Dict[str, float] = {}
    for op in parse_collective_ops(hlo_text):
        counts[op.kind] = counts.get(op.kind, 0) + 1
        vol[op.kind] = vol.get(op.kind, 0.0) + op.wire_bytes
    return CollectiveStats(counts, vol)


def step_time_estimate(flops: float, bytes_by_kind: Dict[str, float], *,
                       hw: Optional[CM.HardwareParams] = None,
                       cross_step: bool = False) -> CM.StepTime:
    """Overlap-aware step-time estimate from compiled-HLO roofline terms.

    The analytic twin is ``comm_model.predict_step_time`` (closed-form
    shapes); this one prices the *measured* per-device collective bytes:
    collective-permute traffic (the ring-decomposed z weight collectives,
    x/y activation all-reduces and DP gradient/param-shard rings) hides
    under up to ``overlap_efficiency`` of the compute time, blocking
    collectives are fully exposed. ``cross_step`` additionally treats
    all-gather/reduce-scatter traffic as hideable — the cross-step
    window of ``comm_model.dp_sync_time`` where a step's terminal
    gathers ride under the next step's forward and the last
    reduce-scatter under the optimizer math (the HLO byte map carries
    no axis attribution, so this is the coarse-grained twin of that
    per-axis model)."""
    hw = hw or CM.TPU_V5E
    compute_t = flops / hw.flops
    kinds = OVERLAPPABLE_COLLECTIVES
    if cross_step:
        kinds = kinds + ("all-gather", "reduce-scatter")
    hid_b = sum(v for k, v in bytes_by_kind.items() if k in kinds)
    exp_b = sum(v for k, v in bytes_by_kind.items() if k not in kinds)
    hid_t = hid_b / hw.link_bw
    hidden = min(hid_t, hw.overlap_efficiency * compute_t)
    exposed = exp_b / hw.link_bw + (hid_t - hidden)
    return CM.StepTime(compute_t, exposed, hidden)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_t: float
    memory_t: float
    collective_t: float
    exposed_collective_t: float
    hidden_collective_t: float
    step_time_est: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collectives: Dict[str, float]
    collective_counts: Dict[str, int]

    def as_dict(self):
        return dataclasses.asdict(self)


def model_flops_per_device(cfg, shape, n_devices: int) -> float:
    """6*N_active*D for training, 2*N_active*D for prefill/decode,
    divided by device count (to compare with per-device HLO flops).
    The per-token factor is ``comm_model.model_flops_per_token`` — the
    same constant the telemetry MFU divides by."""
    per_tok = CM.model_flops_per_token(
        cfg, "train" if shape.kind == "train" else "serve")
    if shape.kind in ("train", "prefill"):
        total = per_tok * shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        total = per_tok * shape.global_batch
    return total / n_devices


def analyze(compiled, cfg, shape, n_devices: int,
            hw: Optional[CM.HardwareParams] = None) -> Roofline:
    hw = hw or CM.TPU_V5E
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    ct = flops / hw.flops
    mt = hbm / HBM_BW
    lt = stats.total_bytes / hw.link_bw
    est = step_time_estimate(flops, stats.bytes_by_kind, hw=hw)
    dom = max((("compute", ct), ("memory", mt), ("collective", lt)),
              key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(cfg, shape, n_devices)
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=stats.total_bytes,
        compute_t=ct, memory_t=mt, collective_t=lt,
        exposed_collective_t=est.exposed_comm,
        hidden_collective_t=est.hidden_comm, step_time_est=est.total,
        dominant=dom,
        model_flops=mf, useful_ratio=(mf / flops if flops else 0.0),
        collectives=stats.bytes_by_kind,
        collective_counts=stats.counts)


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["total_per_device_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out
