"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def block_matmul_ref(a, b):
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(a.dtype)


def rmsnorm_ref(x, gamma, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * gamma.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D). GQA by head grouping."""
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, T, D)
    scores = jnp.einsum("bhgtd,bhsd->bhgts", qf,
                        k.astype(jnp.float32)) / math.sqrt(D)
    iq = jnp.arange(T)[:, None]
    jk = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= iq >= jk
    if window > 0:
        mask &= (iq - jk) < window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgts,bhsd->bhgtd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, T, D).astype(q.dtype)


def selective_scan_ref(x, dt, A, B, C, s0=None):
    """Sequential oracle. x, dt: (Bt, T, d); A: (d, N); B, C: (Bt, T, N).
    Returns (y, final_state)."""
    Bt, T, d = x.shape
    N = A.shape[-1]
    s = jnp.zeros((Bt, d, N), jnp.float32) if s0 is None else s0

    def step(s, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt.astype(jnp.float32)[..., None] * A)
        dBx = (dtt.astype(jnp.float32) * xt.astype(jnp.float32))[..., None] \
            * bt.astype(jnp.float32)[:, None, :]
        s = s * dA + dBx
        y = jnp.einsum("bdn,bn->bd", s, ct.astype(jnp.float32))
        return s, y

    xs = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), (x, dt, B, C))
    s, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s
