"""Flash attention (online softmax) Pallas TPU kernel.

Hot spot: the attention core that the paper inherits from Megatron's fused
kernels. TPU adaptation: KV-blocked streaming with fp32 (m, l, acc)
accumulators in VMEM scratch; q blocks of 128 rows on the MXU; causal and
sliding-window masking by global block indices; GQA handled in the index
map (kv head = q head // group) so grouped KV is never materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, bq: int, bk: int, causal: bool, window: int,
            scale: float, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    iq = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    jk = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jk < kv_len            # padded keys (ops.py) are invalid
    if causal:
        mask &= iq >= jk
    if window > 0:
        mask &= (iq - jk) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    acc_ref[...] = alpha[:, None] * acc_ref[...] \
        + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "kv_len", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, kv_len: int = 0,
                    interpret: bool = True):
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0.

    T % bq == 0 and S % bk == 0 (ops.py pads)."""
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0
    grid = (B, Hq, T // bq, S // bk)
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_kernel, n_kv=grid[3], bq=bq, bk=bk,
                             causal=causal, window=window, scale=scale,
                             kv_len=kv_len or S)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
