"""Flash attention (online softmax) Pallas TPU kernel.

Hot spot: the attention core that the paper inherits from Megatron's fused
kernels. TPU adaptation: KV-blocked streaming with fp32 (m, l, acc)
accumulators in VMEM scratch; q blocks of 128 rows on the MXU; causal and
sliding-window masking by global block indices; GQA handled in the index
map (kv head = q head // group) so grouped KV is never materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, bq: int, bk: int, causal: bool, window: int,
            scale: float, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    iq = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    jk = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jk < kv_len            # padded keys (ops.py) are invalid
    if causal:
        mask &= iq >= jk
    if window > 0:
        mask &= (iq - jk) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    acc_ref[...] = alpha[:, None] * acc_ref[...] \
        + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _partial_kernel(q_ref, k_ref, v_ref, m_in_ref, l_in_ref, acc_in_ref,
                    o_ref, m_out_ref, l_out_ref, m_ref, l_ref, acc_ref, *,
                    n_kv: int, bq: int, bk: int, causal: bool, window: int,
                    scale: float, q_len: int, kv_len: int, q_pos0: int,
                    q_stride: int, k_pos0: int, k_stride: int):
    """One *partial* online-softmax pass: same streaming update as
    :func:`_kernel` but (m, l, acc) flow in and out unnormalized, so hops
    of a ring (or pages of a paged KV cache) chain through it.

    Q/K positions are affine in the local index (``pos0 + i * stride``) —
    stride g_seq with the striped context-parallel layout, stride 1 for
    contiguous blocks — so causal/window masking runs on *global*
    positions while the refs hold local shards. Keys at local index >=
    ``kv_len`` (block padding) and queries >= ``q_len`` are masked; a row
    that sees no valid key keeps its carry exactly (p is zeroed under the
    mask, so a NEG_INF running max cannot leak exp(0) mass into l)."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = m_in_ref[0, 0]
        l_ref[...] = l_in_ref[0, 0]
        acc_ref[...] = acc_in_ref[0, 0]

    q = q_ref[0, 0].astype(jnp.float32)             # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    li = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    lj = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    iq = q_pos0 + li * q_stride                     # global q positions
    jk = k_pos0 + lj * k_stride                     # global k positions
    mask = (li < q_len) & (lj < kv_len)
    if causal:
        mask &= iq >= jk
    if window > 0:
        mask &= (iq - jk) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # under full masking m_new stays NEG_INF and s - m_new == 0: the
    # explicit mask keeps that exp(0) out of l/acc (carry passes through)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    acc_ref[...] = alpha[:, None] * acc_ref[...] \
        + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...]
        m_out_ref[0, 0] = m_ref[...]
        l_out_ref[0, 0] = l_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "q_len", "kv_len", "q_pos0",
    "q_stride", "k_pos0", "k_stride", "interpret"))
def flash_attention_partial(q, k, v, m, l, acc, *, causal: bool = True,
                            window: int = 0, bq: int = 128, bk: int = 128,
                            q_len: int = 0, kv_len: int = 0,
                            q_pos0: int = 0, q_stride: int = 1,
                            k_pos0: int = 0, k_stride: int = 1,
                            interpret: bool = True):
    """Variable-length / partial-block flash attention over ONE KV block,
    carrying the online softmax state across calls.

    q: (B, Hq, T, D); k, v: (B, Hkv, S, D); m, l: (B, Hq, T) fp32 running
    max / denominator; acc: (B, Hq, T, D) fp32 unnormalized numerator.
    Returns the updated (acc, m, l) — *not* normalized: the caller chains
    further blocks (ring hops, KV pages) and finalizes with
    ``acc / max(l, 1e-30)``. Seed the first call with m = NEG_INF,
    l = acc = 0; a single call seeded that way + finalize equals
    :func:`flash_attention`. ``q_len``/``kv_len`` mask block padding
    (T % bq / S % bk handled by kernels/ops.py), ``*_pos0``/``*_stride``
    give each local index its global position (striped context
    parallelism: stride = g_seq)."""
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0
    grid = (B, Hq, T // bq, S // bk)
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(
        _partial_kernel, n_kv=grid[3], bq=bq, bk=bk, causal=causal,
        window=window, scale=scale, q_len=q_len or T, kv_len=kv_len or S,
        q_pos0=q_pos0, q_stride=q_stride, k_pos0=k_pos0, k_stride=k_stride)
    row = pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi))
    mat = pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0))
    f32 = jnp.float32
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            mat,
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
            row, row, mat,
        ],
        out_specs=[mat, row, row],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, T, D), f32),
                   jax.ShapeDtypeStruct((B, Hq, T), f32),
                   jax.ShapeDtypeStruct((B, Hq, T), f32)],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, m.astype(f32), l.astype(f32), acc.astype(f32))


def _paged_kernel(table_ref, qlen_ref, q_ref, qpos_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, n_pages: int, page: int,
                  window: int, scale: float):
    """Paged variable-length decode/chunked-prefill attention.

    The per-slot page table is a *scalar-prefetch* operand: it drives the
    K/V BlockSpec index maps (physical page id = ``table[b, pi]``), so the
    grid walks each slot's logical pages in order while the DMA engine
    fetches from wherever the allocator put them — the vLLM pattern on
    the PR-6 online-softmax carry. Page-slot ``pi`` of request ``b``
    holds global key positions ``[pi*page, (pi+1)*page)``; causal masking
    runs against the per-row global query positions ``qpos`` and rows
    ``>= qlen[b]`` (chunk padding / idle slots) are masked entirely. A
    fully-masked row contributes exact zeros (p is zeroed under the
    mask), so null/stale pages never leak probability mass."""
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (T, D)
    k = k_ref[0, 0].astype(jnp.float32)             # (page, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    T = q.shape[0]
    iq = qpos_ref[0]                                # (T,) global q positions
    jk = pi * page + jax.lax.broadcasted_iota(jnp.int32, (T, page), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (T, page), 0)
    mask = (row < qlen_ref[b]) & (iq[:, None] >= jk)
    if window > 0:
        mask &= (iq[:, None] - jk) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    acc_ref[...] = alpha[:, None] * acc_ref[...] \
        + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_attention_paged(q, k_pages, v_pages, table, q_pos, q_len, *,
                          window: int = 0, interpret: bool = True):
    """Paged/variable-length flash attention over a physical KV page pool.

    q: (B, Hq, T, D) — T is 1 for pure decode, the chunk length for
    chunked prefill; k_pages/v_pages: (P, Hkv, page, D) page pools;
    table: (B, n_pages) int32 per-slot page table (page-slot p of slot b
    lives in physical page ``table[b, p]``; unallocated slots point at
    the reserved null page 0); q_pos: (B, T) int32 global query
    positions; q_len: (B,) int32 valid query rows per slot.

    ``table``/``q_len`` ride :class:`pltpu.PrefetchScalarGridSpec` so the
    table gather happens in the index maps, not the kernel body. The jnp
    oracle is ``layers.attention.paged_attn_core``; tests validate the
    two against each other. On hardware T/page/D should be lane/sublane
    multiples; interpret mode (the CI backend) takes any shape."""
    B, Hq, T, D = q.shape
    Hkv, page = k_pages.shape[1], k_pages.shape[2]
    n_pages = table.shape[1]
    g = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_paged_kernel, n_pages=n_pages, page=page,
                             window=window, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hq, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, T, D),
                         lambda b, h, pi, table, qlen: (b, h, 0, 0)),
            pl.BlockSpec((1, T),
                         lambda b, h, pi, table, qlen: (b, 0)),
            pl.BlockSpec((1, 1, page, D),
                         lambda b, h, pi, table, qlen:
                         (table[b, pi], h // g, 0, 0)),
            pl.BlockSpec((1, 1, page, D),
                         lambda b, h, pi, table, qlen:
                         (table[b, pi], h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, D),
                               lambda b, h, pi, table, qlen: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), q_len.astype(jnp.int32),
      q, q_pos.astype(jnp.int32), k_pages, v_pages)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "kv_len", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, kv_len: int = 0,
                    interpret: bool = True):
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0.

    T % bq == 0 and S % bk == 0 (ops.py pads)."""
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0
    grid = (B, Hq, T // bq, S // bk)
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_kernel, n_kv=grid[3], bq=bq, bk=bk,
                             causal=causal, window=window, scale=scale,
                             kv_len=kv_len or S)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
