"""Fused RMSNorm Pallas TPU kernel (row-tiled, fp32 statistics)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "eps", "interpret"))
def rmsnorm(x, gamma, *, bm: int = 256, eps: float = 1e-6,
            interpret: bool = True):
    """x: (M, D); gamma: (D,). M % bm == 0 (ops.py pads)."""
    M, D = x.shape
    bm = min(bm, M)
    assert M % bm == 0
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, D), lambda mi: (mi, 0)),
                  pl.BlockSpec((D,), lambda mi: (0,))],
        out_specs=pl.BlockSpec((bm, D), lambda mi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        interpret=interpret,
    )(x, gamma)
