"""Tiled MXU matmul — the local GEMM of paper Algorithm 1 (lines 6/13).

The paper's per-GPU compute is exactly these block GEMMs on the local
partitions X_i, W_ij; Megatron's fused CUDA kernels are the GPU analogue.
TPU adaptation: (bm, bk) x (bk, bn) VMEM tiles, 128-aligned for the MXU,
fp32 accumulation in scratch across the K grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def block_matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 512,
                 interpret: bool = True):
    """a: (M, K) @ b: (K, N) -> (M, N). Dims must divide the block sizes
    (ops.py pads)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
