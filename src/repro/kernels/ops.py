"""jit'd public wrappers for the Pallas kernels: shape padding to block
multiples, GQA-aware dispatch, dtype handling. Models call these (behind
the ``use_kernels`` flag); tests sweep shapes/dtypes against ref.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.block_matmul import block_matmul as _bmm
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_attention import (
    flash_attention_partial as _flash_partial)
from repro.kernels.flash_attention import (
    flash_attention_paged as _flash_paged)
from repro.kernels.rmsnorm import rmsnorm as _rms
from repro.kernels.selective_scan import selective_scan as _scan


def _pad_to(x, mult: int, axis: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def matmul(a, b, *, bm=128, bn=128, bk=512, interpret=True):
    """Padded tiled matmul: (M, K) @ (K, N)."""
    a, M = _pad_to(a, bm, 0)
    a, K = _pad_to(a, bk, 1)
    b, _ = _pad_to(b, bk, 0)
    b, N = _pad_to(b, bn, 1)
    out = _bmm(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


def flash_attention(q, k, v, *, causal=True, window=0, bq=128, bk=128,
                    interpret=True):
    """(B, Hq, T, D) x (B, Hkv, S, D) padded flash attention.

    Padding keys are masked out by padding k positions past S with -inf
    handling: we pad T/S and slice back; padded kv rows are masked because
    causal/global masking uses *true* lengths via explicit masking of the
    padded region (scores for j >= S get NEG_INF through the window/causal
    mask only when causal — for the general case we pad S and rely on
    slicing q rows; kv padding is handled by masking inside via length)."""
    T0, S0 = q.shape[2], k.shape[2]
    q, _ = _pad_to(q, bq, 2)
    k, _ = _pad_to(k, bk, 2)
    v, _ = _pad_to(v, bk, 2)
    out = _flash(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                 kv_len=S0, interpret=interpret)
    return out[:, :, :T0, :]


def flash_attention_partial(q, k, v, m, l, acc, *, causal=True, window=0,
                            bq=128, bk=128, q_pos0=0, q_stride=1,
                            k_pos0=0, k_stride=1, interpret=True):
    """Padded partial-block flash attention over one KV block, carrying
    the unnormalized online-softmax state (m, l, acc) across calls —
    the ring-attention hop / paged-KV entry point.

    Handles non-dividing T/S by block padding: padded queries carry
    their state through untouched, padded keys are masked via
    ``kv_len``. Returns the updated (acc, m, l) sliced back to T;
    finalize with ``acc / max(l, 1e-30)`` after the last block."""
    T0, S0 = q.shape[2], k.shape[2]
    q, _ = _pad_to(q, bq, 2)
    k, _ = _pad_to(k, bk, 2)
    v, _ = _pad_to(v, bk, 2)
    m, _ = _pad_to(m, bq, 2)
    l, _ = _pad_to(l, bq, 2)
    acc, _ = _pad_to(acc, bq, 2)
    acc, m, l = _flash_partial(
        q, k, v, m, l, acc, causal=causal, window=window, bq=bq, bk=bk,
        q_len=T0, kv_len=S0, q_pos0=q_pos0, q_stride=q_stride,
        k_pos0=k_pos0, k_stride=k_stride, interpret=interpret)
    return acc[:, :, :T0, :], m[:, :, :T0], l[:, :, :T0]


def flash_attention_paged(q, k_pages, v_pages, table, q_pos, q_len, *,
                          window=0, interpret=True):
    """Paged variable-length flash attention in the MODEL's layouts:
    q (R, T, nq, hd) row-major slots, pools (P, page, H, hd) as stored by
    ``layers.attention.paged_attn_cache_spec``, table (R, n_pages) int32,
    q_pos (R, T), q_len (R,). Transposes to the kernel's head-major
    layout, runs the scalar-prefetch paged kernel, transposes back.
    Interpret mode accepts arbitrary T/page; on hardware keep them
    lane/sublane multiples."""
    qk = jnp.moveaxis(q, 2, 1)               # (R, nq, T, hd)
    kp = jnp.moveaxis(k_pages, 2, 1)         # (P, H, page, hd)
    vp = jnp.moveaxis(v_pages, 2, 1)
    out = _flash_paged(qk, kp, vp, table, q_pos, q_len, window=window,
                       interpret=interpret)
    return jnp.moveaxis(out, 1, 2)           # (R, T, nq, hd)


def rmsnorm(x, gamma, *, eps=1e-6, bm=256, interpret=True):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    x2, M = _pad_to(x2, bm, 0)
    out = _rms(x2, gamma, bm=bm, eps=eps, interpret=interpret)
    return out[:M].reshape(shape)


def selective_scan(x, dt, A, B, C, *, bd=256, ck=128, interpret=True):
    """Padded selective scan; pads T with dt=0 steps (identity updates)."""
    T0 = x.shape[1]
    x, _ = _pad_to(x, ck, 1)
    dt, _ = _pad_to(dt, ck, 1)
    B, _ = _pad_to(B, ck, 1)
    C, _ = _pad_to(C, ck, 1)
    d0 = x.shape[2]
    x, _ = _pad_to(x, bd, 2)
    dt, _ = _pad_to(dt, bd, 2)
    A, _ = _pad_to(A, bd, 0)
    out = _scan(x, dt, A, B, C, bd=bd, ck=ck, interpret=interpret)
    return out[:, :T0, :d0]
