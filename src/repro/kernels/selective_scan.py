"""Selective-scan (Mamba S6) Pallas TPU kernel.

Hot spot: jamba's recurrence. TPU adaptation: channels live on the VPU
lanes (block over d), the sequence is processed in chunks with the carry
state in VMEM scratch across the sequential chunk grid dimension; within a
chunk a fori_loop steps time with fully vectorized (bd, N) updates. The
grid is (B, d_blocks, chunks) — chunks is minor-most so the carry is
correct, and (B, d_blocks) parallelize.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *,
            ck: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[...].astype(jnp.float32)              # (bd, N)

    def step(t, s):
        xt = x_ref[0, t, :].astype(jnp.float32)     # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)
        bt = b_ref[0, t, :].astype(jnp.float32)     # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)
        dA = jnp.exp(dtt[:, None] * a)              # (bd, N)
        s = s * dA + (dtt * xt)[:, None] * bt[None, :]
        y_ref[0, t, :] = jnp.sum(s * ct[None, :], axis=-1).astype(
            y_ref.dtype)
        return s

    s_ref[...] = jax.lax.fori_loop(0, ck, step, s_ref[...])


@functools.partial(jax.jit, static_argnames=("bd", "ck", "interpret"))
def selective_scan(x, dt, A, B, C, *, bd: int = 256, ck: int = 128,
                   interpret: bool = True):
    """x, dt: (Bt, T, d); A: (d, N); B, C: (Bt, T, N). Returns y (Bt, T, d).

    d % bd == 0 and T % ck == 0 (ops.py pads)."""
    Bt, T, d = x.shape
    N = A.shape[-1]
    bd = min(bd, d)
    ck = min(ck, T)
    assert d % bd == 0 and T % ck == 0
    grid = (Bt, d // bd, T // ck)
    kern = functools.partial(_kernel, ck=ck, n_chunks=grid[2])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, ck, bd), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((bd, N), lambda b, di, ci: (di, 0)),
            pl.BlockSpec((1, ck, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((1, ck, N), lambda b, di, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, ck, bd), lambda b, di, ci: (b, ci, di)),
        out_shape=jax.ShapeDtypeStruct((Bt, T, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
