"""Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling) for the
paper's compute hot spots, with jit wrappers (ops) and jnp oracles (ref)."""
