"""Deterministic synthetic data pipeline.

Produces reproducible token streams (and stub modality embeddings) keyed by
(seed, step) — the same global batch regardless of mesh shape, so loss
curves are comparable across decompositions (paper Fig. 6 methodology: the
parallelization must not change statistical efficiency).

Two text generators:
  * ``zipf``: unigram Zipf draw (fast, for throughput tests)
  * ``markov``: a fixed random bigram chain — *learnable* structure so
    smoke/validation losses actually descend like Fig. 6's curves.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "markov"      # "markov" | "zipf"
    n_states: int = 64        # markov chain order-1 state count


class SyntheticText:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        k = min(cfg.n_states, v)
        # sparse-ish bigram transition over k "hub" tokens mixed with tail
        self._hubs = rng.choice(v, size=k, replace=False)
        self._trans = rng.dirichlet(np.ones(k) * 0.3, size=k)
        self._start = rng.dirichlet(np.ones(k))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step)
                                    % (2 ** 31 - 1))
        B, S = cfg.global_batch, cfg.seq_len
        if cfg.kind == "zipf":
            toks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
            toks = (toks % cfg.vocab_size).astype(np.int32)
        else:
            k = len(self._hubs)
            states = np.empty((B, S + 1), np.int32)
            states[:, 0] = rng.choice(k, size=B, p=self._start)
            u = rng.random_sample((B, S))
            cum = np.cumsum(self._trans, axis=1)
            for t in range(S):
                states[:, t + 1] = (
                    cum[states[:, t]] > u[:, t:t + 1]).argmax(axis=1)
            toks = self._hubs[states].astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def stub_frames(step: int, batch: int, n_ctx: int, dim: int,
                seed: int = 7) -> np.ndarray:
    """Deterministic stand-in for the audio conv / vision ViT frontend
    (the assignment's one allowed stub)."""
    rng = np.random.RandomState((seed * 999_983 + step) % (2 ** 31 - 1))
    return rng.randn(batch, n_ctx, dim).astype(np.float32)


def make_batch(cfg_arch, step: int, data: SyntheticText,
               dtype=np.float32) -> Dict[str, np.ndarray]:
    """Full batch for an architecture (adds stub modality inputs)."""
    b = data.batch(step)
    if cfg_arch.arch_type == "vlm":
        ec = cfg_arch.encoder
        b["image_embeds"] = stub_frames(step, data.cfg.global_batch,
                                        ec.n_ctx, ec.input_dim).astype(dtype)
    if cfg_arch.arch_type == "audio":
        ec = cfg_arch.encoder
        b["frames"] = stub_frames(step, data.cfg.global_batch, ec.n_ctx,
                                  cfg_arch.d_model).astype(dtype)
    return b
