"""Deterministic synthetic data pipeline."""
from repro.data.synthetic import DataConfig, SyntheticText, make_batch
