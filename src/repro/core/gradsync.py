"""ZeRO-sharded data-parallel gradient synchronization (bucketed rings).

The paper's 4th dimension is plain data parallelism whose gradient
all-reduce is meant to hide behind backward compute (AxoNN's asynchronous
message-driven design, arXiv:2110.13005; memory-optimized in its
production successor, arXiv:2502.08145). The blocking form in
``launch/steps.py`` was one ``psum`` per gradient leaf over ``axes.data``
*after* the whole overdecompose loop — fully exposed, with AdamW state
replicated across ``G_data``.

This module replaces that with a subsystem built on the ring machinery of
:mod:`repro.core.mesh`:

  * **Bucketing** (:func:`make_plan`): the gradient tree is flattened into
    size-bounded fp32 buckets. Leaves are grouped by their reduction class
    ``(z_reduced, y_reduce, dtype)`` so a whole bucket shares one
    tensor-axis reduction schedule, then packed greedily in tree order
    under ``bucket_mb`` (at least one leaf per bucket) and padded to a
    multiple of ``G_data`` so the reduce-scatter splits evenly.
  * **Streamed reduce-scatter**: each microbatch's bucket gradients are
    reduce-scattered over the ``data`` ring (``ring_reduce_scatter``,
    i.e. ``lax.ppermute`` chains) *inside* the overdecompose loop —
    microbatch ``i+1``'s backward has no data dependency on microbatch
    ``i``'s ring hops, so XLA's latency-hiding scheduler can overlap them
    exactly like the x/y/z rings. Shards accumulate in fp32.
  * **ZeRO-1 state sharding**: with ``zero`` on, the scattered gradients
    are never re-gathered; each data rank keeps fp32 AdamW state
    (m/v/master) only for its ``1/G_data`` bucket shard
    (``optim.adamw.apply_updates_sharded``) and a ring all-gather
    rebroadcasts the updated params — optimizer memory drops by
    ``G_data`` on top of the z-axis sharding the 4D layout already gives.
  * **ZeRO-3 param-shard streaming**: with ``zero3`` on, the params are
    never rebroadcast either — they live permanently as ``1/G_data``
    shards (one stack-aware bucket per leaf, :func:`make_leaf_plan`, so
    the layer scans of the models can slice per-layer shard rows) and
    each layer's working copy is assembled just-in-time inside the layer
    scan body by a ring all-gather over the data axis — the same
    place/accumulate ``ppermute`` convention as the z-axis weight rings
    of :mod:`repro.core.collective_matmul`, generalized to the data ring
    (:class:`ParamStreamer`). The gather sits *inside* the rematerialized
    scan body, so the working copy is released after each layer's
    forward and re-gathered by remat for its backward; with ``prefetch``
    the next layer's gathered copy rides the scan carry instead
    (gathered one layer ahead — its ring hops overlap the current
    layer's GEMMs — and retained as a saved carry for the backward, no
    re-gather: FSDP's reshard_after_forward=False point). The backward's
    gradient w.r.t. each shard is the *transpose* of the gather — a ring
    reduce-scatter summed over data — so every microbatch's DP gradient
    sync streams through the backward itself, per layer, for free.

Per-element metadata that the blocking path read off the pytree (weight
decay masks, which mesh axes a leaf's grad-norm contribution must be
psum'd over) cannot use static per-rank segment boundaries under SPMD —
the scattered shard's content depends on ``axis_index``. It is instead
encoded as a per-bucket ``int8`` group-id array (:class:`GroupMeta`)
whose own shard is carved out with ``dynamic_slice`` at the rank's ring
index.

Every ring schedule here is a pure decomposition of the blocking one
(DESIGN.md §Data-parallel sync schedule): same operands reduced to the
same places, bitwise on exactly-summable values, and bitwise-identical to
the blocking ``psum`` at ``G_data = 2`` (two-term fp addition commutes).

Knob units and degeneracy guarantees (DESIGN.md §Data-parallel sync /
§ZeRO-3 streaming; pinned by tests/test_gradsync.py, tests/test_zero3.py):

  * ``bucket_mb`` — fp32 bucket bound in **MiB** (the α-latency grain of
    ``comm_model.dp_sync_time``: smaller buckets = finer overlap, more
    ring launches).
  * ``GradSyncConfig()`` (all off) ⇒ the per-leaf blocking ``psum`` path
    of launch/steps.py, bit for bit.
  * ``stream=False`` or one microbatch ⇒ RS + AG volume == the blocking
    all-reduce volume exactly (Patarasuk-Yuan).
  * ``cross_step=False`` ⇒ ``comm_model.dp_sync_time`` is exactly the
    PR-3 exposed model; with it on, the hidden fraction of the terminal
    passes scales with the *measured* ``HardwareParams.
    cross_step_efficiency`` (core/calibrate.py; 1.0 uncalibrated = the
    PR-4 model).
  * ``zero3`` with ``prefetch`` at one microbatch ⇒ AG + RS == the
    all-reduce volume (ZeRO-3's volume floor is the blocking one).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import mesh as M
from repro.core import trace
from repro.core.partition import ParamSpec


# ---------------------------------------------------------------------- #
# config
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    """Knobs for the data-parallel gradient synchronization subsystem.

    bucketed: replace the per-leaf blocking ``psum`` over ``data`` with
    bucketed ring reduce-scatter + all-gather of the *gradients* (AdamW
    state stays replicated). zero: additionally keep the gradients
    scattered and shard the AdamW state ZeRO-1-style over ``data``
    (implies the bucketed schedule; the all-gather moves updated *params*
    instead of gradients). zero3: additionally shard the *params* over
    ``data`` (one stack-aware bucket per leaf, :func:`make_leaf_plan`)
    and stream each layer's working copy just-in-time through the layer
    scan (:class:`ParamStreamer`) — param memory drops by ``G_data`` on
    top of the ZeRO-1 optimizer drop; the update's param rebroadcast
    disappears (new shards come straight from the master shards). All
    off (default) keeps the blocking path.

    prefetch (zero3 only): gather layer ``i+1``'s shards during layer
    ``i``'s compute via the scan carry and *retain* the gathered copy
    for the backward (no re-gather; per-rank peak param memory returns
    to ~full — the comm-vs-memory point of FSDP's
    reshard_after_forward=False). Off (default): the gather lives inside
    the rematerialized scan body, released after the layer and
    re-gathered for its backward — peak param memory is the shards plus
    one in-flight layer's working set.

    cross_step: comm-model knob only (``comm_model.dp_sync_time``):
    model the cross-step overlap window where the terminal collectives
    of step t — the ZeRO-1 param all-gather / ZeRO-3 first-layer gather
    and the last microbatch's reduce-scatter — hide under step t+1's
    first-microbatch forward and the optimizer math respectively. Off
    reproduces the fully-exposed terminal model exactly.

    bucket_mb: fp32 bucket size bound in MiB. Smaller buckets give the
    scheduler finer-grained ring/backward pairs to overlap but pay more
    α-latency (``comm_model.dp_sync_time`` prices exactly this).

    stream: issue each microbatch's bucket reduce-scatters *inside* the
    overdecompose loop (the overlap window — DP comm of microbatch i
    rides under microbatch i+1's backward). Off accumulates fp32 locally
    and reduce-scatters once after the loop (lower volume at high
    overdecompose, no overlap window).

    ring: decompose the data-axis collectives into ``ppermute`` ring hops
    (collective-permute chains in HLO). Off uses the blocking
    ``psum_scatter``/``all_gather`` (still no all-reduce over ``data``).
    """

    bucketed: bool = False
    zero: bool = False
    zero3: bool = False
    prefetch: bool = False
    cross_step: bool = False
    bucket_mb: float = 4.0
    stream: bool = True
    ring: bool = True

    def __post_init__(self):
        if self.bucket_mb <= 0:
            raise ValueError(f"bucket_mb must be > 0, got {self.bucket_mb}")
        if self.prefetch and not self.zero3:
            raise ValueError("prefetch is a zero3 knob (param-shard "
                             "streaming retention); set zero3=True")

    @property
    def enabled(self) -> bool:
        return self.bucketed or self.zero or self.zero3

    @property
    def state_sharded(self) -> bool:
        """AdamW state lives as 1/G_data shards (ZeRO-1 and up)."""
        return self.zero or self.zero3

    @property
    def bucket_bytes(self) -> int:
        return int(self.bucket_mb * 2 ** 20)


# ---------------------------------------------------------------------- #
# bucket plan
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class GroupMeta:
    """One per-element metadata class inside a bucket: whether weight
    decay applies and which mesh axes the element's grad-norm
    contribution must be psum'd over (the leaf's sharded axes, exactly
    as ``optim.adamw.global_grad_norm`` reads them off the ParamSpec)."""

    decay: bool
    norm_names: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Segment:
    """One leaf's slice of a bucket (offsets/sizes in *local* elements)."""

    leaf: int                 # index into the flattened param/grad tree
    offset: int               # start inside the (unpadded) bucket
    size: int                 # local element count
    shape: Tuple[int, ...]    # local shape


@dataclasses.dataclass(eq=False)
class Bucket:
    """``stack == 1`` buckets are flat ``(padded,)`` buffers (the PR-3
    gradient plan). ``stack > 1`` buckets hold one *scan-stacked* leaf
    (:func:`make_leaf_plan`): ``size``/``padded``/``gid`` and the
    segment offsets describe ONE stack slot (one layer of the scan), the
    flat buffer is ``(stack, padded)``, and every collective/shard slice
    works on the last dim — so a layer scan can slice row ``i`` and
    gather just that layer's shard."""

    segments: Tuple[Segment, ...]
    size: int                 # unpadded elements (per stack slot)
    padded: int               # padded to a multiple of dp (per slot)
    z_reduced: bool           # grads already reduce-scattered over z
    y_reduce: bool            # grads need a psum over y
    dtype: Any                # param dtype of every leaf in this bucket
    groups: Tuple[GroupMeta, ...]
    gid: np.ndarray           # (padded,) int8 group id per element
    stack: int = 1            # leading scan dim (1 = unstacked)


@dataclasses.dataclass(eq=False)
class BucketPlan:
    buckets: Tuple[Bucket, ...]
    treedef: Any              # treedef of the param/grad tree
    dp: int                   # flattened data-ring size
    n_leaves: int

    @property
    def shard_sizes(self) -> Tuple[int, ...]:
        """Per-rank fp32 elements per bucket, per stack slot."""
        return tuple(b.padded // self.dp for b in self.buckets)

    @property
    def total_elements(self) -> int:
        return sum(b.size * b.stack for b in self.buckets)

    @property
    def padded_elements(self) -> int:
        return sum(b.padded * b.stack for b in self.buckets)


def plan_fingerprint(plan: BucketPlan) -> str:
    """Stable digest of a plan's *replicated* leaf layout.

    Covers everything that determines the replicated per-leaf state a
    checkpoint (or an in-memory elastic snapshot) carries — leaf count,
    per-slot sizes/segments, stacking, dtypes, reduction classes — but
    deliberately excludes ``dp`` and the dp-derived padding, so two
    plans built at different ``g_data`` over the same model/tensor
    factors fingerprint identically. ``launch.steps.restore_state``
    compares fingerprints across an elastic rebuild: a mismatch means
    the rebuild changed the tensor partitioning (not just the data
    axis) and the snapshot cannot be re-sharded onto it.
    """
    import hashlib
    h = hashlib.sha256(f"{plan.n_leaves}".encode())
    for b in plan.buckets:
        h.update(f"|{b.size}:{b.stack}:{jnp.dtype(b.dtype).name}"
                 f":{int(b.z_reduced)}:{int(b.y_reduce)}".encode())
        for s in b.segments:
            h.update(f";{s.leaf}:{s.offset}:{s.size}:{s.shape}".encode())
    return h.hexdigest()[:16]


def _local_shape(shape, spec, axes: M.MeshAxes) -> Tuple[int, ...]:
    """Per-device shape of a leaf whose GLOBAL shape is ``shape``."""
    sizes = dict(axes.sizes)
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        p = 1
        if entry is not None:
            names = entry if isinstance(entry, tuple) else (entry,)
            p = math.prod(sizes.get(n, 1) for n in names)
        if dim % p:
            raise ValueError(f"dim {dim} not divisible by axis product {p} "
                             f"of spec entry {entry!r}")
        out.append(dim // p)
    return tuple(out)


def _norm_names(spec) -> Tuple[str, ...]:
    """Mesh axes a leaf's grad-norm contribution is psum'd over (same
    extraction as ``optim.adamw.global_grad_norm``)."""
    return tuple(n for entry in spec if entry is not None
                 for n in (entry if isinstance(entry, tuple) else (entry,)))


def make_plan(structs, specs, axes: M.MeshAxes, bucket_bytes: int, *,
              no_decay: Optional[Callable] = None) -> BucketPlan:
    """Pack the param/grad tree into size-bounded fp32 buckets.

    ``structs`` are GLOBAL-shaped leaves (abstract init output); sizes in
    the plan are per-device. ``no_decay(path) -> bool`` marks leaves that
    skip weight decay (``optim.adamw._no_decay``); None = decay
    everywhere the config asks. Leaves are grouped by reduction class
    ``(z_reduced, y_reduce, dtype)`` — one bucket never mixes classes, so
    the post-scatter tensor-axis reductions apply to whole buckets — then
    packed greedily in tree order with at least one leaf per bucket, and
    padded to a multiple of the data-ring size.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(structs)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec))
    assert len(flat) == len(spec_leaves)
    dp = max(axes.dp, 1)
    cap = max(int(bucket_bytes) // 4, 1)  # buckets are fp32

    # one open bucket per reduction class: key -> [(Segment, GroupMeta)]
    open_buckets: dict = {}
    done: List[Bucket] = []

    def close(key):
        items = open_buckets.pop(key)
        segs = tuple(s for s, _ in items)
        size = sum(s.size for s in segs)
        padded = -(-size // dp) * dp
        gid = np.zeros((padded,), np.int8)
        groups: List[GroupMeta] = []
        gix: dict = {}
        for seg, meta in items:
            g = gix.setdefault(meta, len(groups))
            if g == len(groups):
                groups.append(meta)
            gid[seg.offset:seg.offset + seg.size] = g
        if len(groups) > 127:
            raise ValueError("too many metadata groups in one bucket")
        z_red, y_red, dtname = key
        done.append(Bucket(segments=segs, size=size, padded=padded,
                           z_reduced=z_red, y_reduce=y_red,
                           dtype=jnp.dtype(dtname),
                           groups=tuple(groups), gid=gid))

    for i, ((path, leaf), ps) in enumerate(zip(flat, spec_leaves)):
        lshape = _local_shape(tuple(leaf.shape), tuple(ps.spec), axes)
        size = int(np.prod(lshape)) if lshape else 1
        key = (bool(ps.z_reduced), bool(ps.y_reduce),
               jnp.dtype(leaf.dtype).name)
        meta = GroupMeta(decay=(no_decay is None or not no_decay(path)),
                         norm_names=_norm_names(tuple(ps.spec)))
        items = open_buckets.get(key)
        if items is not None and sum(s.size for s, _ in items) + size > cap:
            close(key)
            items = None
        if items is None:
            items = open_buckets[key] = []
        off = sum(s.size for s, _ in items)
        items.append((Segment(leaf=i, offset=off, size=size, shape=lshape),
                      meta))
    for key in list(open_buckets):
        close(key)
    return BucketPlan(buckets=tuple(done), treedef=treedef, dp=dp,
                      n_leaves=len(flat))


def make_leaf_plan(structs, specs, axes: M.MeshAxes, *,
                   no_decay: Optional[Callable] = None,
                   stack_of: Optional[Callable] = None) -> BucketPlan:
    """The ZeRO-3 param-shard layout: one bucket per leaf, in tree order
    (``plan.buckets[i]`` <-> tree leaf ``i``), so a shard tree carries
    the params' own pytree structure and the models' layer scans can
    slice it unchanged.

    ``stack_of(path, local_shape) -> int`` marks scan-stacked leaves
    (leading layer dim; 1 / None = unstacked): a stacked leaf is sharded
    *per stack slot* — shard shape ``(stack, padded // dp)`` — so slicing
    row ``i`` yields exactly layer ``i``'s shard and the just-in-time
    gather stays per-layer. Padding/metadata machinery is shared with
    :func:`make_plan` (the gradient bucket plan); every downstream
    consumer — sharded AdamW, grad norm, checkpoint gather/scatter —
    works on either plan.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(structs)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec))
    assert len(flat) == len(spec_leaves)
    dp = max(axes.dp, 1)
    done: List[Bucket] = []
    for i, ((path, leaf), ps) in enumerate(zip(flat, spec_leaves)):
        lshape = _local_shape(tuple(leaf.shape), tuple(ps.spec), axes)
        stack = int(stack_of(path, lshape)) if stack_of else 1
        if stack > 1:
            assert lshape and lshape[0] == stack, (path, lshape, stack)
            slot_shape = lshape[1:]
        else:
            stack, slot_shape = 1, lshape
        size = int(np.prod(slot_shape)) if slot_shape else 1
        padded = -(-size // dp) * dp
        meta = GroupMeta(decay=(no_decay is None or not no_decay(path)),
                         norm_names=_norm_names(tuple(ps.spec)))
        done.append(Bucket(
            segments=(Segment(leaf=i, offset=0, size=size,
                              shape=slot_shape),),
            size=size, padded=padded, z_reduced=bool(ps.z_reduced),
            y_reduce=bool(ps.y_reduce), dtype=jnp.dtype(leaf.dtype),
            groups=(meta,), gid=np.zeros((padded,), np.int8),
            stack=stack))
    return BucketPlan(buckets=tuple(done), treedef=treedef, dp=dp,
                      n_leaves=len(flat))


# ---------------------------------------------------------------------- #
# flatten / unflatten (trace-time; local shards)
# ---------------------------------------------------------------------- #

def flatten_bucket(leaves: Sequence, bucket: Bucket, *,
                   dtype=jnp.float32):
    """Concat the bucket's leaves (raveled, cast) + zero padding.

    Unstacked buckets -> ``(padded,)``; stacked buckets -> ``(stack,
    padded)`` (each slot raveled and padded independently, so a scan can
    slice slot rows)."""
    if bucket.stack > 1:
        parts = [leaves[s.leaf].astype(dtype).reshape(bucket.stack, -1)
                 for s in bucket.segments]
        if bucket.padded > bucket.size:
            parts.append(jnp.zeros(
                (bucket.stack, bucket.padded - bucket.size), dtype))
        return (jnp.concatenate(parts, axis=-1) if len(parts) > 1
                else parts[0])
    parts = [leaves[s.leaf].astype(dtype).reshape(-1)
             for s in bucket.segments]
    if bucket.padded > bucket.size:
        parts.append(jnp.zeros((bucket.padded - bucket.size,), dtype))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unflatten_bucket(flat, bucket: Bucket) -> List[Tuple[int, Any]]:
    """Full (padded) flat bucket -> [(leaf index, local-shaped array)]."""
    if bucket.stack > 1:
        return [(s.leaf,
                 flat[..., s.offset:s.offset + s.size].reshape(
                     (bucket.stack,) + s.shape))
                for s in bucket.segments]
    return [(s.leaf, flat[s.offset:s.offset + s.size].reshape(s.shape))
            for s in bucket.segments]


def _shard_index(axes: M.MeshAxes):
    """This rank's block index on the flattened data ring — the block
    ``ring_reduce_scatter`` leaves here and ``ring_all_gather`` reads
    from here (first-name-major, mesh.flat_ring_axis convention)."""
    return M.flat_ring_index(axes.data)


def shard_slice(full, plan: BucketPlan, bucket: Bucket, axes: M.MeshAxes):
    """Carve this rank's shard out of a full (padded) bucket-length
    array (last dim — the per-slot dim of stacked buckets); works on
    traced values and embedded constants alike."""
    ln = bucket.padded // plan.dp
    return jax.lax.dynamic_slice_in_dim(full, _shard_index(axes) * ln, ln,
                                        axis=-1)


# ---------------------------------------------------------------------- #
# collectives over the data ring
# ---------------------------------------------------------------------- #

def reduce_scatter_grads(grads, plan: BucketPlan, axes: M.MeshAxes, *,
                         ring: bool = True) -> List:
    """One microbatch's gradient tree -> per-bucket scattered fp32 shards
    (this rank's ``1/G_data`` block of each data-summed bucket)."""
    leaves = jax.tree.leaves(grads)
    out = []
    for i, b in enumerate(plan.buckets):
        with trace.scope("dp_rs", None, f"bucket{i}"):
            flat = flatten_bucket(leaves, b)
            if ring:
                out.append(M.ring_reduce_scatter(flat, axes.data, dim=-1))
            else:
                out.append(M.psum_scatter(flat, axes.data, dim=-1))
    return out


def tensor_reduce_shards(shards: Sequence, plan: BucketPlan,
                         axes: M.MeshAxes) -> List:
    """The per-leaf y/z reductions of ``partition.z_reduce_grads``, as
    whole-bucket psums on the scattered shards (class-pure buckets; flat
    layouts align element-wise across y/z ranks). Shards are 1/G_data of
    the full buffers, so this moves less than the per-leaf form."""
    out = []
    for b, s in zip(plan.buckets, shards):
        if b.y_reduce:
            s = M.psum(s, axes.y)
        if not b.z_reduced:
            s = M.psum(s, axes.z)
        out.append(s)
    return out


def _gather(flat_shard, axes: M.MeshAxes, ring: bool):
    if ring:
        return M.ring_all_gather(flat_shard, axes.data, dim=-1)
    return M.all_gather(flat_shard, axes.data, dim=-1)


def _gather_to_tree(shards: Sequence, plan: BucketPlan, axes: M.MeshAxes,
                    *, ring: bool, cast: bool):
    """Shared shard -> tree path of the two all-gather consumers below:
    optionally cast each shard to its bucket's param dtype, gather over
    ``data``, unflatten every bucket back into leaves."""
    leaves: List = [None] * plan.n_leaves
    for i, (b, s) in enumerate(zip(plan.buckets, shards)):
        with trace.scope("dp_ag", None, f"bucket{i}"):
            full = _gather(s.astype(b.dtype) if cast else s, axes, ring)
        for j, arr in unflatten_bucket(full, b):
            leaves[j] = arr
    return jax.tree.unflatten(plan.treedef, leaves)


def all_gather_grads(shards: Sequence, plan: BucketPlan,
                     axes: M.MeshAxes, *, ring: bool = True):
    """Scattered fp32 shards -> full per-leaf gradient tree (fp32)."""
    return _gather_to_tree(shards, plan, axes, ring=ring, cast=False)


def rebuild_params(master_shards: Sequence, plan: BucketPlan,
                   axes: M.MeshAxes, *, ring: bool = True):
    """ZeRO-1 param rebroadcast: cast each updated fp32 master shard to
    the bucket's param dtype, ring all-gather over ``data``, unflatten.
    (Cast-then-gather halves the wire bytes vs gathering fp32; the cast
    is element-wise so the result is unchanged.)"""
    return _gather_to_tree(master_shards, plan, axes, ring=ring, cast=True)


# ---------------------------------------------------------------------- #
# ZeRO-3 param-shard streaming (leaf plans, make_leaf_plan)
# ---------------------------------------------------------------------- #

def shard_params(params, plan: BucketPlan, axes: M.MeshAxes):
    """Full local params -> the permanent ZeRO-3 shard tree (same pytree
    structure; each leaf is this rank's 1/G_data flat shard in the
    leaf's own dtype — ``(stack, padded/dp)`` for scan-stacked leaves,
    ``(padded/dp,)`` otherwise). shard_map body."""
    leaves = jax.tree.leaves(params)
    out = []
    for b in plan.buckets:
        flat = flatten_bucket(leaves, b, dtype=b.dtype)
        out.append(shard_slice(flat, plan, b, axes))
    return jax.tree.unflatten(plan.treedef, out)


def gather_param_leaf(shard, bucket: Bucket, axes: M.MeshAxes, *,
                      ring: bool = True):
    """Assemble one leaf's working copy from its data-axis shard — the
    just-in-time gather of the streaming schedule (ring ``ppermute``
    chain, same send-right convention as the z-axis weight rings).

    A 1-D shard is either an unstacked leaf or ONE scan-sliced slot row
    of a stacked leaf (both reshape to the slot shape); a 2-D shard is a
    whole stacked leaf (checkpoint/serve path). Differentiable: the
    transpose is a ring reduce-scatter over ``data`` — the backward's DP
    gradient sync falls out of autodiff."""
    seg = bucket.segments[0]
    with trace.scope("zero3_ag", axes.data, f"leaf{seg.leaf}"):
        full = _gather(shard, axes, ring)
        if full.ndim == 2:
            return full[:, :seg.size].reshape((bucket.stack,) + seg.shape)
        return full[:seg.size].reshape(seg.shape)


def unshard_params(shards, plan: BucketPlan, axes: M.MeshAxes, *,
                   ring: bool = False):
    """Shard tree -> full local params (the checkpoint/save path, and
    the escape hatch back to the replicated layout)."""
    leaves = jax.tree.leaves(shards)
    out: List = [None] * plan.n_leaves
    for b, s in zip(plan.buckets, leaves):
        out[b.segments[0].leaf] = gather_param_leaf(s, b, axes, ring=ring)
    return jax.tree.unflatten(plan.treedef, out)


def shards_to_tree(masters: Sequence, plan: BucketPlan):
    """Updated fp32 master shards (bucket order) -> the param shard tree
    (cast to each leaf's dtype). The ZeRO-3 replacement for
    :func:`rebuild_params`: no collective at all — the new params ARE
    the shards."""
    return jax.tree.unflatten(
        plan.treedef, [s.astype(b.dtype)
                       for b, s in zip(plan.buckets, masters)])


def _flat_pspec(axes: M.MeshAxes, *, stacked: bool):
    """PartitionSpec of a flat shard dim: distinct on every mesh rank
    (scattered over data, tensor-sharded content over x/y/z) -> tiled
    over ALL logical axes in mesh order; stacked leaves keep the scan
    dim replicated."""
    from jax.sharding import PartitionSpec as P
    names = axes.all_names()
    entry = (names if len(names) != 1 else names[0]) if names else None
    return P(None, entry) if stacked else P(entry)


def param_shard_pspecs(plan: BucketPlan, axes: M.MeshAxes):
    """shard_map specs for the ZeRO-3 param shard tree."""
    return jax.tree.unflatten(
        plan.treedef,
        [_flat_pspec(axes, stacked=b.stack > 1) for b in plan.buckets])


def abstract_param_shards(plan: BucketPlan, axes: M.MeshAxes):
    """GLOBAL-shaped ShapeDtypeStructs of the shard tree (dry-run)."""
    g = axes.size(axes.all_names())
    out = []
    for b, ln in zip(plan.buckets, plan.shard_sizes):
        shape = (b.stack, ln * g) if b.stack > 1 else (ln * g,)
        out.append(jax.ShapeDtypeStruct(shape, b.dtype))
    return jax.tree.unflatten(plan.treedef, out)


@dataclasses.dataclass(eq=False)
class ParamStreamer:
    """The just-in-time assembly policy a zero3 train step hands to the
    model: which leaves stream through the layer scan (stacked buckets)
    vs. materialize once up front (everything else), how to gather, and
    whether to prefetch.

    ``buckets_like()`` mirrors the param tree with its Bucket leaves so
    model code can walk shards and layout together; ``resident()``
    gathers every unstacked leaf (embedding, head, final norm, ...) and
    leaves the scan-stacked shards in place for the per-layer streams.
    With ``prefetch`` the scan body gathers layer i+1's shards while
    layer i computes and carries the working copy across iterations
    (retained for backward); otherwise the gather sits inside the
    rematerialized body — released after the layer, re-gathered by
    remat in the backward."""

    plan: BucketPlan
    axes: M.MeshAxes
    ring: bool = True
    prefetch: bool = False

    def buckets_like(self):
        """Bucket tree with the params' own structure (Buckets are
        opaque pytree leaves)."""
        out: List = [None] * self.plan.n_leaves
        for b in self.plan.buckets:
            out[b.segments[0].leaf] = b
        return jax.tree.unflatten(self.plan.treedef, out)

    def gather(self, shard, bucket: Bucket):
        with trace.scope("zero3_stream",
                         detail="prefetch" if self.prefetch else "jit"):
            return gather_param_leaf(shard, bucket, self.axes,
                                     ring=self.ring)

    def gather_tree(self, shards, buckets):
        """Gather a (sub)tree of shards against its bucket subtree —
        one ring all-gather per leaf (the per-layer streaming window
        when called on a scan-sliced block)."""
        return jax.tree.map(lambda s, b: self.gather(s, b), shards,
                            buckets)

    def resident(self, params):
        """Materialize every non-streamed (unstacked) leaf; stacked
        shards pass through untouched for the layer scans."""
        leaves = jax.tree.leaves(params)
        out = []
        for b, s in zip(self.plan.buckets, leaves):
            out.append(s if b.stack > 1 else self.gather(s, b))
        return jax.tree.unflatten(self.plan.treedef, out)


# ---------------------------------------------------------------------- #
# per-element metadata on shards (group ids)
# ---------------------------------------------------------------------- #

def gid_shard(plan: BucketPlan, bucket: Bucket, axes: M.MeshAxes):
    """This rank's slice of the bucket's int8 group-id constant."""
    return shard_slice(jnp.asarray(bucket.gid), plan, bucket, axes)


def decay_mask(bucket: Bucket, gid):
    """fp32 {0,1} mask of elements weight decay applies to. Padding
    carries group 0's flag, which is harmless: padded master stays 0, so
    its decay term is 0 either way."""
    table = jnp.asarray([1.0 if g.decay else 0.0 for g in bucket.groups],
                        jnp.float32)
    return jnp.take(table, gid.astype(jnp.int32))


def sharded_grad_norm(shards: Sequence, plan: BucketPlan,
                      axes: M.MeshAxes):
    """L2 norm of the global gradient from the scattered shards.

    Per (bucket, metadata group): local sum of squares, accumulated
    locally per distinct axis set and psum'd ONCE per set over ``data``
    (the shards partition each bucket across data ranks) plus the set's
    own sharded axes — the exact axis sets
    ``optim.adamw.global_grad_norm`` uses per leaf, so the two paths
    agree (bitwise on exactly-summable values). One collective per
    distinct set (a handful) instead of one per (bucket, group) pair,
    which at small ``bucket_mb`` would spray hundreds of scalar
    all-reduces across the step."""
    dnames = tuple(M._names(axes.data))
    by_axes: dict = {}  # psum axis names -> local scalar accumulator
    for b, s in zip(plan.buckets, shards):
        gid = gid_shard(plan, b, axes)
        sq = (s * s).astype(jnp.float32)
        for g, meta in enumerate(b.groups):
            loc = jnp.sum(jnp.where(gid == g, sq, 0.0))
            names = dnames + meta.norm_names
            by_axes[names] = by_axes.get(names, 0.0) + loc
    total = jnp.zeros((), jnp.float32)
    for names, acc in by_axes.items():
        total = total + M.psum(acc, names)
    return jnp.sqrt(total)


# ---------------------------------------------------------------------- #
# ZeRO-1 sharded optimizer state
# ---------------------------------------------------------------------- #

def init_sharded_state(params, plan: BucketPlan, axes: M.MeshAxes):
    """m/v/master fp32 shards per bucket + step (shard_map body)."""
    leaves = jax.tree.leaves(params)
    buckets = []
    for b in plan.buckets:
        master = shard_slice(flatten_bucket(leaves, b), plan, b, axes)
        buckets.append({"m": jnp.zeros_like(master),
                        "v": jnp.zeros_like(master),
                        "master": master})
    return {"buckets": buckets, "step": jnp.zeros((), jnp.int32)}


def sharded_state_pspecs(plan: BucketPlan, axes: M.MeshAxes):
    """PartitionSpecs for the sharded state: each shard is distinct on
    every mesh rank (scattered over data, tensor-sharded content over
    x/y/z), so the flat dim tiles over ALL logical axes in mesh order
    (stacked buckets keep their leading scan dim replicated)."""
    from jax.sharding import PartitionSpec as P
    buckets = []
    for b in plan.buckets:
        spec = _flat_pspec(axes, stacked=b.stack > 1)
        buckets.append({"m": spec, "v": spec, "master": spec})
    return {"buckets": buckets, "step": P()}


def abstract_sharded_state(plan: BucketPlan, axes: M.MeshAxes):
    """GLOBAL-shaped ShapeDtypeStructs of the sharded state (dry-run)."""
    g = axes.size(axes.all_names())
    buckets = []
    for b, ln in zip(plan.buckets, plan.shard_sizes):
        shape = (b.stack, ln * g) if b.stack > 1 else (ln * g,)
        st = jax.ShapeDtypeStruct(shape, jnp.float32)
        buckets.append({"m": st, "v": st, "master": st})
    return {"buckets": buckets,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def gather_sharded_state(state, plan: BucketPlan, axes: M.MeshAxes):
    """Sharded state -> the replicated-AdamW layout (per-leaf fp32
    m/v/master trees, data-replicated) for checkpointing (shard_map
    body; blocking gathers — this is the save path)."""
    per_leaf: List = [None] * plan.n_leaves
    for b, st in zip(plan.buckets, state["buckets"]):
        fulls = {k: M.all_gather(st[k], axes.data, dim=-1)
                 for k in ("m", "v", "master")}
        for s in b.segments:
            shape = ((b.stack,) + s.shape) if b.stack > 1 else s.shape
            per_leaf[s.leaf] = {
                k: fulls[k][..., s.offset:s.offset + s.size].reshape(shape)
                for k in ("m", "v", "master")}
    return {"opt": jax.tree.unflatten(plan.treedef, per_leaf),
            "step": state["step"]}


def scatter_full_state(full, plan: BucketPlan, axes: M.MeshAxes):
    """Inverse of :func:`gather_sharded_state`: replicated-layout state
    -> this rank's shards (shard_map body; restore path)."""
    flat = plan.treedef.flatten_up_to(full["opt"])
    buckets = []
    for b in plan.buckets:
        out = {}
        for k in ("m", "v", "master"):
            leaves = [flat[s.leaf][k] for s in b.segments]
            keyed = [None] * plan.n_leaves
            for s, lf in zip(b.segments, leaves):
                keyed[s.leaf] = lf
            out[k] = shard_slice(flatten_bucket(keyed, b), plan, b, axes)
        buckets.append(out)
    return {"buckets": buckets, "step": full["step"]}
