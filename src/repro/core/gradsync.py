"""ZeRO-sharded data-parallel gradient synchronization (bucketed rings).

The paper's 4th dimension is plain data parallelism whose gradient
all-reduce is meant to hide behind backward compute (AxoNN's asynchronous
message-driven design, arXiv:2110.13005; memory-optimized in its
production successor, arXiv:2502.08145). The blocking form in
``launch/steps.py`` was one ``psum`` per gradient leaf over ``axes.data``
*after* the whole overdecompose loop — fully exposed, with AdamW state
replicated across ``G_data``.

This module replaces that with a subsystem built on the ring machinery of
:mod:`repro.core.mesh`:

  * **Bucketing** (:func:`make_plan`): the gradient tree is flattened into
    size-bounded fp32 buckets. Leaves are grouped by their reduction class
    ``(z_reduced, y_reduce, dtype)`` so a whole bucket shares one
    tensor-axis reduction schedule, then packed greedily in tree order
    under ``bucket_mb`` (at least one leaf per bucket) and padded to a
    multiple of ``G_data`` so the reduce-scatter splits evenly.
  * **Streamed reduce-scatter**: each microbatch's bucket gradients are
    reduce-scattered over the ``data`` ring (``ring_reduce_scatter``,
    i.e. ``lax.ppermute`` chains) *inside* the overdecompose loop —
    microbatch ``i+1``'s backward has no data dependency on microbatch
    ``i``'s ring hops, so XLA's latency-hiding scheduler can overlap them
    exactly like the x/y/z rings. Shards accumulate in fp32.
  * **ZeRO-1 state sharding**: with ``zero`` on, the scattered gradients
    are never re-gathered; each data rank keeps fp32 AdamW state
    (m/v/master) only for its ``1/G_data`` bucket shard
    (``optim.adamw.apply_updates_sharded``) and a ring all-gather
    rebroadcasts the updated params — optimizer memory drops by
    ``G_data`` on top of the z-axis sharding the 4D layout already gives.

Per-element metadata that the blocking path read off the pytree (weight
decay masks, which mesh axes a leaf's grad-norm contribution must be
psum'd over) cannot use static per-rank segment boundaries under SPMD —
the scattered shard's content depends on ``axis_index``. It is instead
encoded as a per-bucket ``int8`` group-id array (:class:`GroupMeta`)
whose own shard is carved out with ``dynamic_slice`` at the rank's ring
index.

Every ring schedule here is a pure decomposition of the blocking one
(DESIGN.md §Data-parallel sync schedule): same operands reduced to the
same places, bitwise on exactly-summable values, and bitwise-identical to
the blocking ``psum`` at ``G_data = 2`` (two-term fp addition commutes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import mesh as M
from repro.core.partition import ParamSpec


# ---------------------------------------------------------------------- #
# config
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    """Knobs for the data-parallel gradient synchronization subsystem.

    bucketed: replace the per-leaf blocking ``psum`` over ``data`` with
    bucketed ring reduce-scatter + all-gather of the *gradients* (AdamW
    state stays replicated). zero: additionally keep the gradients
    scattered and shard the AdamW state ZeRO-1-style over ``data``
    (implies the bucketed schedule; the all-gather moves updated *params*
    instead of gradients). Both off (default) keeps the blocking path.

    bucket_mb: fp32 bucket size bound in MiB. Smaller buckets give the
    scheduler finer-grained ring/backward pairs to overlap but pay more
    α-latency (``comm_model.dp_sync_time`` prices exactly this).

    stream: issue each microbatch's bucket reduce-scatters *inside* the
    overdecompose loop (the overlap window — DP comm of microbatch i
    rides under microbatch i+1's backward). Off accumulates fp32 locally
    and reduce-scatters once after the loop (lower volume at high
    overdecompose, no overlap window).

    ring: decompose the data-axis collectives into ``ppermute`` ring hops
    (collective-permute chains in HLO). Off uses the blocking
    ``psum_scatter``/``all_gather`` (still no all-reduce over ``data``).
    """

    bucketed: bool = False
    zero: bool = False
    bucket_mb: float = 4.0
    stream: bool = True
    ring: bool = True

    def __post_init__(self):
        if self.bucket_mb <= 0:
            raise ValueError(f"bucket_mb must be > 0, got {self.bucket_mb}")

    @property
    def enabled(self) -> bool:
        return self.bucketed or self.zero

    @property
    def bucket_bytes(self) -> int:
        return int(self.bucket_mb * 2 ** 20)


# ---------------------------------------------------------------------- #
# bucket plan
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class GroupMeta:
    """One per-element metadata class inside a bucket: whether weight
    decay applies and which mesh axes the element's grad-norm
    contribution must be psum'd over (the leaf's sharded axes, exactly
    as ``optim.adamw.global_grad_norm`` reads them off the ParamSpec)."""

    decay: bool
    norm_names: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Segment:
    """One leaf's slice of a bucket (offsets/sizes in *local* elements)."""

    leaf: int                 # index into the flattened param/grad tree
    offset: int               # start inside the (unpadded) bucket
    size: int                 # local element count
    shape: Tuple[int, ...]    # local shape


@dataclasses.dataclass(eq=False)
class Bucket:
    segments: Tuple[Segment, ...]
    size: int                 # unpadded elements
    padded: int               # padded to a multiple of dp
    z_reduced: bool           # grads already reduce-scattered over z
    y_reduce: bool            # grads need a psum over y
    dtype: Any                # param dtype of every leaf in this bucket
    groups: Tuple[GroupMeta, ...]
    gid: np.ndarray           # (padded,) int8 group id per element


@dataclasses.dataclass(eq=False)
class BucketPlan:
    buckets: Tuple[Bucket, ...]
    treedef: Any              # treedef of the param/grad tree
    dp: int                   # flattened data-ring size
    n_leaves: int

    @property
    def shard_sizes(self) -> Tuple[int, ...]:
        return tuple(b.padded // self.dp for b in self.buckets)

    @property
    def total_elements(self) -> int:
        return sum(b.size for b in self.buckets)

    @property
    def padded_elements(self) -> int:
        return sum(b.padded for b in self.buckets)


def _local_shape(shape, spec, axes: M.MeshAxes) -> Tuple[int, ...]:
    """Per-device shape of a leaf whose GLOBAL shape is ``shape``."""
    sizes = dict(axes.sizes)
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        p = 1
        if entry is not None:
            names = entry if isinstance(entry, tuple) else (entry,)
            p = math.prod(sizes.get(n, 1) for n in names)
        if dim % p:
            raise ValueError(f"dim {dim} not divisible by axis product {p} "
                             f"of spec entry {entry!r}")
        out.append(dim // p)
    return tuple(out)


def _norm_names(spec) -> Tuple[str, ...]:
    """Mesh axes a leaf's grad-norm contribution is psum'd over (same
    extraction as ``optim.adamw.global_grad_norm``)."""
    return tuple(n for entry in spec if entry is not None
                 for n in (entry if isinstance(entry, tuple) else (entry,)))


def make_plan(structs, specs, axes: M.MeshAxes, bucket_bytes: int, *,
              no_decay: Optional[Callable] = None) -> BucketPlan:
    """Pack the param/grad tree into size-bounded fp32 buckets.

    ``structs`` are GLOBAL-shaped leaves (abstract init output); sizes in
    the plan are per-device. ``no_decay(path) -> bool`` marks leaves that
    skip weight decay (``optim.adamw._no_decay``); None = decay
    everywhere the config asks. Leaves are grouped by reduction class
    ``(z_reduced, y_reduce, dtype)`` — one bucket never mixes classes, so
    the post-scatter tensor-axis reductions apply to whole buckets — then
    packed greedily in tree order with at least one leaf per bucket, and
    padded to a multiple of the data-ring size.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(structs)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec))
    assert len(flat) == len(spec_leaves)
    dp = max(axes.dp, 1)
    cap = max(int(bucket_bytes) // 4, 1)  # buckets are fp32

    # one open bucket per reduction class: key -> [(Segment, GroupMeta)]
    open_buckets: dict = {}
    done: List[Bucket] = []

    def close(key):
        items = open_buckets.pop(key)
        segs = tuple(s for s, _ in items)
        size = sum(s.size for s in segs)
        padded = -(-size // dp) * dp
        gid = np.zeros((padded,), np.int8)
        groups: List[GroupMeta] = []
        gix: dict = {}
        for seg, meta in items:
            g = gix.setdefault(meta, len(groups))
            if g == len(groups):
                groups.append(meta)
            gid[seg.offset:seg.offset + seg.size] = g
        if len(groups) > 127:
            raise ValueError("too many metadata groups in one bucket")
        z_red, y_red, dtname = key
        done.append(Bucket(segments=segs, size=size, padded=padded,
                           z_reduced=z_red, y_reduce=y_red,
                           dtype=jnp.dtype(dtname),
                           groups=tuple(groups), gid=gid))

    for i, ((path, leaf), ps) in enumerate(zip(flat, spec_leaves)):
        lshape = _local_shape(tuple(leaf.shape), tuple(ps.spec), axes)
        size = int(np.prod(lshape)) if lshape else 1
        key = (bool(ps.z_reduced), bool(ps.y_reduce),
               jnp.dtype(leaf.dtype).name)
        meta = GroupMeta(decay=(no_decay is None or not no_decay(path)),
                         norm_names=_norm_names(tuple(ps.spec)))
        items = open_buckets.get(key)
        if items is not None and sum(s.size for s, _ in items) + size > cap:
            close(key)
            items = None
        if items is None:
            items = open_buckets[key] = []
        off = sum(s.size for s, _ in items)
        items.append((Segment(leaf=i, offset=off, size=size, shape=lshape),
                      meta))
    for key in list(open_buckets):
        close(key)
    return BucketPlan(buckets=tuple(done), treedef=treedef, dp=dp,
                      n_leaves=len(flat))


# ---------------------------------------------------------------------- #
# flatten / unflatten (trace-time; local shards)
# ---------------------------------------------------------------------- #

def flatten_bucket(leaves: Sequence, bucket: Bucket, *,
                   dtype=jnp.float32):
    """Concat the bucket's leaves (raveled, cast) + zero padding."""
    parts = [leaves[s.leaf].astype(dtype).reshape(-1)
             for s in bucket.segments]
    if bucket.padded > bucket.size:
        parts.append(jnp.zeros((bucket.padded - bucket.size,), dtype))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unflatten_bucket(flat, bucket: Bucket) -> List[Tuple[int, Any]]:
    """Full (padded) flat bucket -> [(leaf index, local-shaped array)]."""
    return [(s.leaf, flat[s.offset:s.offset + s.size].reshape(s.shape))
            for s in bucket.segments]


def _shard_index(axes: M.MeshAxes):
    """This rank's block index on the flattened data ring — the block
    ``ring_reduce_scatter`` leaves here and ``ring_all_gather`` reads
    from here (first-name-major, mesh.flat_ring_axis convention)."""
    return M.flat_ring_index(axes.data)


def shard_slice(full, plan: BucketPlan, bucket: Bucket, axes: M.MeshAxes):
    """Carve this rank's shard out of a full (padded) bucket-length
    array; works on traced values and embedded constants alike."""
    ln = bucket.padded // plan.dp
    return jax.lax.dynamic_slice(full, (_shard_index(axes) * ln,), (ln,))


# ---------------------------------------------------------------------- #
# collectives over the data ring
# ---------------------------------------------------------------------- #

def reduce_scatter_grads(grads, plan: BucketPlan, axes: M.MeshAxes, *,
                         ring: bool = True) -> List:
    """One microbatch's gradient tree -> per-bucket scattered fp32 shards
    (this rank's ``1/G_data`` block of each data-summed bucket)."""
    leaves = jax.tree.leaves(grads)
    out = []
    for b in plan.buckets:
        flat = flatten_bucket(leaves, b)
        if ring:
            out.append(M.ring_reduce_scatter(flat, axes.data, dim=0))
        else:
            out.append(M.psum_scatter(flat, axes.data, dim=0))
    return out


def tensor_reduce_shards(shards: Sequence, plan: BucketPlan,
                         axes: M.MeshAxes) -> List:
    """The per-leaf y/z reductions of ``partition.z_reduce_grads``, as
    whole-bucket psums on the scattered shards (class-pure buckets; flat
    layouts align element-wise across y/z ranks). Shards are 1/G_data of
    the full buffers, so this moves less than the per-leaf form."""
    out = []
    for b, s in zip(plan.buckets, shards):
        if b.y_reduce:
            s = M.psum(s, axes.y)
        if not b.z_reduced:
            s = M.psum(s, axes.z)
        out.append(s)
    return out


def _gather(flat_shard, axes: M.MeshAxes, ring: bool):
    if ring:
        return M.ring_all_gather(flat_shard, axes.data, dim=0)
    return M.all_gather(flat_shard, axes.data, dim=0)


def _gather_to_tree(shards: Sequence, plan: BucketPlan, axes: M.MeshAxes,
                    *, ring: bool, cast: bool):
    """Shared shard -> tree path of the two all-gather consumers below:
    optionally cast each shard to its bucket's param dtype, gather over
    ``data``, unflatten every bucket back into leaves."""
    leaves: List = [None] * plan.n_leaves
    for b, s in zip(plan.buckets, shards):
        full = _gather(s.astype(b.dtype) if cast else s, axes, ring)
        for i, arr in unflatten_bucket(full, b):
            leaves[i] = arr
    return jax.tree.unflatten(plan.treedef, leaves)


def all_gather_grads(shards: Sequence, plan: BucketPlan,
                     axes: M.MeshAxes, *, ring: bool = True):
    """Scattered fp32 shards -> full per-leaf gradient tree (fp32)."""
    return _gather_to_tree(shards, plan, axes, ring=ring, cast=False)


def rebuild_params(master_shards: Sequence, plan: BucketPlan,
                   axes: M.MeshAxes, *, ring: bool = True):
    """ZeRO-1 param rebroadcast: cast each updated fp32 master shard to
    the bucket's param dtype, ring all-gather over ``data``, unflatten.
    (Cast-then-gather halves the wire bytes vs gathering fp32; the cast
    is element-wise so the result is unchanged.)"""
    return _gather_to_tree(master_shards, plan, axes, ring=ring, cast=True)


# ---------------------------------------------------------------------- #
# per-element metadata on shards (group ids)
# ---------------------------------------------------------------------- #

def gid_shard(plan: BucketPlan, bucket: Bucket, axes: M.MeshAxes):
    """This rank's slice of the bucket's int8 group-id constant."""
    return shard_slice(jnp.asarray(bucket.gid), plan, bucket, axes)


def decay_mask(bucket: Bucket, gid):
    """fp32 {0,1} mask of elements weight decay applies to. Padding
    carries group 0's flag, which is harmless: padded master stays 0, so
    its decay term is 0 either way."""
    table = jnp.asarray([1.0 if g.decay else 0.0 for g in bucket.groups],
                        jnp.float32)
    return jnp.take(table, gid.astype(jnp.int32))


def sharded_grad_norm(shards: Sequence, plan: BucketPlan,
                      axes: M.MeshAxes):
    """L2 norm of the global gradient from the scattered shards.

    Per (bucket, metadata group): local sum of squares, accumulated
    locally per distinct axis set and psum'd ONCE per set over ``data``
    (the shards partition each bucket across data ranks) plus the set's
    own sharded axes — the exact axis sets
    ``optim.adamw.global_grad_norm`` uses per leaf, so the two paths
    agree (bitwise on exactly-summable values). One collective per
    distinct set (a handful) instead of one per (bucket, group) pair,
    which at small ``bucket_mb`` would spray hundreds of scalar
    all-reduces across the step."""
    dnames = tuple(M._names(axes.data))
    by_axes: dict = {}  # psum axis names -> local scalar accumulator
    for b, s in zip(plan.buckets, shards):
        gid = gid_shard(plan, b, axes)
        sq = (s * s).astype(jnp.float32)
        for g, meta in enumerate(b.groups):
            loc = jnp.sum(jnp.where(gid == g, sq, 0.0))
            names = dnames + meta.norm_names
            by_axes[names] = by_axes.get(names, 0.0) + loc
    total = jnp.zeros((), jnp.float32)
    for names, acc in by_axes.items():
        total = total + M.psum(acc, names)
    return jnp.sqrt(total)


# ---------------------------------------------------------------------- #
# ZeRO-1 sharded optimizer state
# ---------------------------------------------------------------------- #

def init_sharded_state(params, plan: BucketPlan, axes: M.MeshAxes):
    """m/v/master fp32 shards per bucket + step (shard_map body)."""
    leaves = jax.tree.leaves(params)
    buckets = []
    for b in plan.buckets:
        master = shard_slice(flatten_bucket(leaves, b), plan, b, axes)
        buckets.append({"m": jnp.zeros_like(master),
                        "v": jnp.zeros_like(master),
                        "master": master})
    return {"buckets": buckets, "step": jnp.zeros((), jnp.int32)}


def sharded_state_pspecs(plan: BucketPlan, axes: M.MeshAxes):
    """PartitionSpecs for the sharded state: each shard is distinct on
    every mesh rank (scattered over data, tensor-sharded content over
    x/y/z), so dim 0 tiles over ALL logical axes in mesh order."""
    from jax.sharding import PartitionSpec as P
    names = axes.all_names()
    spec = P(names if len(names) != 1 else names[0]) if names else P(None)
    return {"buckets": [{"m": spec, "v": spec, "master": spec}
                        for _ in plan.buckets],
            "step": P()}


def abstract_sharded_state(plan: BucketPlan, axes: M.MeshAxes):
    """GLOBAL-shaped ShapeDtypeStructs of the sharded state (dry-run)."""
    g = axes.size(axes.all_names())
    buckets = []
    for ln in plan.shard_sizes:
        st = jax.ShapeDtypeStruct((ln * g,), jnp.float32)
        buckets.append({"m": st, "v": st, "master": st})
    return {"buckets": buckets,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def gather_sharded_state(state, plan: BucketPlan, axes: M.MeshAxes):
    """Sharded state -> the replicated-AdamW layout (per-leaf fp32
    m/v/master trees, data-replicated) for checkpointing (shard_map
    body; blocking gathers — this is the save path)."""
    per_leaf: List = [None] * plan.n_leaves
    for b, st in zip(plan.buckets, state["buckets"]):
        fulls = {k: M.all_gather(st[k], axes.data, dim=0)
                 for k in ("m", "v", "master")}
        for s in b.segments:
            per_leaf[s.leaf] = {
                k: fulls[k][s.offset:s.offset + s.size].reshape(s.shape)
                for k in ("m", "v", "master")}
    return {"opt": jax.tree.unflatten(plan.treedef, per_leaf),
            "step": state["step"]}


def scatter_full_state(full, plan: BucketPlan, axes: M.MeshAxes):
    """Inverse of :func:`gather_sharded_state`: replicated-layout state
    -> this rank's shards (shard_map body; restore path)."""
    flat = plan.treedef.flatten_up_to(full["opt"])
    buckets = []
    for b in plan.buckets:
        out = {}
        for k in ("m", "v", "master"):
            leaves = [flat[s.leaf][k] for s in b.segments]
            keyed = [None] * plan.n_leaves
            for s, lf in zip(b.segments, leaves):
                keyed[s.leaf] = lf
            out[k] = shard_slice(flatten_bucket(keyed, b), plan, b, axes)
        buckets.append(out)
    return {"buckets": buckets, "step": full["step"]}
