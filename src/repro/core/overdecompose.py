"""Overdecomposition (paper §4.2) adapted to XLA.

The paper splits each tensor group's batch shard into two micro-shards and
round-robins their compute/communication on separate CUDA streams so the
all-reduce of one shard overlaps the GEMMs of the other.

JAX has no streams; the TPU equivalent is XLA's latency-hiding scheduler +
async collectives, which overlap any *data-independent* collective/compute
pairs. We therefore express overdecomposition structurally: the loss/grad
computation is replicated into ``n_shards`` independent program slices over
disjoint halves of the local batch, and their gradients are averaged at the
end. Nothing in slice 0 depends on slice 1 until the final tree-add, so the
scheduler is free to interleave AR(shard0) with GEMM(shard1) exactly as the
paper's Figure 4 shows. Total collective volume is unchanged (each
all-reduce happens twice at half size).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def split_batch(batch, n_shards: int):
    """Split every leaf of a batch pytree along axis 0 into n_shards."""
    def s(x):
        b = x.shape[0]
        if b % n_shards:
            raise ValueError(f"local batch {b} not divisible by "
                             f"overdecomposition factor {n_shards}")
        return x.reshape(n_shards, b // n_shards, *x.shape[1:])
    return jax.tree.map(s, batch)


def overdecomposed_value_and_grad(loss_fn: Callable, n_shards: int = 2):
    """value_and_grad over ``n_shards`` independent batch slices.

    ``loss_fn(params, batch) -> scalar``. Returns a function with the same
    signature as ``jax.value_and_grad(loss_fn)``. A python loop (NOT scan /
    vmap) is used deliberately: scan would serialize the slices and vmap
    would fuse their collectives, either of which destroys the overlap
    opportunity the paper's overdecomposition creates.
    """
    if n_shards == 1:
        return jax.value_and_grad(loss_fn)
    vg = jax.value_and_grad(loss_fn)

    def wrapped(params, batch):
        shards = split_batch(batch, n_shards)
        losses, grads = [], None
        for i in range(n_shards):
            sub = jax.tree.map(lambda x: x[i], shards)
            li, gi = vg(params, sub)
            losses.append(li)
            grads = gi if grads is None else jax.tree.map(
                jnp.add, grads, gi)
        loss = sum(losses) / n_shards
        grads = jax.tree.map(lambda g: g / n_shards, grads)
        return loss, grads

    return wrapped
