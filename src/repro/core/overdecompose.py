"""Overdecomposition (paper §4.2) adapted to XLA.

The paper splits each tensor group's batch shard into two micro-shards and
round-robins their compute/communication on separate CUDA streams so the
all-reduce of one shard overlaps the GEMMs of the other.

JAX has no streams; the TPU equivalent is XLA's latency-hiding scheduler +
async collectives, which overlap any *data-independent* collective/compute
pairs. We therefore express overdecomposition structurally: the loss/grad
computation is replicated into ``n_shards`` independent program slices over
disjoint halves of the local batch, and their gradients are averaged at the
end. Nothing in slice 0 depends on slice 1 until the final tree-add, so the
scheduler is free to interleave AR(shard0) with GEMM(shard1) exactly as the
paper's Figure 4 shows. Total collective volume is unchanged (each
all-reduce happens twice at half size).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def split_batch(batch, n_shards: int, *, axes=None):
    """Split every leaf of a batch pytree along axis 0 into n_shards.

    Inside a shard_map'd train step the leaves are the *per-shard* batch
    (global batch / (G_data × G_z)); a non-dividing shape is a config
    error, so it is reported with the offending leaf and the global
    divisibility rule instead of surfacing as a reshape failure deep in
    the microbatch loop. ``axes`` (a ``mesh.MeshAxes``) is optional
    context used only to phrase that error in global-batch terms."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    for path, x in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path) or "<batch>"
        if not getattr(x, "ndim", 0):
            raise ValueError(
                f"overdecompose={n_shards}: batch leaf {name!r} is a "
                f"scalar — every leaf needs a leading batch dim to split")
        if x.shape[0] % n_shards:
            hint = ""
            if axes is not None:
                bs = axes.batch_shards
                hint = (f" (global batch = {x.shape[0] * bs} over "
                        f"{bs} data×z batch shards; the global batch "
                        f"must be divisible by batch_shards × "
                        f"overdecompose = {bs * n_shards})")
            raise ValueError(
                f"overdecompose={n_shards}: per-shard batch {x.shape[0]} "
                f"of leaf {name!r} is not divisible by the "
                f"overdecomposition factor{hint}")
    return jax.tree.unflatten(
        treedef, [x.reshape(n_shards, x.shape[0] // n_shards, *x.shape[1:])
                  for _, x in flat])


def overdecomposed_value_and_grad(loss_fn: Callable, n_shards: int = 2):
    """value_and_grad over ``n_shards`` independent batch slices.

    ``loss_fn(params, batch) -> scalar``. Returns a function with the same
    signature as ``jax.value_and_grad(loss_fn)``. A python loop (NOT scan /
    vmap) is used deliberately: scan would serialize the slices and vmap
    would fuse their collectives, either of which destroys the overlap
    opportunity the paper's overdecomposition creates.
    """
    if n_shards == 1:
        return jax.value_and_grad(loss_fn)
    vg = jax.value_and_grad(loss_fn)

    def wrapped(params, batch):
        shards = split_batch(batch, n_shards)
        losses, grads = [], None
        for i in range(n_shards):
            sub = jax.tree.map(lambda x: x[i], shards)
            li, gi = vg(params, sub)
            losses.append(li)
            grads = gi if grads is None else jax.tree.map(
                jnp.add, grads, gi)
        loss = sum(losses) / n_shards
        grads = jax.tree.map(lambda g: g / n_shards, grads)
        return loss, grads

    return wrapped
