"""OverlapConfig: the comm/compute-overlap knobs for the 4D primitives.

The paper's first key strategy is to "aggressively overlap expensive
collective operations with computation". Two mechanisms implement it here:

  * overdecomposition (paper §4.2, core/overdecompose.py) — overlap
    *between* batch micro-shards, and
  * ring-decomposed collective matmuls (core/collective_matmul.py) —
    overlap *inside* each layer: the z-axis weight all-gather / gradient
    reduce-scatter is decomposed into ``lax.ppermute`` ring steps whose
    per-chunk GEMMs interleave with the permutes, so the weight traffic
    hides under the layer's own compute. The same treatment applies to
    the x/y *activation* all-reduces of every tensor-parallel matmul
    (``all_reduce`` below): each all-reduce becomes a reduce-scatter ring
    whose hops consume the producing GEMM's output chunk by chunk,
    followed by an all-gather ring (AxoNN-style, arXiv:2110.13005).

An :class:`OverlapConfig` instance rides on :class:`repro.core.mesh.
MeshAxes` (``axes.with_overlap(cfg)``) so every ``tp_*`` primitive sees it
without threading an extra argument through the layer stack. It is a
frozen (hashable) dataclass: it participates in ``custom_vjp`` nondiff
args and jit static args unchanged.

``cache_weight_gather`` subsumes the old module-global
``parallel.CACHE_WEIGHT_GATHER`` trace-time flag: cache the z-gathered
weight from the forward pass instead of re-gathering in the backward pass
(trades one AG_z per layer for holding the full (k_local, n_local) weight
across the residual).

Knob units and degeneracy guarantees (DESIGN.md §Overlapped schedule):

  * ``z_chunks`` / ``ar_chunks`` — sub-rings per block (dimensionless
    counts; non-dividing values round down to the largest divisor).
  * ``OverlapConfig()`` (all off) ⇒ the blocking collective schedule of
    core/parallel.py, bit for bit — and in ``comm_model.layer_time`` an
    all-off config with ``alpha = 0`` reduces the exposed-communication
    term exactly to the volume model.
  * The ring knobs never change wire volume, only exposure; only
    ``cache_weight_gather`` changes volume (drops one AG_z per layer),
    and ``comm_model.layer_volume(overlap=...)`` models exactly that.
  * How much ring traffic actually hides is the *measured*
    ``HardwareParams.overlap_efficiency`` (core/calibrate.py's overlap
    probe; 0.8 is the uncalibrated guess).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class OverlapConfig:
    """Per-primitive on/off switches + ring chunking for collective matmuls.

    matmul / batched_matmul / tied_logits: use the ring-decomposed
    (overlapped) z-axis schedule inside ``tp_matmul`` /
    ``tp_batched_matmul`` / ``tied_lm_logits``. Off (default) keeps the
    blocking all-gather / reduce-scatter schedule.

    all_reduce: ring-decompose the x/y *activation* all-reduces of the
    same three primitives (fwd partial-output reduce, bwd dX reduce, tied
    dh reduce) into reduce-scatter + all-gather ``ppermute`` phases; where
    the reduced tensor's producing GEMM is materialized in the same
    schedule, its output is produced per chunk, just in time for each
    reduce-scatter hop (``collective_matmul.ar_matmul*``). The scalar
    psums of the feature-sharded norms and the vocab-parallel softmax
    stay blocking (latency-bound, nothing to pipeline).

    z_chunks: how many independent ring pipelines the z-axis collective of
    one matmul is split into. 1 = one ring whose steps already interleave
    one GEMM per hop; c > 1 splits each per-device weight block into ``c``
    sub-blocks with their own (smaller) rings, giving the scheduler
    finer-grained permute/GEMM pairs to overlap. Must divide the per-device
    block's gathered dimension.

    ar_chunks: same knob for the activation all-reduce rings (sub-rings
    per scattered block; the largest divisor <= ar_chunks is used).

    cache_weight_gather: keep the z-gathered weight from the forward as a
    residual instead of re-gathering it in the backward (EXPERIMENTS.md
    §Perf).

    ring_attention: circulate per-hop KV blocks over the ``seq`` mesh
    axis as ``ppermute`` ring steps (layers/attention.py ``seq_attn``),
    with hop i+1's permute issued before hop i's partial-attention
    compute so the exchange hides under attention math. Off keeps the
    blocking schedule (one KV all-gather over ``seq``). Inert when the
    seq axis is unmapped (g_seq = 1: both paths reduce to the plain
    ``attn_core`` call, bit for bit).

    embed_gather: ring-decompose the embedding table's z-axis all-gather
    (``parallel.embedding_lookup``) into ``ppermute`` hops —
    ``mesh.ring_all_gather`` is bitwise the blocking gather, so this
    only changes exposure, never values.

    expert_a2a: ring-decompose the MoE dispatch/combine all-to-all over
    the ``expert`` mesh axis into pairwise ``ppermute`` exchanges
    interleaved with the per-source expert GEMMs
    (``collective_matmul.ring_a2a_expert``), so the token exchange hides
    under expert compute. Off keeps the blocking ``lax.all_to_all``
    schedule. Inert when the expert axis is unmapped (g_expert = 1: both
    paths reduce to the within-y dispatch, bit for bit).
    """

    matmul: bool = False
    batched_matmul: bool = False
    tied_logits: bool = False
    all_reduce: bool = False
    z_chunks: int = 1
    ar_chunks: int = 1
    cache_weight_gather: bool = False
    ring_attention: bool = False
    embed_gather: bool = False
    expert_a2a: bool = False

    def __post_init__(self):
        if self.z_chunks < 1:
            raise ValueError(f"z_chunks must be >= 1, got {self.z_chunks}")
        if self.ar_chunks < 1:
            raise ValueError(f"ar_chunks must be >= 1, got {self.ar_chunks}")

    @property
    def any_enabled(self) -> bool:
        return (self.matmul or self.batched_matmul or self.tied_logits
                or self.all_reduce or self.ring_attention
                or self.embed_gather or self.expert_a2a)

    @classmethod
    def all_on(cls, *, z_chunks: int = 1, ar_chunks: int = 1,
               cache_weight_gather: bool = False) -> "OverlapConfig":
        return cls(matmul=True, batched_matmul=True, tied_logits=True,
                   all_reduce=True, z_chunks=z_chunks, ar_chunks=ar_chunks,
                   cache_weight_gather=cache_weight_gather,
                   ring_attention=True, embed_gather=True,
                   expert_a2a=True)
