"""Analytic communication model (paper §5, generalized to 4D).

The supplied text models the per-GPU, per-iteration all-reduce volume of its
2D tensor-parallel algorithm (Eqs. 1-4) and derives decomposition rules
(max ``G_data``; for transformers ``G_c = sqrt(3 G_tensor)``, Eq. 7). The 4D
algorithm adds the depth axis ``G_z``; its extra collectives are the weight
all-gather (forward) and the weight-gradient reduce-scatter (backward) over
``z``, whose volumes are *batch-independent* — the 4D trade: pay
``O(params)`` weight traffic to cut ``O(batch)`` activation traffic by
``1/G_z``.

All volumes are *elements sent+received per device per iteration* (multiply
by dtype bytes for bytes), mirroring the paper. Collectives are assumed
bandwidth-optimal (Patarasuk & Yuan): ``V_AR = 2 (p-1)/p * buf``,
``V_AG = V_RS = (p-1)/p * buf_full``.

Beyond the paper's volume-only ranking, the α-β *time* model
(:class:`HardwareParams`, :func:`predict_step_time`) prices each
collective as ``steps * α + bytes / bw`` (all-reduces at 2(p-1) ring
hops, gathers/scatters at p-1) and — when an
:class:`~repro.core.overlap.OverlapConfig` enables the ring-decomposed
collective matmuls — hides the z-axis weight traffic (``matmul``) and
then the x/y activation all-reduce traffic (``all_reduce``) under the
layer's own GEMM time, charging only the *exposed* remainder.

Units: volumes in *elements sent+received per device per iteration*;
times in seconds; α in seconds/hop; ``link_bw`` in bytes/s; ``flops``
in FLOP/s; ``bytes_per_elem`` in wire bytes per element; the
``overlap_efficiency`` / ``cross_step_efficiency`` knobs are fractions
in [0, 1].

Degeneracy guarantees (pinned by tests/test_overlap.py,
tests/test_gradsync.py, tests/test_zero3.py and tests/test_calibrate.py):

  * α = 0 (γ is 0 by default) and overlap disabled ⇒ the
    exposed-communication term equals ``model_volume * bytes_per_elem /
    bw`` exactly — the volume model is the degenerate point of the time
    model (the shared :func:`layer_geometry` keeps the two in lockstep);
  * ``GradSyncConfig.cross_step = False`` ⇒ :func:`dp_sync_time` is
    exactly the PR-3 exposed model;
  * the :class:`HardwareParams` defaults (``z_claims_first=True``,
    ``cross_step_efficiency=1.0``) ⇒ the pre-calibration model bitwise —
    an uncalibrated run is unchanged. ``core/calibrate.py`` fits
    measured replacements (``--calib`` on the CLIs);
  * ``g_seq = 1`` ⇒ the 4-factor model bitwise, and ``g_expert = 1`` ⇒
    the 5-factor model bitwise (tests/test_properties.py,
    tests/test_expert_parallel.py): every new factor at its identity
    value reproduces the previous model term for term.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.core.gradsync import GradSyncConfig
from repro.core.overlap import OverlapConfig


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One contraction layer: Y[m,n] = X[m,k] @ W[k,n].

    ``transposed`` layers store W with the x/y roles swapped (paper §4.1),
    which swaps G_x and G_y in the volume formulas (paper Table 1).
    ``count`` multiplies the layer (e.g. repeated blocks).
    ``moe_factor`` scales the *weight* terms only (routed experts hold
    ``E`` times the parameters but each token activates ``top_k``; the
    activation all-reduces see ``top_k/E``-scaled token counts folded in by
    the caller via separate LayerShape entries).
    """

    k: int
    n: int
    transposed: bool = False
    count: int = 1
    tokens_scale: float = 1.0  # fraction of batch tokens that hit this layer
    # elements per token of the KV block this layer's output feeds into
    # the context-parallel ring (2 * n_kv_heads * head_dim on the QKV
    # projection, 0 elsewhere): with g_seq > 1 the ring circulates
    # m_local * kv_ring_width / g_y elements per hop, fwd and bwd
    kv_ring_width: float = 0.0
    # expert-parallel markers: ``expert`` marks a routed-expert-bank
    # layer (its weights shard over g_expert, so the z/DP weight buffers
    # divide by it and its gradients need no expert-axis sync);
    # ``a2a_width`` (set once per MoE block, on the up-projection) is
    # the elements per token the capacity-based dispatch moves across
    # the expert axis each direction (capacity_factor * top_k * d) —
    # with g_expert > 1 the block pays 4 all_to_all passes of
    # m_local * a2a_width / (g_x * g_y) elements (dispatch + combine,
    # fwd + bwd)
    expert: bool = False
    a2a_width: float = 0.0


@dataclasses.dataclass(frozen=True)
class Decomposition:
    """``g_seq`` (context parallelism, a 5th factor of the same device
    budget) defaults to 1 so every 4-factor caller is unchanged; it
    joins ``g`` but NOT ``g_tensor`` — the seq axis shards activations
    by token, not weights, so the min_tensor memory floor and the
    paper's G_tensor-based closed forms see only x*y*z.

    ``g_expert`` (expert parallelism, a 6th factor) likewise defaults
    to 1 so every 5-factor caller reduces bitwise to today's model: it
    shards the routed-expert bank of MoE layers AND the batch (dense
    layers see it as a second data axis), and tokens cross it via the
    capacity-based dispatch/combine all-to-all. Like ``g_seq`` it joins
    ``g`` but not ``g_tensor`` — dense weights replicate over it."""

    g_data: int
    g_x: int
    g_y: int
    g_z: int
    g_seq: int = 1
    g_expert: int = 1

    @property
    def g(self) -> int:
        return (self.g_data * self.g_x * self.g_y * self.g_z * self.g_seq
                * self.g_expert)

    @property
    def g_tensor(self) -> int:
        return self.g_x * self.g_y * self.g_z


def allreduce_volume(p: int, buf: float) -> float:
    """Lower-bound all-reduce volume per participant (Eq. 1)."""
    return 0.0 if p <= 1 else 2.0 * (p - 1) / p * buf


def gather_or_scatter_volume(p: int, full_buf: float) -> float:
    """All-gather / reduce-scatter volume per participant."""
    return 0.0 if p <= 1 else (p - 1) / p * full_buf


def ring_exchange_volume(p: int, buf: float) -> float:
    """Ring-attention KV circulation volume per participant: p-1
    ppermute hops each forwarding a *full* per-rank block of ``buf``
    elements (no 1/p reduction — every rank must see every block), so
    the class is strictly more expensive per element than AG/RS."""
    return 0.0 if p <= 1 else (p - 1) * buf


def all_to_all_volume(p: int, buf: float) -> float:
    """All-to-all volume per participant: each rank keeps its own 1/p
    block and exchanges the other (p-1)/p of its ``buf``-element
    dispatch buffer — the MoE expert dispatch/combine geometry. Same
    wire bytes whether spelled as one ``lax.all_to_all`` or the
    ring-decomposed pairwise ppermute schedule (each block travels
    exactly once either way)."""
    return 0.0 if p <= 1 else (p - 1) / p * buf


@dataclasses.dataclass(frozen=True)
class LayerGeometry:
    """Shared per-layer geometry of the volume and time models.

    One source of truth for the axis-role swap, the local token count and
    every collective's buffer size, consumed by both :func:`layer_volume`
    and :func:`layer_time` — factored out so the α=0/no-overlap
    degeneracy of the time model to the volume model cannot drift
    (tests/test_overlap.py pins it).

    ``gx``/``gy`` are the contraction/output axis sizes with the
    transposed-layer role swap applied; buffers are in elements, with the
    :func:`allreduce_volume` / :func:`gather_or_scatter_volume`
    conventions.
    """

    gx: int
    gy: int
    m_local: float         # tokens hitting this layer, per (data x z x seq
                           # x expert)
    ar_fwd_buf: float      # fwd partial-output all-reduce over gx (Eq. 2)
    ar_bwd_buf: float      # bwd dX all-reduce over gy (Eq. 3)
    w_full_per_xy: float   # z-collective buffer: full weight per x*y shard
    n_gathers: int         # AG_z count (1 when the bwd re-gather is cached)
    dp_buf: float          # DP gradient buffer per device (w / (x*y*z))
    seq_buf: float         # per-hop KV ring block (elements per seq-rank)
    a2a_buf: float = 0.0   # expert dispatch buffer per rank (elements)


def layer_geometry(ls: LayerShape, tokens: int, d: Decomposition,
                   overlap: Optional[OverlapConfig] = None) -> LayerGeometry:
    gx, gy = (d.g_x, d.g_y) if not ls.transposed else (d.g_y, d.g_x)
    m_local = (tokens * ls.tokens_scale
               / (d.g_data * d.g_z * d.g_seq * d.g_expert))
    cached = bool(overlap and overlap.cache_weight_gather)
    w_full_per_xy = ls.k * ls.n / (d.g_x * d.g_y)
    if ls.expert:
        # the routed-expert bank co-shards over g_expert: every weight
        # buffer (and hence the z collectives and DP sync riding on it)
        # shrinks by 1/g_expert
        w_full_per_xy /= d.g_expert
    return LayerGeometry(
        gx=gx, gy=gy, m_local=m_local,
        ar_fwd_buf=m_local * ls.n / gy,
        ar_bwd_buf=m_local * ls.k / gx,
        w_full_per_xy=w_full_per_xy,
        n_gathers=1 if cached else 2,
        dp_buf=w_full_per_xy / d.g_z,
        # KV heads shard over the layer's output axis (gy for the
        # untransposed QKV projection); the ring forwards this per hop
        seq_buf=m_local * ls.kv_ring_width / gy,
        # per-rank dispatch buffer of the expert all-to-all: capacity
        # slots for every expert of this y row — capacity_factor *
        # top_k * m_local tokens of d/(gx*gy)-wide… folded into
        # a2a_width = capacity_factor * top_k * d by the caller
        a2a_buf=m_local * ls.a2a_width / (d.g_x * d.g_y))


def dp_sync_volume(p: int, buf: float,
                   gradsync: Optional[GradSyncConfig] = None,
                   microbatches: int = 1) -> float:
    """Per-device DP param/gradient-sync volume (elements) for one
    layer's weight buffer ``buf``.

    Blocking (no gradsync): one bandwidth-optimal all-reduce. Bucketed /
    ZeRO-1 (core/gradsync.py): one reduce-scatter per streamed
    microbatch plus one all-gather (updated params under ``zero``,
    gradients otherwise — same size). With ``stream`` off — or one
    microbatch — this is RS + AG == exactly the all-reduce volume (the
    Patarasuk-Yuan decomposition), so the bucketed path's volume
    degenerates to the blocking one at the no-overlap point.

    ZeRO-3 (``zero3``): every microbatch's forward all-gathers each
    layer's params just-in-time and its backward re-gathers them (remat)
    and reduce-scatters the gradient via the gather's transpose — per
    microbatch: 2 AG + 1 RS, or 1 AG + 1 RS with ``prefetch`` (the
    forward's working copy is retained for the backward). There is no
    trailing param rebroadcast (the update writes shards). At one
    microbatch with prefetch this is again AG + RS == the all-reduce
    volume — ZeRO-3's volume floor is the blocking one."""
    if p <= 1:
        return 0.0
    if gradsync is None or not gradsync.enabled:
        return allreduce_volume(p, buf)
    if gradsync.zero3:
        per_mb = (2 if gradsync.prefetch else 3)  # AG [+AG regather] + RS
        return microbatches * per_mb * gather_or_scatter_volume(p, buf)
    n = microbatches if gradsync.stream else 1
    return (n + 1) * gather_or_scatter_volume(p, buf)


def layer_volume(ls: LayerShape, tokens: int, d: Decomposition, *,
                 overlap: Optional[OverlapConfig] = None,
                 include_data_parallel: bool = True,
                 gradsync: Optional[GradSyncConfig] = None,
                 microbatches: int = 1) -> float:
    """Per-GPU per-iteration volume (elements) for one layer, fwd+bwd.

    ``tokens`` is the *global* batch in tokens (B*S). Paper Eqs. 2-4 are the
    ``g_z = 1`` specialization of this function.

    ``overlap.cache_weight_gather`` drops the backward re-gather of the
    weight (one AG_z per layer). The ring decompositions themselves move
    the same bytes as the blocking collectives, so the other overlap knobs
    do not change *volume* — only :func:`predict_step_time` sees them.
    ``gradsync``/``microbatches`` switch the DP term to the bucketed
    schedule of :func:`dp_sync_volume` (streamed reduce-scatters *do*
    change volume: one RS per microbatch).
    """
    g = layer_geometry(ls, tokens, d, overlap)
    # fwd all-reduce of partial outputs over the contraction axis (Eq. 2)
    v_fp = allreduce_volume(g.gx, g.ar_fwd_buf)
    # bwd all-reduce of dX over the output axis (Eq. 3)
    v_bp = allreduce_volume(g.gy, g.ar_bwd_buf)
    # z-axis weight collectives (4D): AG fwd (+AG bwd if not cached) + RS bwd
    v_z = (g.n_gathers + 1) * gather_or_scatter_volume(d.g_z,
                                                       g.w_full_per_xy)
    # context-parallel KV ring (5th axis): the attention circulates each
    # seq-rank's KV block around the ring in the forward and its
    # gradients back in the backward — 2 ring_exchange passes
    v_seq = 2.0 * ring_exchange_volume(d.g_seq, g.seq_buf)
    # expert-parallel token exchange (6th axis): dispatch + combine
    # all-to-all in the forward, mirrored in the backward — 4 passes of
    # the per-rank dispatch buffer
    v_ex = 4.0 * all_to_all_volume(d.g_expert, g.a2a_buf)
    # data-parallel gradient sync (the text measures it as 1e-3 of the
    # tensor terms but we keep it for completeness); weight grads are
    # additionally summed over seq (params replicate across it) and —
    # for dense layers — over expert (the expert bank itself is sharded
    # over g_expert, so its grads need no expert-axis sync)
    v_dp = 0.0
    if include_data_parallel:
        v_dp = dp_sync_volume(d.g_data, g.dp_buf, gradsync, microbatches)
        v_dp += allreduce_volume(d.g_seq, g.dp_buf)
        if not ls.expert:
            v_dp += allreduce_volume(d.g_expert, g.dp_buf)
    return ls.count * (v_fp + v_bp + v_z + v_seq + v_ex + v_dp)


def model_volume(layers: Sequence[LayerShape], tokens: int, d: Decomposition,
                 **kw) -> float:
    return sum(layer_volume(ls, tokens, d, **kw) for ls in layers)


def model_flops_per_token(cfg, mode: str = "train") -> float:
    """Model FLOPs one token costs: ``2 * N_active`` per forward pass
    (one multiply + one add per active parameter), tripled for training
    (forward + the two backward GEMMs per forward GEMM). MoE counts only
    the routed top-k + shared experts (``cfg.active_param_count``).

    Single source for both ``roofline.model_flops_per_device`` (HLO
    useful-flop ratio) and the telemetry MFU
    (``launch/telemetry.Telemetry``); tests/test_telemetry.py
    cross-checks the two against a hand-counted config."""
    if mode not in ("train", "serve"):
        raise ValueError(f"mode must be 'train' or 'serve', got {mode!r}")
    n = float(cfg.active_param_count())
    return (6.0 if mode == "train" else 2.0) * n


# ---------------------------------------------------------------------- #
# Closed forms from the paper (for tests / sanity checks)
# ---------------------------------------------------------------------- #

def transformer_layers(hidden: int, n_layers: int = 1,
                       ffn_mult: int = 4) -> List[LayerShape]:
    """Paper Table 1: the four FC layers of a transformer block."""
    h = hidden
    return [
        LayerShape(h, 3 * h, transposed=False, count=n_layers),
        LayerShape(h, h, transposed=True, count=n_layers),
        LayerShape(h, ffn_mult * h, transposed=False, count=n_layers),
        LayerShape(ffn_mult * h, h, transposed=True, count=n_layers),
    ]


def paper_transformer_volume(tokens: int, hidden: int, g: int,
                             g_x: int, g_y: int) -> float:
    """Eq. 6: V = 8*B*H/G * ((G_c - 1) + 3*(G_r - 1)).

    Here paper's (G_r, G_c) == our (g_x, g_y); paper's B is tokens.
    """
    return 8.0 * tokens * hidden / g * ((g_y - 1) + 3 * (g_x - 1))


def paper_optimal_gc(g_tensor: int) -> float:
    """Eq. 7: G_c = sqrt(3 * G_tensor)."""
    return math.sqrt(3.0 * g_tensor)


# ---------------------------------------------------------------------- #
# α-β (latency + bandwidth) overlap-aware time model
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class HardwareParams:
    """Link/compute constants for the step-time predictor.

    Units — ``alpha``: seconds per ring hop (link latency);
    ``gamma``: seconds per collective *call* (launch/dispatch overhead,
    LogGP's ``o``; hop-count-independent — on CPU backends it dominates
    α, on ring interconnects α dominates); ``link_bw``: bytes/s of
    per-device injection bandwidth; ``flops``: FLOP/s achievable matmul
    rate; ``bytes_per_elem``: wire bytes per model element (2.0 = bf16);
    ``overlap_efficiency``: the fraction of a layer's GEMM time the
    scheduler can actually use to hide ring traffic (1.0 = perfect
    latency hiding; real schedulers lose some to chunk-boundary
    bubbles).

    ``z_claims_first`` orders the overlap-window claims in
    :func:`layer_time`: True (default, the PR-2 assumption) lets the
    z-axis weight rings hide before the x/y activation all-reduce
    rings; False swaps the order. ``cross_step_efficiency`` scales the
    cross-step window of :func:`dp_sync_time` (1.0 = the terminal
    collectives hide fully, the PR-4 model).

    Defaults are *guessed* TPU v5e constants (launch/roofline.py uses
    the same ones); ``core/calibrate.py`` fits measured replacements
    from the live backend and the ``--calib`` CLI flags load them. The
    defaults are the uncalibrated degenerate point: every new field's
    default reproduces the pre-calibration model bitwise.
    """

    alpha: float = 1e-6
    gamma: float = 0.0
    link_bw: float = 50e9
    flops: float = 197e12
    bytes_per_elem: float = 2.0
    overlap_efficiency: float = 0.8
    z_claims_first: bool = True
    cross_step_efficiency: float = 1.0
    # HBM bandwidth (bytes/s), read ONLY by the serving-capacity model
    # (:func:`serve_capacity` — decode is memory-bound on the KV-cache
    # read, not FLOP-bound). No training-path prediction touches it, so
    # its default keeps every pre-serving result bitwise (the degeneracy
    # discipline of this docstring). v5e HBM ≈ 819 GB/s.
    mem_bw: float = 819e9


TPU_V5E = HardwareParams()


def collective_time(kind: str, p: int, buf: float,
                    hw: HardwareParams) -> float:
    """α-β time of one bandwidth-optimal (ring) collective.

    ``buf`` is in elements: the reduced buffer for ``all_reduce``, the
    full gathered buffer for ``all_gather``/``reduce_scatter`` — the same
    conventions as the volume functions above, which supply the byte
    term; the α term charges one hop per ring step (AR = 2(p-1) steps,
    AG/RS = p-1), the γ term one launch per collective call."""
    if p <= 1:
        return 0.0
    if kind == "all_reduce":
        vol, steps = allreduce_volume(p, buf), 2 * (p - 1)
    elif kind in ("all_gather", "reduce_scatter"):
        vol, steps = gather_or_scatter_volume(p, buf), p - 1
    elif kind == "ring_exchange":
        # seq-axis KV circulation: p-1 ppermute hops of a FULL per-rank
        # block (no 1/p factor) — β-heavier per element than AG/RS at
        # the same hop count, which is why it has its own α-β-γ class
        # in core/calibrate.py rather than reusing the gather fit
        vol, steps = ring_exchange_volume(p, buf), p - 1
    elif kind == "all_to_all":
        # expert dispatch/combine: (p-1)/p of the buffer crosses the
        # wire (each rank keeps its own block), in p-1 pairwise
        # exchanges under the ring decomposition — AG/RS wire geometry,
        # but its own fitted class (the pairwise pattern stresses
        # links differently than a hop chain; core/calibrate.py)
        vol, steps = all_to_all_volume(p, buf), p - 1
    else:
        raise ValueError(f"unknown collective kind {kind!r}")
    return (hw.gamma + hw.alpha * steps
            + vol * hw.bytes_per_elem / hw.link_bw)


@dataclasses.dataclass(frozen=True)
class StepTime:
    """Predicted per-device step time, decomposed.

    ``hidden_comm`` is communication that rides under compute (the ring-
    decomposed z collectives when overlap is on); only ``exposed_comm``
    adds wall-clock time."""

    compute: float
    exposed_comm: float
    hidden_comm: float

    @property
    def total(self) -> float:
        return self.compute + self.exposed_comm

    def __add__(self, o: "StepTime") -> "StepTime":
        return StepTime(self.compute + o.compute,
                        self.exposed_comm + o.exposed_comm,
                        self.hidden_comm + o.hidden_comm)


ZERO_TIME = StepTime(0.0, 0.0, 0.0)


def dp_sync_time(p: int, buf: float,
                 gradsync: Optional[GradSyncConfig],
                 microbatches: int, hw: HardwareParams
                 ) -> Tuple[float, float]:
    """(total, hideable) α-β time of one layer's DP param/gradient sync.

    Blocking: one all-reduce, nothing hideable (it runs after the whole
    microbatch loop). Bucketed/ZeRO-1: each streamed microbatch pays one
    reduce-scatter pass of ``ceil(buf·bytes / bucket_bytes)`` ring
    buckets — the bucket count is the α-latency knob: smaller buckets
    mean finer overlap grain but more ring launches — plus the final
    all-gather. The RS passes of the first ``microbatches - 1``
    microbatches are *hideable*: each rides under the next microbatch's
    backward (the last RS and the all-gather have no later compute in
    the step to hide behind). Only ring mode is hideable — the blocking
    psum_scatter is a synchronizing collective.

    ZeRO-3 (``zero3``): ``dp_sync_volume``'s per-microbatch AG/RS passes
    stream *per layer* through the scan, so every pass except the step's
    first param gather (nothing earlier to ride under) and the last
    gradient RS (nothing later) is hideable under the layer compute
    window.

    ``cross_step`` widens the window across the step boundary: the
    terminal collectives — the ZeRO-1 param all-gather / ZeRO-3 leading
    param gather (they hide under the NEXT step's first-microbatch
    forward) and the last RS pass (it hides under the optimizer math) —
    become hideable too. With ``cross_step`` off this function is
    exactly the PR-3 exposed model, and with α = 0 and nothing hideable
    (one microbatch, or ``stream`` off) the total reduces exactly to
    ``dp_sync_volume · bytes / bw`` — degeneracies
    tests/test_gradsync.py and tests/test_zero3.py pin."""
    if p <= 1:
        return 0.0, 0.0
    if gradsync is None or not gradsync.enabled:
        return collective_time("all_reduce", p, buf, hw), 0.0
    n_buckets = max(1, math.ceil(buf * hw.bytes_per_elem
                                 / max(gradsync.bucket_bytes, 1)))
    t_pass = ((hw.gamma + hw.alpha * (p - 1)) * n_buckets
              + gather_or_scatter_volume(p, buf)
              * hw.bytes_per_elem / hw.link_bw)
    if gradsync.zero3:
        per_mb = 2 if gradsync.prefetch else 3
        total = microbatches * per_mb * t_pass
        hideable = 0.0
        if gradsync.ring:
            # all per-layer streams ride the scan except the leading
            # param gather and the trailing gradient reduce-scatter
            hideable = total - 2 * t_pass
            if gradsync.cross_step:
                # spelled so efficiency 1.0 gives `total` bitwise (the
                # pre-calibration model) and 0.0 the cross_step=False one
                hideable = total - ((1.0 - hw.cross_step_efficiency)
                                    * 2 * t_pass)
        return total, hideable
    n = microbatches if gradsync.stream else 1
    total = (n + 1) * t_pass  # n RS passes + the AG rebroadcast
    hideable = (n - 1) * t_pass if (gradsync.ring and gradsync.stream
                                    and microbatches > 1) else 0.0
    if gradsync.cross_step and gradsync.ring:
        # cross-step window: the param/gradient all-gather hides under
        # the next step's first-microbatch forward, the last RS pass
        # under the optimizer math — scaled by the *measured* fraction
        # of that window (calibrate.cross_step_probe; 1.0 uncalibrated)
        hideable = hideable + hw.cross_step_efficiency * 2 * t_pass
    return total, hideable


def layer_time(ls: LayerShape, tokens: int, d: Decomposition,
               hw: HardwareParams = TPU_V5E, *,
               overlap: Optional[OverlapConfig] = None,
               include_data_parallel: bool = True,
               gradsync: Optional[GradSyncConfig] = None,
               microbatches: int = 1) -> StepTime:
    """Overlap-aware α-β time of one layer, fwd+bwd (cf. layer_volume).

    Compute: 3 GEMMs (fwd Y, bwd dX, bwd dW) of 2·m·k·n/(gx·gy) flops
    each. The activation all-reduces are priced as 2(p-1)-hop rings
    (Eqs. 2-3 buffers); with ``overlap.all_reduce`` their ring
    decomposition hides under whatever part of the
    ``overlap_efficiency``-scaled compute window the z weight rings
    (``overlap.matmul``) left over — the z collectives hide first by
    default, since their rings pipeline against the very GEMM that
    consumes/produces the weight (``hw.z_claims_first=False``, set when
    ``calibrate.overlap_probe`` measures the opposite, swaps the claim
    order). With ``gradsync`` streaming (core/gradsync.py) the DP
    reduce-scatter rings claim whatever window is left after z and the
    activation ARs (:func:`dp_sync_time`: the last microbatch's RS and
    the param all-gather stay exposed). Blocking mode keeps every
    collective fully exposed (overdecomposition overlaps them *across*
    batch shards; that is a step-level effect the dry-run measures, not
    modeled here)."""
    g = layer_geometry(ls, tokens, d, overlap)
    t_compute = 6.0 * g.m_local * ls.k * ls.n / (g.gx * g.gy) / hw.flops
    # activation all-reduces (Eqs. 2-3): 2(p-1) α-β ring steps each
    t_act = (collective_time("all_reduce", g.gx, g.ar_fwd_buf, hw)
             + collective_time("all_reduce", g.gy, g.ar_bwd_buf, hw))
    # z-axis weight collectives (AG fwd [+AG bwd] + RS bwd)
    t_z = (g.n_gathers
           * collective_time("all_gather", d.g_z, g.w_full_per_xy, hw)
           + collective_time("reduce_scatter", d.g_z, g.w_full_per_xy, hw))
    # seq-axis KV ring (fwd + bwd circulation) and the seq grad
    # all-reduce; the latter is a step-end psum like blocking DP —
    # never hideable here
    t_seq = 2.0 * collective_time("ring_exchange", d.g_seq, g.seq_buf, hw)
    t_seq_grad = (collective_time("all_reduce", d.g_seq, g.dp_buf, hw)
                  if include_data_parallel else 0.0)
    # expert-axis token exchange (dispatch + combine, fwd + bwd) and
    # the dense-layer grad all-reduce over expert (the expert bank is
    # sharded over the axis; dense params replicate and sync like a
    # second DP pass — step-end, never hideable)
    t_ex = (4.0 * collective_time("all_to_all", d.g_expert, g.a2a_buf, hw)
            if ls.a2a_width > 0 else 0.0)
    t_ex_grad = (collective_time("all_reduce", d.g_expert, g.dp_buf, hw)
                 if include_data_parallel and not ls.expert else 0.0)
    t_dp = dp_hideable = 0.0
    if include_data_parallel:
        t_dp, dp_hideable = dp_sync_time(d.g_data, g.dp_buf, gradsync,
                                         microbatches, hw)
    window = hw.overlap_efficiency * t_compute
    want_z = overlap is not None and overlap.matmul and d.g_z > 1
    want_ar = overlap is not None and overlap.all_reduce
    # hop i+1's KV permute issues before hop i's partial attention
    # (layers/attention.py seq_attn), so the ring rides the attention
    # compute itself — it claims the window after z and the activation
    # ARs (claim order z -> AR -> seq -> expert a2a -> DP, the same
    # measured-window discipline as the rest)
    want_seq = (overlap is not None and overlap.ring_attention
                and d.g_seq > 1 and ls.kv_ring_width > 0)
    # the ring-decomposed a2a's pairwise exchanges interleave with the
    # per-source expert GEMMs (collective_matmul.ring_a2a_expert), so
    # it hides in whatever window the earlier claims left
    want_ex = (overlap is not None and overlap.expert_a2a
               and d.g_expert > 1 and ls.a2a_width > 0)
    # window claim order: z weight rings first by default (they pipeline
    # against the very GEMM that consumes/produces the weight);
    # hw.z_claims_first=False swaps it — calibrate.overlap_probe measures
    # which ring actually hides better on the live backend
    if hw.z_claims_first:
        hidden_z = min(t_z, window) if want_z else 0.0
        hidden_ar = min(t_act, window - hidden_z) if want_ar else 0.0
    else:
        hidden_ar = min(t_act, window) if want_ar else 0.0
        hidden_z = min(t_z, window - hidden_ar) if want_z else 0.0
    hidden_seq = (min(t_seq, max(window - hidden_z - hidden_ar, 0.0))
                  if want_seq else 0.0)
    hidden_ex = (min(t_ex, max(window - hidden_z - hidden_ar - hidden_seq,
                               0.0))
                 if want_ex else 0.0)
    hidden_dp = min(dp_hideable,
                    max(window - hidden_z - hidden_ar - hidden_seq
                        - hidden_ex, 0.0))
    hidden = hidden_z + hidden_ar + hidden_seq + hidden_ex + hidden_dp
    exposed = (t_act + t_z + t_seq + t_seq_grad + t_ex + t_ex_grad + t_dp
               - hidden)
    return StepTime(ls.count * t_compute, ls.count * exposed,
                    ls.count * hidden)


def predict_step_time(layers: Sequence[LayerShape], tokens: int,
                      d: Decomposition, hw: HardwareParams = TPU_V5E, *,
                      overlap: Optional[OverlapConfig] = None,
                      include_data_parallel: bool = True,
                      gradsync: Optional[GradSyncConfig] = None,
                      microbatches: int = 1) -> StepTime:
    """Per-device per-iteration predicted time for a layer list (§5's
    analytical model, upgraded from volume to overlap-aware α-β time).

    With ``overlap=None`` (or all knobs off) and ``hw.alpha == 0`` the
    exposed-communication term equals
    ``model_volume(...) * hw.bytes_per_elem / hw.link_bw`` exactly —
    including the bucketed DP path of ``gradsync``, whose streamed
    microbatch reduce-scatters only become *hidden* when there is a
    later microbatch backward to ride under (``microbatches > 1`` with
    ``stream``/``ring`` on; :func:`dp_sync_time`), the ZeRO-3
    param-shard streams (per-layer gather/RS rides the scan; only the
    terminal passes stay exposed), and the ``cross_step`` window that
    hides exactly those terminal passes across the step boundary.
    """
    out = ZERO_TIME
    for ls in layers:
        out = out + layer_time(ls, tokens, d, hw, overlap=overlap,
                               include_data_parallel=include_data_parallel,
                               gradsync=gradsync, microbatches=microbatches)
    return out


# ---------------------------------------------------------------------- #
# Serving capacity (decode-time) model
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ServeCapacity:
    """Predicted steady-state continuous-batching decode capacity.

    ``step`` is one decode iteration over every layer (forward-only α-β
    time, KV reads included in compute); ``kv_time`` is the HBM time of
    the paged KV-cache reads alone (the memory-bound decode term);
    ``tokens_per_s`` = batch / step.total (each iteration emits one
    token per active slot); ``step_latency_ms`` is the per-token decode
    latency a request observes."""

    step: StepTime
    kv_time: float
    batch: int
    context: int

    @property
    def tokens_per_s(self) -> float:
        return self.batch / max(self.step.total, 1e-30)

    @property
    def step_latency_ms(self) -> float:
        return self.step.total * 1e3


def serve_layer_time(ls: LayerShape, batch: int, d: Decomposition,
                     hw: HardwareParams = TPU_V5E, *, context: int,
                     overlap: Optional[OverlapConfig] = None
                     ) -> Tuple[StepTime, float]:
    """Forward-only α-β time of one layer for a decode iteration of
    ``batch`` single-token rows against ``context`` cached tokens.

    Reuses :func:`layer_geometry` with tokens = batch (m_local = the
    shard's active slots), so the same calibrated α/β/γ constants price
    the collectives. Differences from :func:`layer_time`, all decode
    facts: ONE GEMM (2·m·k·n flops, no backward); one fwd partial-output
    all-reduce over gx (γ-dominated at decode sizes — the buffer is a
    few KB, so the launch overhead IS the cost, which is why calibrated
    γ matters more here than anywhere in training); one z weight
    all-gather (batch-independent — the price of co-sharding weights
    over z at tiny m); and a KV-read term ``m_local · context ·
    kv_ring_width / g_y`` elements from HBM at ``hw.mem_bw`` on layers
    that carry KV (``kv_ring_width > 0``, the QKV projection). The
    overlap window claims z rings then activation ARs, scaled by the
    same measured ``overlap_efficiency``."""
    g = layer_geometry(ls, batch, d, overlap)
    t_compute = 2.0 * g.m_local * ls.k * ls.n / (g.gx * g.gy) / hw.flops
    t_kv = (g.m_local * context * ls.kv_ring_width / g.gy
            * hw.bytes_per_elem / hw.mem_bw)
    t_act = collective_time("all_reduce", g.gx, g.ar_fwd_buf, hw)
    t_z = collective_time("all_gather", d.g_z, g.w_full_per_xy, hw)
    window = hw.overlap_efficiency * (t_compute + t_kv)
    want_z = overlap is not None and overlap.matmul and d.g_z > 1
    want_ar = overlap is not None and overlap.all_reduce
    if hw.z_claims_first:
        hidden_z = min(t_z, window) if want_z else 0.0
        hidden_ar = min(t_act, window - hidden_z) if want_ar else 0.0
    else:
        hidden_ar = min(t_act, window) if want_ar else 0.0
        hidden_z = min(t_z, window - hidden_ar) if want_z else 0.0
    hidden = hidden_z + hidden_ar
    exposed = t_act + t_z - hidden
    return (StepTime(ls.count * (t_compute + t_kv), ls.count * exposed,
                     ls.count * hidden),
            ls.count * t_kv)


def serve_capacity(layers: Sequence[LayerShape], batch: int,
                   d: Decomposition, hw: HardwareParams = TPU_V5E, *,
                   context: int,
                   overlap: Optional[OverlapConfig] = None
                   ) -> ServeCapacity:
    """Predict continuous-batching decode capacity for a mesh: the
    serving analogue of :func:`predict_step_time` (docs/serving.md).

    ``layers`` is the arch's ``comm_layers()`` list, ``batch`` the
    engine's active slot count (tokens per decode iteration), ``context``
    the mean cached tokens per slot (prompt + half the generation is the
    steady-state average). Throughput ranks meshes — validated against
    the measured open-loop benchmark via Spearman rank correlation
    (EXPERIMENTS.md §Serving), exactly how the training model was
    validated in fig5_measured."""
    step, kv = ZERO_TIME, 0.0
    for ls in layers:
        st, k = serve_layer_time(ls, batch, d, hw, context=context,
                                 overlap=overlap)
        step, kv = step + st, kv + k
    return ServeCapacity(step=step, kv_time=kv, batch=batch,
                         context=context)


# ---------------------------------------------------------------------- #
# Decomposition optimizer
# ---------------------------------------------------------------------- #

def _divisors(n: int) -> List[int]:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return out


@dataclasses.dataclass(frozen=True)
class Constraints:
    """Divisibility / memory constraints for a real model."""

    global_batch: int = 0          # g_data * g_z must divide it (0 = skip)
    max_x: int = 0                 # e.g. d_model shard limit (0 = unbounded)
    max_y: int = 0                 # e.g. num_kv_heads (0 = unbounded)
    min_tensor: int = 1            # memory floor: params must fit
    x_divides: Tuple[int, ...] = ()  # dims that g_x must divide
    y_divides: Tuple[int, ...] = ()
    z_divides: Tuple[int, ...] = ()
    # context parallelism: largest g_seq the search may use (1, the
    # default, keeps the 4-factor enumeration byte-identical) and the
    # dims g_seq must divide (the sequence length)
    max_seq: int = 1
    seq_divides: Tuple[int, ...] = ()
    # expert parallelism: largest g_expert the search may use (1, the
    # default, keeps the 5-factor enumeration byte-identical) and the
    # dims g_expert must divide (the routed expert count)
    max_expert: int = 1
    expert_divides: Tuple[int, ...] = ()


def enumerate_decompositions(g: int, c: Constraints = Constraints()
                             ) -> Iterable[Decomposition]:
    for g_data in _divisors(g):
        rem = g // g_data
        for g_x in _divisors(rem):
            rem2 = rem // g_x
            for g_z in _divisors(rem2):
                rem3 = rem2 // g_z
                for g_seq in _divisors(rem3):
                    if g_seq > max(c.max_seq, 1):
                        continue
                    rem4 = rem3 // g_seq
                    for g_expert in _divisors(rem4):
                        if g_expert > max(c.max_expert, 1):
                            continue
                        g_y = rem4 // g_expert
                        d = Decomposition(g_data, g_x, g_y, g_z, g_seq,
                                          g_expert)
                        if d.g_tensor < c.min_tensor:
                            continue
                        # the batch shards over data x z x expert
                        if c.global_batch and c.global_batch % (
                                g_data * g_z * g_expert):
                            continue
                        if c.max_x and g_x > c.max_x:
                            continue
                        if c.max_y and g_y > c.max_y:
                            continue
                        if any(dim % g_x for dim in c.x_divides):
                            continue
                        if any(dim % g_y for dim in c.y_divides):
                            continue
                        if any(dim % g_z for dim in c.z_divides):
                            continue
                        if any(dim % g_seq for dim in c.seq_divides):
                            continue
                        if any(dim % g_expert for dim in c.expert_divides):
                            continue
                        yield d


def optimize_decomposition(layers: Sequence[LayerShape], tokens: int, g: int,
                           constraints: Constraints = Constraints(),
                           top_k: int = 1, *, objective: str = "volume",
                           hw: Optional[HardwareParams] = None, **kw
                           ) -> List[Tuple[Decomposition, float]]:
    """Exhaustively rank decompositions (paper §5.2 does this analytically
    for transformers; we do it for arbitrary layer lists, which is what
    the paper's 'general model' promises).

    ``objective='volume'`` scores by modeled per-device element volume
    (the paper's Eq. 5 criterion); ``objective='time'`` by the α-β
    overlap-aware :func:`predict_step_time` total — which additionally
    sees latency (penalizing needlessly deep rings) and the overlap knobs
    (``overlap=OverlapConfig(...)`` in ``kw`` hides z traffic under
    compute, making z-heavier decompositions cheaper than volume alone
    suggests)."""
    if objective == "time":
        hw = hw or TPU_V5E

        def score(d):
            return predict_step_time(layers, tokens, d, hw, **kw).total
    elif objective == "volume":
        if hw is not None:
            raise ValueError("hw is only meaningful with objective='time' "
                             "(the volume model has no hardware terms)")

        def score(d):
            return model_volume(layers, tokens, d, **kw)
    else:
        raise ValueError(f"unknown objective {objective!r}")
    scored = [(d, score(d)) for d in enumerate_decompositions(g, constraints)]
    if not scored:
        raise ValueError(f"no feasible decomposition of {g} devices under "
                         f"{constraints}")
    scored.sort(key=lambda t: (t[1], t[0].g_tensor))
    return scored[:top_k]


def megatron_decomposition(g: int, g_tensor: int) -> Decomposition:
    """The text's observation: G_c = G_tensor (1D TP) == Megatron-LM.
    (G_c is our g_y: column-parallel QKV, row-parallel projections.)"""
    return Decomposition(g // g_tensor, 1, g_tensor, 1)


def cai3d_decomposition(g: int, g_tensor: int) -> Optional[Decomposition]:
    """Colossal-AI-3D: symmetric cube over the tensor group."""
    cube = round(g_tensor ** (1 / 3))
    if cube ** 3 != g_tensor:
        return None
    return Decomposition(g // g_tensor, cube, cube, cube)
