"""Analytic communication model (paper §5, generalized to 4D).

The supplied text models the per-GPU, per-iteration all-reduce volume of its
2D tensor-parallel algorithm (Eqs. 1-4) and derives decomposition rules
(max ``G_data``; for transformers ``G_c = sqrt(3 G_tensor)``, Eq. 7). The 4D
algorithm adds the depth axis ``G_z``; its extra collectives are the weight
all-gather (forward) and the weight-gradient reduce-scatter (backward) over
``z``, whose volumes are *batch-independent* — the 4D trade: pay
``O(params)`` weight traffic to cut ``O(batch)`` activation traffic by
``1/G_z``.

All volumes are *elements sent+received per device per iteration* (multiply
by dtype bytes for bytes), mirroring the paper. Collectives are assumed
bandwidth-optimal (Patarasuk & Yuan): ``V_AR = 2 (p-1)/p * buf``,
``V_AG = V_RS = (p-1)/p * buf_full``.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One contraction layer: Y[m,n] = X[m,k] @ W[k,n].

    ``transposed`` layers store W with the x/y roles swapped (paper §4.1),
    which swaps G_x and G_y in the volume formulas (paper Table 1).
    ``count`` multiplies the layer (e.g. repeated blocks).
    ``moe_factor`` scales the *weight* terms only (routed experts hold
    ``E`` times the parameters but each token activates ``top_k``; the
    activation all-reduces see ``top_k/E``-scaled token counts folded in by
    the caller via separate LayerShape entries).
    """

    k: int
    n: int
    transposed: bool = False
    count: int = 1
    tokens_scale: float = 1.0  # fraction of batch tokens that hit this layer


@dataclasses.dataclass(frozen=True)
class Decomposition:
    g_data: int
    g_x: int
    g_y: int
    g_z: int

    @property
    def g(self) -> int:
        return self.g_data * self.g_x * self.g_y * self.g_z

    @property
    def g_tensor(self) -> int:
        return self.g_x * self.g_y * self.g_z


def allreduce_volume(p: int, buf: float) -> float:
    """Lower-bound all-reduce volume per participant (Eq. 1)."""
    return 0.0 if p <= 1 else 2.0 * (p - 1) / p * buf


def gather_or_scatter_volume(p: int, full_buf: float) -> float:
    """All-gather / reduce-scatter volume per participant."""
    return 0.0 if p <= 1 else (p - 1) / p * full_buf


def layer_volume(ls: LayerShape, tokens: int, d: Decomposition, *,
                 cached_weight_gather: bool = False,
                 include_data_parallel: bool = True) -> float:
    """Per-GPU per-iteration volume (elements) for one layer, fwd+bwd.

    ``tokens`` is the *global* batch in tokens (B*S). Paper Eqs. 2-4 are the
    ``g_z = 1`` specialization of this function.
    """
    gx, gy = (d.g_x, d.g_y) if not ls.transposed else (d.g_y, d.g_x)
    m_local = tokens * ls.tokens_scale / (d.g_data * d.g_z)
    # fwd all-reduce of partial outputs over the contraction axis (Eq. 2)
    v_fp = allreduce_volume(gx, m_local * ls.n / gy)
    # bwd all-reduce of dX over the output axis (Eq. 3)
    v_bp = allreduce_volume(gy, m_local * ls.k / gx)
    # z-axis weight collectives (4D): AG fwd (+AG bwd if not cached) + RS bwd
    w_full_per_xy = ls.k * ls.n / (d.g_x * d.g_y)
    n_gathers = 2 if not cached_weight_gather else 1
    v_z = (n_gathers + 1) * gather_or_scatter_volume(d.g_z, w_full_per_xy)
    # data-parallel gradient all-reduce (the text measures it as 1e-3 of the
    # tensor terms but we keep it for completeness)
    v_dp = 0.0
    if include_data_parallel:
        v_dp = allreduce_volume(d.g_data, w_full_per_xy / d.g_z)
    return ls.count * (v_fp + v_bp + v_z + v_dp)


def model_volume(layers: Sequence[LayerShape], tokens: int, d: Decomposition,
                 **kw) -> float:
    return sum(layer_volume(ls, tokens, d, **kw) for ls in layers)


# ---------------------------------------------------------------------- #
# Closed forms from the paper (for tests / sanity checks)
# ---------------------------------------------------------------------- #

def transformer_layers(hidden: int, n_layers: int = 1,
                       ffn_mult: int = 4) -> List[LayerShape]:
    """Paper Table 1: the four FC layers of a transformer block."""
    h = hidden
    return [
        LayerShape(h, 3 * h, transposed=False, count=n_layers),
        LayerShape(h, h, transposed=True, count=n_layers),
        LayerShape(h, ffn_mult * h, transposed=False, count=n_layers),
        LayerShape(ffn_mult * h, h, transposed=True, count=n_layers),
    ]


def paper_transformer_volume(tokens: int, hidden: int, g: int,
                             g_x: int, g_y: int) -> float:
    """Eq. 6: V = 8*B*H/G * ((G_c - 1) + 3*(G_r - 1)).

    Here paper's (G_r, G_c) == our (g_x, g_y); paper's B is tokens.
    """
    return 8.0 * tokens * hidden / g * ((g_y - 1) + 3 * (g_x - 1))


def paper_optimal_gc(g_tensor: int) -> float:
    """Eq. 7: G_c = sqrt(3 * G_tensor)."""
    return math.sqrt(3.0 * g_tensor)


# ---------------------------------------------------------------------- #
# Decomposition optimizer
# ---------------------------------------------------------------------- #

def _divisors(n: int) -> List[int]:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return out


@dataclasses.dataclass(frozen=True)
class Constraints:
    """Divisibility / memory constraints for a real model."""

    global_batch: int = 0          # g_data * g_z must divide it (0 = skip)
    max_x: int = 0                 # e.g. d_model shard limit (0 = unbounded)
    max_y: int = 0                 # e.g. num_kv_heads (0 = unbounded)
    min_tensor: int = 1            # memory floor: params must fit
    x_divides: Tuple[int, ...] = ()  # dims that g_x must divide
    y_divides: Tuple[int, ...] = ()
    z_divides: Tuple[int, ...] = ()


def enumerate_decompositions(g: int, c: Constraints = Constraints()
                             ) -> Iterable[Decomposition]:
    for g_data in _divisors(g):
        rem = g // g_data
        for g_x in _divisors(rem):
            rem2 = rem // g_x
            for g_z in _divisors(rem2):
                g_y = rem2 // g_z
                d = Decomposition(g_data, g_x, g_y, g_z)
                if d.g_tensor < c.min_tensor:
                    continue
                if c.global_batch and c.global_batch % (g_data * g_z):
                    continue
                if c.max_x and g_x > c.max_x:
                    continue
                if c.max_y and g_y > c.max_y:
                    continue
                if any(dim % g_x for dim in c.x_divides):
                    continue
                if any(dim % g_y for dim in c.y_divides):
                    continue
                if any(dim % g_z for dim in c.z_divides):
                    continue
                yield d


def optimize_decomposition(layers: Sequence[LayerShape], tokens: int, g: int,
                           constraints: Constraints = Constraints(),
                           top_k: int = 1, **kw
                           ) -> List[Tuple[Decomposition, float]]:
    """Exhaustively rank decompositions by modeled volume (paper §5.2 does
    this analytically for transformers; we do it for arbitrary layer lists,
    which is what the paper's 'general model' promises)."""
    scored = [(d, model_volume(layers, tokens, d, **kw))
              for d in enumerate_decompositions(g, constraints)]
    if not scored:
        raise ValueError(f"no feasible decomposition of {g} devices under "
                         f"{constraints}")
    scored.sort(key=lambda t: (t[1], t[0].g_tensor))
    return scored[:top_k]


def megatron_decomposition(g: int, g_tensor: int) -> Decomposition:
    """The text's observation: G_c = G_tensor (1D TP) == Megatron-LM.
    (G_c is our g_y: column-parallel QKV, row-parallel projections.)"""
    return Decomposition(g // g_tensor, 1, g_tensor, 1)


def cai3d_decomposition(g: int, g_tensor: int) -> Optional[Decomposition]:
    """Colossal-AI-3D: symmetric cube over the tensor group."""
    cube = round(g_tensor ** (1 / 3))
    if cube ** 3 != g_tensor:
        return None
    return Decomposition(g // g_tensor, cube, cube, cube)
