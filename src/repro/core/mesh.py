"""Mesh axis conventions for the 4D hybrid algorithm.

The paper decomposes ``G`` devices as ``G_data x G_x x G_y x G_z``:

  * ``data`` — data parallelism (batch sharding; may include a leading
    ``pod`` axis on multi-pod meshes, since pods simply extend data
    parallelism),
  * ``x``    — tensor-parallel rows: shards the *contraction* (k) dim of a
    "normal" layer's weight and the feature dim of the residual stream,
  * ``y``    — tensor-parallel columns: shards the output (n) dim of a
    normal layer; activations are replicated over ``y``,
  * ``z``    — depth: co-shards the batch and the weight/optimizer storage
    (weights all-gathered over ``z`` at use, gradients reduce-scattered).

Setting ``z=None`` (G_z=1) recovers the supplied Tensor3D text verbatim;
setting additionally ``y=None`` recovers Megatron-LM 1D tensor parallelism.

Everything in :mod:`repro.layers` is written against :class:`MeshAxes`, so
the same model code runs on the assignment-mandated ``("data","model")``
production mesh (1D TP baseline) and on the 4D mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]


def _names(axis: AxisName) -> Tuple[str, ...]:
    if axis is None:
        return ()
    if isinstance(axis, str):
        return (axis,)
    return tuple(axis)


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical 4D axes bound to physical mesh axis names (or None == size 1)."""

    data: AxisName = ("data",)
    x: AxisName = "x"
    y: AxisName = "y"
    z: AxisName = "z"
    # static sizes, captured from the physical mesh at bind time
    sizes: Tuple[Tuple[str, int], ...] = ()

    # ------------------------------------------------------------------ #
    def size(self, axis: AxisName) -> int:
        d = dict(self.sizes)
        return math.prod(d.get(n, 1) for n in _names(axis))

    @property
    def dp(self) -> int:
        return self.size(self.data)

    @property
    def gx(self) -> int:
        return self.size(self.x)

    @property
    def gy(self) -> int:
        return self.size(self.y)

    @property
    def gz(self) -> int:
        return self.size(self.z)

    @property
    def tensor(self) -> int:
        return self.gx * self.gy * self.gz

    @property
    def batch_shards(self) -> int:
        """How many ways the global batch is split (data x z)."""
        return self.dp * self.gz

    def axis(self, logical: str) -> AxisName:
        return {"data": self.data, "x": self.x, "y": self.y, "z": self.z}[logical]

    def all_names(self) -> Tuple[str, ...]:
        out: Tuple[str, ...] = ()
        for a in (self.data, self.x, self.y, self.z):
            out += _names(a)
        return out

    def batch_axes(self) -> Tuple[str, ...]:
        """Mesh axes the batch dim is sharded over (data then z)."""
        return _names(self.data) + _names(self.z)

    def swap_xy(self) -> "MeshAxes":
        return dataclasses.replace(self, x=self.y, y=self.x)

    # -- PartitionSpec helpers ---------------------------------------- #
    def pspec(self, *dims: AxisName) -> P:
        """Build a PartitionSpec from per-dim logical axis names."""
        out = []
        for d in dims:
            n = _names(d)
            if not n:
                out.append(None)
            elif len(n) == 1:
                out.append(n[0])
            else:
                out.append(n)
        return P(*out)


def bind_axes(mesh: Mesh, *, data: AxisName, x: AxisName = None,
              y: AxisName = None, z: AxisName = None) -> MeshAxes:
    """Bind logical 4D axes to a physical mesh, validating names."""
    sizes = tuple(zip(mesh.axis_names, mesh.devices.shape))
    known = dict(sizes)
    for a in (data, x, y, z):
        for n in _names(a):
            if n not in known:
                raise ValueError(f"axis {n!r} not in mesh axes {mesh.axis_names}")
    return MeshAxes(data=data, x=x, y=y, z=z, sizes=sizes)


# ---------------------------------------------------------------------- #
# Collective helpers that degrade to identity when the axis is unmapped.
# These are only legal inside shard_map bodies.
# ---------------------------------------------------------------------- #

def psum(v, axis: AxisName):
    n = _names(axis)
    return jax.lax.psum(v, n) if n else v


def pmax(v, axis: AxisName):
    n = _names(axis)
    return jax.lax.pmax(v, n) if n else v


def all_gather(v, axis: AxisName, *, dim: int, tiled: bool = True):
    n = _names(axis)
    if not n:
        return v
    out = v
    for name in n:
        out = jax.lax.all_gather(out, name, axis=dim, tiled=tiled)
    return out


def psum_scatter(v, axis: AxisName, *, dim: int, tiled: bool = True):
    n = _names(axis)
    if not n:
        return v
    out = v
    for name in reversed(n):
        out = jax.lax.psum_scatter(out, name, scatter_dimension=dim, tiled=tiled)
    return out


def axis_index(axis: AxisName):
    n = _names(axis)
    if not n:
        return jnp.int32(0)
    idx = jnp.int32(0)
    for name in n:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


def axis_size_in(axes: MeshAxes, axis: AxisName) -> int:
    return axes.size(axis)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
