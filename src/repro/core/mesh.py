"""Mesh axis conventions for the 4D hybrid algorithm.

The paper decomposes ``G`` devices as ``G_data x G_x x G_y x G_z``:

  * ``data`` — data parallelism (batch sharding; may include a leading
    ``pod`` axis on multi-pod meshes, since pods simply extend data
    parallelism),
  * ``x``    — tensor-parallel rows: shards the *contraction* (k) dim of a
    "normal" layer's weight and the feature dim of the residual stream,
  * ``y``    — tensor-parallel columns: shards the output (n) dim of a
    normal layer; activations are replicated over ``y``,
  * ``z``    — depth: co-shards the batch and the weight/optimizer storage
    (weights all-gathered over ``z`` at use, gradients reduce-scattered),
  * ``seq``  — context parallelism: shards the *sequence* (token) dim of
    activations in a striped layout (seq-rank r holds global positions
    r, r+p, r+2p, ... — the causal load-balancing stripe); weights stay
    replicated over ``seq`` and attention runs as a KV ``ppermute`` ring
    (layers/attention.py),
  * ``expert`` — expert parallelism: shards the routed-expert bank of
    MoE layers (layers/moe.py) AND the batch dim (dense layers see it as
    a second data axis); tokens cross it via the capacity-based
    dispatch/combine all-to-all, ring-decomposed into pairwise
    ``ppermute`` exchanges when ``OverlapConfig.expert_a2a`` is on
    (core/collective_matmul.py).

Setting ``z=None`` (G_z=1) recovers the supplied Tensor3D text verbatim;
setting additionally ``y=None`` recovers Megatron-LM 1D tensor
parallelism. ``seq=None`` (G_seq=1, the default) recovers the 4D model
of PRs 1-5 bitwise, and ``expert=None`` (G_expert=1, the default) the
5-axis model of PRs 6-9 bitwise.

Everything in :mod:`repro.layers` is written against :class:`MeshAxes`, so
the same model code runs on the assignment-mandated ``("data","model")``
production mesh (1D TP baseline) and on the 4D mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import trace
from repro.core.compat import axis_size as _axis_size
from repro.core.overlap import OverlapConfig

AxisName = Union[str, Tuple[str, ...], None]


def _names(axis: AxisName) -> Tuple[str, ...]:
    if axis is None:
        return ()
    if isinstance(axis, str):
        return (axis,)
    return tuple(axis)


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical 4D axes bound to physical mesh axis names (or None == size 1)."""

    data: AxisName = ("data",)
    x: AxisName = "x"
    y: AxisName = "y"
    z: AxisName = "z"
    # context parallelism (None == unsharded sequence, the 4D model)
    seq: AxisName = None
    # expert parallelism (None == experts sharded over y only, the
    # 5-axis model)
    expert: AxisName = None
    # static sizes, captured from the physical mesh at bind time
    sizes: Tuple[Tuple[str, int], ...] = ()
    # comm/compute-overlap knobs for the tp primitives (core/overlap.py);
    # rides here so layers don't thread an extra argument everywhere
    overlap: OverlapConfig = OverlapConfig()

    # ------------------------------------------------------------------ #
    def size(self, axis: AxisName) -> int:
        d = dict(self.sizes)
        return math.prod(d.get(n, 1) for n in _names(axis))

    @property
    def dp(self) -> int:
        return self.size(self.data)

    @property
    def gx(self) -> int:
        return self.size(self.x)

    @property
    def gy(self) -> int:
        return self.size(self.y)

    @property
    def gz(self) -> int:
        return self.size(self.z)

    @property
    def gseq(self) -> int:
        return self.size(self.seq)

    @property
    def gexpert(self) -> int:
        return self.size(self.expert)

    @property
    def tensor(self) -> int:
        return self.gx * self.gy * self.gz

    @property
    def batch_shards(self) -> int:
        """How many ways the global batch is split (data x z x expert)."""
        return self.dp * self.gz * self.gexpert

    @property
    def token_shards(self) -> int:
        """How many ways the token grid (batch x seq) is split."""
        return self.batch_shards * self.gseq

    def axis(self, logical: str) -> AxisName:
        return {"data": self.data, "x": self.x, "y": self.y, "z": self.z,
                "seq": self.seq, "expert": self.expert}[logical]

    def all_names(self) -> Tuple[str, ...]:
        out: Tuple[str, ...] = ()
        for a in (self.data, self.x, self.y, self.z, self.seq, self.expert):
            out += _names(a)
        return out

    def batch_axes(self) -> Tuple[str, ...]:
        """Mesh axes the batch dim is sharded over (data, z, then expert
        — dense layers see the expert axis as a second data axis; MoE
        layers re-gather its tokens via the dispatch all-to-all)."""
        return _names(self.data) + _names(self.z) + _names(self.expert)

    def token_axes(self) -> Tuple[str, ...]:
        """Mesh axes the token grid is sharded over (batch + seq) — the
        reduction set for per-token sums like the LM loss."""
        return self.batch_axes() + _names(self.seq)

    def swap_xy(self) -> "MeshAxes":
        return dataclasses.replace(self, x=self.y, y=self.x)

    def with_overlap(self, overlap: OverlapConfig) -> "MeshAxes":
        return dataclasses.replace(self, overlap=overlap)

    # -- PartitionSpec helpers ---------------------------------------- #
    def pspec(self, *dims: AxisName) -> P:
        """Build a PartitionSpec from per-dim logical axis names."""
        out = []
        for d in dims:
            n = _names(d)
            if not n:
                out.append(None)
            elif len(n) == 1:
                out.append(n[0])
            else:
                out.append(n)
        return P(*out)


def bind_axes(mesh: Mesh, *, data: AxisName, x: AxisName = None,
              y: AxisName = None, z: AxisName = None,
              seq: AxisName = None, expert: AxisName = None) -> MeshAxes:
    """Bind logical 4D axes to a physical mesh, validating names.

    Tuple axes must list their names in mesh-axis order: the flattened
    ring helpers (:func:`flat_ring_axis`) and ``lax.ppermute``'s group
    numbering (sorted global device ids == mesh order) agree only then —
    out-of-order tuples would silently route ring hops to the wrong
    ranks."""
    sizes = tuple(zip(mesh.axis_names, mesh.devices.shape))
    known = dict(sizes)
    order = {name: i for i, name in enumerate(mesh.axis_names)}
    for a in (data, x, y, z, seq, expert):
        n = _names(a)
        for name in n:
            if name not in known:
                raise ValueError(
                    f"axis {name!r} not in mesh axes {mesh.axis_names}")
        pos = [order[name] for name in n]
        if pos != sorted(pos):
            raise ValueError(
                f"tuple axis {n!r} must list names in mesh-axis order "
                f"{mesh.axis_names} (ring collectives linearize by it)")
    return MeshAxes(data=data, x=x, y=y, z=z, seq=seq, expert=expert,
                    sizes=sizes)


# ---------------------------------------------------------------------- #
# Collective helpers that degrade to identity when the axis is unmapped.
# These are only legal inside shard_map bodies.
# ---------------------------------------------------------------------- #

def psum(v, axis: AxisName):
    n = _names(axis)
    return jax.lax.psum(v, n) if n else v


def pmax(v, axis: AxisName):
    n = _names(axis)
    return jax.lax.pmax(v, n) if n else v


def all_gather(v, axis: AxisName, *, dim: int, tiled: bool = True):
    """Tiled all-gather; tuple axes gather minor name first so the result
    blocks land FIRST-name-major — the order a PartitionSpec tuple shards
    the global dim, and the flattened-ring layout of the ring helpers."""
    n = _names(axis)
    if not n:
        return v
    dim = dim % v.ndim  # lax collectives reject negative dims
    out = v
    for name in reversed(n):
        out = jax.lax.all_gather(out, name, axis=dim, tiled=tiled)
    return out


def psum_scatter(v, axis: AxisName, *, dim: int, tiled: bool = True):
    """Tiled reduce-scatter; tuple axes scatter major name first (the
    exact inverse of :func:`all_gather`'s first-name-major layout)."""
    n = _names(axis)
    if not n:
        return v
    dim = dim % v.ndim  # lax collectives reject negative dims
    out = v
    for name in n:
        out = jax.lax.psum_scatter(out, name, scatter_dimension=dim, tiled=tiled)
    return out


def ring_perm(p: int, shift: int = 1):
    """The send-right ring permutation (rank i -> i + shift mod p).

    Single source of the ring convention shared by the helpers below and
    the fused drivers in core/collective_matmul.py: after ``s`` hops rank
    ``i`` holds the block originally owned by rank ``(i - s) mod p``."""
    return [(i, (i + shift) % p) for i in range(p)]


def flat_ring_axis(axis: AxisName):
    """(p, ppermute axis arg) of the flattened ring over ``axis``.

    Multi-name axes form ONE ring over the FIRST-name-major
    linearization — the order a PartitionSpec tuple shards a dim, and
    (since ``lax.ppermute`` numbers a multi-name group by sorted global
    device id, i.e. by mesh-axis order) the order the permutation indices
    actually route, provided the tuple lists its names in mesh-axis
    order — which every :class:`MeshAxes` binding does. The blocking
    :func:`all_gather` / :func:`psum_scatter` helpers produce the same
    layout, so ring and blocking schedules stay interchangeable."""
    n = _names(axis)
    p = math.prod(_axis_size(name) for name in n)
    return p, (n if len(n) > 1 else n[0])


def flat_ring_index(axis: AxisName):
    """This rank's position on the flattened (first-name-major) ring."""
    return axis_index(axis)


def ppermute_ring(v, axis: AxisName, shift: int = 1):
    """One ring hop: send to (i + shift) mod p along ``axis``.

    Identity on unmapped axes. Multi-name axes hop along the flattened
    ring of :func:`flat_ring_axis`.
    """
    n = _names(axis)
    if not n:
        return v
    p, axn = flat_ring_axis(axis)
    if p == 1:
        return v
    return jax.lax.ppermute(v, axn, ring_perm(p, shift))


def ring_all_gather(v, axis: AxisName, *, dim: int):
    """``all_gather(tiled=True)`` decomposed into p-1 ``ppermute`` ring
    steps (so XLA can overlap each hop with unrelated compute). Bitwise
    the same result ordering as :func:`all_gather` (tuple axes ring once
    over the flattened group instead of once per name — same layout,
    fewer chained rings); identity on unmapped axes."""
    n = _names(axis)
    if not n:
        return v
    p, axn = flat_ring_axis(axis)
    if p == 1:
        return v
    dim = dim % v.ndim
    idx = flat_ring_index(axis)
    perm = ring_perm(p)
    chunk = v.shape[dim]
    out_shape = list(v.shape)
    out_shape[dim] = p * chunk
    out = jnp.zeros(tuple(out_shape), v.dtype)
    cur = v
    for s in range(p):
        with trace.scope("ring_ag", axis, f"hop{s}"):
            # after s hops of the send-right ring, we hold rank
            # (i - s)'s block
            j = (idx - s) % p
            out = jax.lax.dynamic_update_slice_in_dim(out, cur, j * chunk,
                                                      axis=dim)
            if s < p - 1:
                cur = jax.lax.ppermute(cur, axn, perm)
    return out


def ring_reduce_scatter(v, axis: AxisName, *, dim: int):
    """``psum_scatter(tiled=True)`` as a p-1 step ``ppermute`` ring:
    each rank's partial for block j is added just-in-time as the running
    sum passes through. Identity on unmapped axes; tuple axes ring once
    over the flattened group (same block layout as the per-name loop in
    :func:`psum_scatter`)."""
    n = _names(axis)
    if not n:
        return v
    p, axn = flat_ring_axis(axis)
    if p == 1:
        return v
    dim = dim % v.ndim
    if v.shape[dim] % p:
        raise ValueError(  # psum_scatter(tiled=True) rejects this too
            f"ring_reduce_scatter: dim {dim} of size {v.shape[dim]} not "
            f"divisible by axis {n!r} size {p}")
    idx = flat_ring_index(axis)
    perm = ring_perm(p)
    chunk = v.shape[dim] // p
    recv = None
    for s in range(1, p):
        with trace.scope("ring_rs", axis, f"hop{s - 1}"):
            # the partial destined for rank (i - s) leaves here at step s
            j = (idx - s) % p
            g = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=dim)
            part = g if recv is None else recv + g
            recv = jax.lax.ppermute(part, axn, perm)
    with trace.scope("ring_rs", axis, "local"):
        g = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=dim)
        return g if recv is None else recv + g


def ring_all_reduce(v, axis: AxisName, *, dim: int = -1):
    """:func:`psum` decomposed into a reduce-scatter ring phase followed
    by an all-gather ring phase over ``dim`` (the bandwidth-optimal
    all-reduce, spelled as 2(p-1) ``ppermute`` hops so XLA's
    latency-hiding scheduler can interleave them with unrelated compute).

    Fast path p == 2: the send-right "ring" *is* the bidirectional
    exchange — each shard sends its full buffer one hop and adds what it
    receives (bitwise equal to psum: two-term fp addition commutes).
    Identity on unmapped/size-1 axes; falls back to the blocking psum
    when ``dim`` does not split evenly over the ring (the scatter phase
    needs equal blocks). Results match psum within fp32-accumulation
    reassociation; exactly when the addends sum exactly."""
    n = _names(axis)
    if not n:
        return v
    p, axn = flat_ring_axis(axis)
    if p == 1:
        return v
    if p == 2:
        with trace.scope("ring_ar", axis, "exchange"):
            return v + jax.lax.ppermute(v, axn, ring_perm(2))
    dim = dim % v.ndim
    if v.shape[dim] % p:
        return jax.lax.psum(v, n)
    with trace.scope("ring_ar", axis):
        return ring_all_gather(ring_reduce_scatter(v, axis, dim=dim), axis,
                               dim=dim)


def all_to_all(v, axis: AxisName, *, dim: int = 0):
    """Blocking all-to-all over ``axis``: ``dim`` (p equal blocks, block
    k destined for rank k) is exchanged so the result's block k holds
    what rank k sent here — the MoE dispatch/combine primitive
    (layers/moe.py). Identity on unmapped/size-1 axes."""
    n = _names(axis)
    if not n:
        return v
    p, axn = flat_ring_axis(axis)
    if p == 1:
        return v
    dim = dim % v.ndim
    with trace.scope("a2a", axis):
        return jax.lax.all_to_all(v, axn, split_axis=dim, concat_axis=dim,
                                  tiled=True)


def ring_all_to_all(v, axis: AxisName, *, dim: int = 0):
    """:func:`all_to_all` decomposed into p-1 pairwise ``ppermute``
    exchanges (shift s moves each rank's block destined s hops ahead
    directly there), so no all-to-all op reaches the HLO and XLA's
    latency-hiding scheduler can interleave the permutes with unrelated
    compute — the same schedule family as the z/AR rings. Bitwise the
    same block layout as the blocking path (each block travels exactly
    once either way); falls back to the blocking :func:`all_to_all` when
    ``dim`` does not split evenly over the group. Identity on
    unmapped/size-1 axes."""
    n = _names(axis)
    if not n:
        return v
    p, axn = flat_ring_axis(axis)
    if p == 1:
        return v
    dim = dim % v.ndim
    if v.shape[dim] % p:
        return all_to_all(v, axis, dim=dim)   # blocking fallback
    idx = flat_ring_index(axis)
    chunk = v.shape[dim] // p
    own = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=dim)
    out = jnp.zeros_like(v)
    out = jax.lax.dynamic_update_slice_in_dim(out, own, idx * chunk,
                                              axis=dim)
    for s in range(1, p):
        with trace.scope("ring_a2a", axis, f"shift{s}"):
            send = jax.lax.dynamic_slice_in_dim(
                v, ((idx + s) % p) * chunk, chunk, axis=dim)
            recv = jax.lax.ppermute(send, axn, ring_perm(p, s))
            out = jax.lax.dynamic_update_slice_in_dim(
                out, recv, ((idx - s) % p) * chunk, axis=dim)
    return out


def stripe_seq(v, p: int, *, dim: int = 1):
    """Permute a global sequence dim into the striped context-parallel
    layout: contiguous shard r of the result holds global positions
    ``r, r + p, r + 2p, ...`` (``result[r*C + j] == v[j*p + r]`` with
    ``C = T // p``), so a plain ``PartitionSpec`` shard over the seq axis
    lands each rank the causal load-balancing stripe. Identity at p == 1.
    Runs OUTSIDE shard_map, on the global batch."""
    if p <= 1:
        return v
    dim = dim % v.ndim
    t = v.shape[dim]
    if t % p:
        raise ValueError(f"stripe_seq: dim {dim} of size {t} not "
                         f"divisible by g_seq {p}")
    shape = v.shape[:dim] + (t // p, p) + v.shape[dim + 1:]
    return jnp.swapaxes(v.reshape(shape), dim, dim + 1).reshape(v.shape)


def unstripe_seq(v, p: int, *, dim: int = 1):
    """Inverse of :func:`stripe_seq` (``result[j*p + r] == v[r*C + j]``)."""
    if p <= 1:
        return v
    dim = dim % v.ndim
    t = v.shape[dim]
    if t % p:
        raise ValueError(f"unstripe_seq: dim {dim} of size {t} not "
                         f"divisible by g_seq {p}")
    shape = v.shape[:dim] + (p, t // p) + v.shape[dim + 1:]
    return jnp.swapaxes(v.reshape(shape), dim, dim + 1).reshape(v.shape)


def axis_index(axis: AxisName):
    n = _names(axis)
    if not n:
        return jnp.int32(0)
    idx = jnp.int32(0)
    for name in n:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx


def axis_size_in(axes: MeshAxes, axis: AxisName) -> int:
    return axes.size(axis)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
