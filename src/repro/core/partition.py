"""Parameter boxing: arrays + their 4D sharding metadata in one tree.

Layer ``init`` functions return trees whose leaves are :class:`Boxed`
(array + PartitionSpec + flags). ``unbox`` splits that into a pure-array
params tree (what the optimizer and train step see) and a parallel
``specs`` tree used for (a) ``shard_map`` in_specs, (b) deciding which
gradients still need a ``z``-axis reduction (tp_matmul weights are already
reduce-scattered over ``z`` inside their custom_vjp; everything else is
replicated over ``z`` and needs an explicit psum).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class Boxed:
    value: Any                      # jnp.ndarray or ShapeDtypeStruct
    spec: P = P()
    z_reduced: bool = False         # grad already reduced over z (tp weights)
    y_reduce: bool = False          # grad needs a psum over y (duplicated
                                    # KV-head weights: each y rank only
                                    # back-props its own slice)

    # make Boxed an opaque leaf for jax.tree_util
    def __repr__(self):  # pragma: no cover - debugging aid
        shape = getattr(self.value, "shape", None)
        return f"Boxed(shape={shape}, spec={self.spec}, z_reduced={self.z_reduced})"


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    spec: P
    z_reduced: bool
    y_reduce: bool = False


def _is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree) -> Tuple[Any, Any]:
    """Split a Boxed tree into (arrays, specs) with identical structure."""
    arrays = jax.tree.map(lambda b: b.value, tree, is_leaf=_is_boxed)
    specs = jax.tree.map(lambda b: ParamSpec(b.spec, b.z_reduced,
                                             b.y_reduce), tree,
                         is_leaf=_is_boxed)
    return arrays, specs


def spec_tree_to_pspecs(specs) -> Any:
    """ParamSpec tree -> plain PartitionSpec tree (for shard_map in_specs)."""
    return jax.tree.map(lambda s: s.spec,
                        specs, is_leaf=lambda s: isinstance(s, ParamSpec))


def z_reduce_grads(grads, specs, axes, psum_fn):
    """psum grads over z for every param whose grad is not already z-reduced
    (tp_matmul weights come out of their custom_vjp reduce-scattered over
    z; replicated params see different z batch shards), and over y for
    duplicated-KV weights (each y rank back-props only its head slice)."""
    def one(g, s):
        if s.y_reduce:
            g = psum_fn(g, axes.y)
        if s.z_reduced:
            return g
        return psum_fn(g, axes.z)
    return jax.tree.map(one, grads, specs,
                        is_leaf=lambda s: isinstance(s, ParamSpec))


def spec_names(s) -> Tuple[str, ...]:
    """All mesh axis names a ParamSpec/PartitionSpec shards over."""
    spec = s.spec if isinstance(s, ParamSpec) else s
    out = []
    for entry in spec:
        if entry is None:
            continue
        out.extend(entry if isinstance(entry, (tuple, list)) else (entry,))
    return tuple(out)


def expert_reduce_grads(grads, specs, axes, psum_fn):
    """psum grads over the expert axis for every param NOT sharded over
    it. The expert axis is a second data axis for dense layers (each
    expert-rank sees its own batch shard), so replicated params need
    their grads summed like DP; the expert-bank weights are sharded over
    the axis and each rank's grad already holds exactly its own experts'
    contributions — summing them would be wrong, not just wasteful."""
    names = set()
    for n in (axes.expert if isinstance(axes.expert, tuple)
              else (axes.expert,)):
        if n is not None:
            names.add(n)

    def one(g, s):
        if names & set(spec_names(s)):
            return g
        return psum_fn(g, axes.expert)
    return jax.tree.map(one, grads, specs,
                        is_leaf=lambda s: isinstance(s, ParamSpec))
