"""Deterministic, seedable fault injection for chaos-mode runs.

At real scale the mesh changes under you: ranks drop, collectives hang
rather than fail, and storage bit-rots. This module simulates those
events *deterministically* so the recovery machinery in
``launch.train`` (checkpoint-or-restore, ``MeshLifecycle`` rebuild,
online re-shard) can be exercised in CI on a single host.

Chaos specs are compact strings passed via ``--chaos``::

    seed=0;rank_loss@5:n=4,via=online;ckpt_corrupt@3;timeout@7:class=dp_rs_ag,secs=0.2

Grammar: ``;``-separated tokens. ``seed=<int>`` sets the RNG seed;
every other token is ``<kind>@<step>[:k=v,k=v...]``:

``rank_loss@S``
    Before step S, raise :class:`RankLossError` simulating the loss of
    ``n`` devices (default 1). ``via=online`` (default) recovers from
    the in-memory snapshot; ``via=ckpt`` forces the checkpoint-restore
    path first (falling back to the snapshot if the file is corrupt).

``rank_recover@S``
    Before step S, clear all failure marks (the lost devices came back
    or were replaced): the recovery loop snapshots, re-shards, and grows
    ``g_data`` back to the full pool — the elastic *grow* path.

``ckpt_corrupt@S``
    Before step S, corrupt the run's checkpoint file in place:
    ``mode=bitflip`` (default) flips one byte inside a deterministically
    chosen leaf's data; ``mode=truncate`` cuts the file in half. Either
    way the hardened reader must refuse the file with a clear error.

``timeout@S``
    At step S, inflate the measured wall time of one collective-probe
    class (``class=`` one of ``launch.probes.PROBE_CLASSES``; ``secs=``
    the injected stall) so the watchdog classifies the step as a hung
    collective rather than slow compute.

All randomness derives from ``(seed, kind, step)`` so events are
reproducible and order-independent.
"""
from __future__ import annotations

import dataclasses
import os
import zipfile
import zlib
from typing import Dict, List, Optional

import numpy as np

KINDS = ("rank_loss", "rank_recover", "ckpt_corrupt", "timeout")


class RankLossError(RuntimeError):
    """Simulated loss of one or more ranks, raised between steps."""

    def __init__(self, step: int, n_lost: int = 1, via: str = "online"):
        self.step = int(step)
        self.n_lost = int(n_lost)
        self.via = via
        super().__init__(
            f"simulated loss of {n_lost} rank(s) before step {step} "
            f"(recover via={via})")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    kind: str
    step: int
    params: Dict[str, str]

    def get(self, key: str, default: str = "") -> str:
        return self.params.get(key, default)

    def rng(self, seed: int) -> np.random.Generator:
        """Event-local RNG derived from (seed, kind, step)."""
        tag = zlib.crc32(f"{seed}:{self.kind}:{self.step}".encode())
        return np.random.default_rng(tag)


def parse_chaos(spec: str) -> "FaultInjector":
    """Parse a ``--chaos`` spec string into a :class:`FaultInjector`."""
    seed = 0
    events: List[ChaosEvent] = []
    for token in filter(None, (t.strip() for t in spec.split(";"))):
        if token.startswith("seed="):
            seed = int(token.split("=", 1)[1])
            continue
        if "@" not in token:
            raise ValueError(
                f"chaos token {token!r}: expected '<kind>@<step>[:k=v,...]'"
                f" or 'seed=<int>'")
        head, _, tail = token.partition(":")
        kind, _, step_s = head.partition("@")
        if kind not in KINDS:
            raise ValueError(
                f"chaos token {token!r}: unknown kind {kind!r} "
                f"(expected one of {KINDS})")
        params: Dict[str, str] = {}
        for kv in filter(None, tail.split(",")):
            if "=" not in kv:
                raise ValueError(
                    f"chaos token {token!r}: bad param {kv!r}")
            k, v = kv.split("=", 1)
            params[k.strip()] = v.strip()
        events.append(ChaosEvent(kind, int(step_s), params))
    return FaultInjector(events, seed=seed)


class FaultInjector:
    """Schedules :class:`ChaosEvent`s against the training step counter.

    The train loop asks ``events_at(step)`` once per step (events fire
    at most once even if a step index is retried after recovery) and
    the probe layer asks ``probe_delay(step, cls)`` for injected
    collective stalls.
    """

    def __init__(self, events: List[ChaosEvent], *, seed: int = 0):
        self.events = sorted(events, key=lambda e: e.step)
        self.seed = int(seed)
        self.fired: List[ChaosEvent] = []

    def events_at(self, step: int) -> List[ChaosEvent]:
        out = []
        for ev in self.events:
            if ev.step == step and ev not in self.fired:
                self.fired.append(ev)
                out.append(ev)
        return out

    def probe_delay(self, step: int, cls: str) -> float:
        """Injected stall (seconds) for probe class ``cls`` at ``step``.

        Unlike ``events_at`` this is a pure query — timeout events stay
        active for every probe run at their step.
        """
        total = 0.0
        for ev in self.events:
            if ev.kind == "timeout" and ev.step == step \
                    and ev.get("class", "") == cls:
                total += float(ev.get("secs", "0.25"))
        return total

    def step_stall(self, step: int) -> float:
        """Total injected stall for the *training step* at ``step`` (all
        timeout events regardless of class): a hung collective stalls
        the step that issues it, which is what trips the watchdog; the
        per-class ``probe_delay`` then attributes the blame."""
        total = 0.0
        for ev in self.events:
            if ev.kind == "timeout" and ev.step == step:
                total += float(ev.get("secs", "0.25"))
        return total

    def summary(self) -> dict:
        return {"seed": self.seed,
                "events": [dataclasses.asdict(e) for e in self.events],
                "fired": len(self.fired)}


# ---------------------------------------------------------------------- #
# checkpoint corruption
# ---------------------------------------------------------------------- #

def corrupt_checkpoint(path: str, *, seed: int = 0, step: int = 0,
                       mode: str = "bitflip",
                       leaf: Optional[str] = None) -> str:
    """Deterministically damage a checkpoint file in place.

    ``bitflip`` picks a member (``leaf`` names one explicitly; otherwise
    the event RNG chooses) and flips one byte inside its data region, so
    the zip CRC / per-leaf checksum layers must catch it. ``truncate``
    halves the file, so the container itself is unreadable. Returns a
    short description of what was damaged (for the telemetry event
    record).
    """
    if not os.path.exists(path) and os.path.exists(path + ".npz"):
        path = path + ".npz"
    rng = ChaosEvent("ckpt_corrupt", step, {}).rng(seed)
    raw = bytearray(open(path, "rb").read())
    if mode == "truncate":
        open(path, "wb").write(bytes(raw[: len(raw) // 2]))
        return f"truncated {os.path.basename(path)} to {len(raw) // 2} bytes"
    if mode != "bitflip":
        raise ValueError(f"corrupt_checkpoint: unknown mode {mode!r}")
    with zipfile.ZipFile(path) as z:
        infos = [i for i in z.infolist()
                 if i.filename != "__meta__.npy" and i.file_size > 256]
        if leaf is not None:
            infos = [i for i in infos
                     if i.filename == leaf or i.filename == leaf + ".npy"]
        if not infos:
            raise ValueError(f"corrupt_checkpoint: no target member in "
                             f"{path!r} (leaf={leaf!r})")
        info = infos[int(rng.integers(len(infos)))]
    # skip past the local file header + filename + the ~128-byte npy
    # header so the flip lands in array data, then damage one byte
    data_start = info.header_offset + 30 + len(info.filename) + 160
    pos = data_start + int(rng.integers(max(1, info.file_size - 200)))
    raw[pos] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    return f"flipped byte {pos} inside member {info.filename!r}"
