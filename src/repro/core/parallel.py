"""4D tensor-parallel primitives (paper Algorithm 1 + §4.1 + the z axis).

All cross-device communication on the differentiated path goes through
``jax.custom_vjp`` so the backward pass issues *exactly* the paper's
collective schedule (Algorithm 1 lines 6/13 plus the 4D z-axis weight
collectives) — naive autodiff of ``lax.psum`` inside a manual ``shard_map``
would both double-count replicated cotangents and emit redundant
collectives.

Layout invariant (see DESIGN.md):
  * residual stream: features sharded over ``x``, replicated over ``y``,
    batch sharded over ``data x z``.
  * "normal" layer  (paper: non-transposed): W[k/x, n/(y*z)]; forward
    all-reduce over ``x``; output features sharded over ``y``.
  * "transposed" layer (paper §4.1): W[k/y, n/(x*z)]; forward all-reduce
    over ``y``; output features sharded over ``x`` — i.e. back to the
    residual layout with zero layer-boundary communication.

These functions are only valid inside a ``shard_map`` over the bound mesh.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import collective_matmul as CMM
from repro.core import mesh as M
from repro.core import trace
from repro.core.overlap import OverlapConfig  # noqa: F401  (re-export)
from repro.core.partition import Boxed

# The overlap knobs (ring-decomposed z collectives, weight-gather caching)
# ride on ``axes.overlap`` — an OverlapConfig bound via
# ``axes.with_overlap(...)`` (see core/overlap.py and EXPERIMENTS.md
# §Perf). The old module-global CACHE_WEIGHT_GATHER trace-time flag is
# subsumed by ``axes.overlap.cache_weight_gather``.


# ---------------------------------------------------------------------- #
# small helpers
# ---------------------------------------------------------------------- #

def _mm(a, b, out_dtype=None):
    """(..., k) @ (k, n) with fp32 accumulation on the MXU."""
    out = jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)


def _logical(axes: M.MeshAxes, shard: Optional[str]):
    """Map a logical shard tag ('x', 'y', None) to mesh axis names."""
    if shard is None:
        return None
    if shard == "x":
        return axes.x
    if shard == "y":
        return axes.y
    raise ValueError(f"bad shard tag {shard!r}")


def _axes_for(axes: M.MeshAxes, transposed: bool):
    """(contraction axis, output axis) — swapped for transposed layers."""
    return (axes.y, axes.x) if transposed else (axes.x, axes.y)


def _zring(axes: M.MeshAxes, enabled: bool):
    """Mesh axis name(s) for the fused z ring path, or None for blocking.

    Single- and multi-name (tuple) z axes both ring (the drivers flatten
    tuples into one combined ring); unmapped/size-1 z falls back to the
    blocking schedule (which is itself an identity over z there)."""
    if not enabled:
        return None
    n = M._names(axes.z)
    if not n or axes.gz <= 1:
        return None
    return n[0] if len(n) == 1 else n


def _arring(axes: M.MeshAxes, ax):
    """Ring axis name(s) for an activation all-reduce over ``ax`` under
    ``overlap.all_reduce``, or None for the blocking psum."""
    if not axes.overlap.all_reduce:
        return None
    n = M._names(ax)
    if not n or axes.size(ax) <= 1:
        return None
    return n[0] if len(n) == 1 else n


def _ar(v, axes: M.MeshAxes, ax):
    """All-reduce ``v`` over ``ax``: ring-decomposed over the last dim
    when ``overlap.all_reduce`` is on (with ring_all_reduce's own
    fallbacks for p == 1 / non-dividing shapes), else blocking psum."""
    if _arring(axes, ax) is not None:
        return M.ring_all_reduce(v, ax, dim=-1)
    return M.psum(v, ax)


def wspec(axes: M.MeshAxes, in_shard: Optional[str], out_shard: Optional[str]
          ) -> P:
    """PartitionSpec for a tp weight W[k, n]: k over the contraction shard,
    n over (output shard, z)."""
    in_ax = _logical(axes, in_shard)
    out_names = M._names(_logical(axes, out_shard)) + M._names(axes.z)
    return axes.pspec(in_ax, out_names if out_names else None)


def yz_spec(axes: M.MeshAxes, transposed: bool) -> P:
    return wspec(axes, *(('y', 'x') if transposed else ('x', 'y')))


# ---------------------------------------------------------------------- #
# replicated-cotangent all-reduce (Megatron's "g" operator)
# ---------------------------------------------------------------------- #

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ar_bwd_identity(v, axis):
    """Forward all-reduce; backward identity.

    Correct when the consumer treats the output as replicated over ``axis``
    (so the incoming cotangent is itself replicated)."""
    return M.psum(v, axis)


def _arbi_fwd(v, axis):
    return M.psum(v, axis), None


def _arbi_bwd(axis, _, dy):
    return (dy,)


ar_bwd_identity.defvjp(_arbi_fwd, _arbi_bwd)


# ---------------------------------------------------------------------- #
# the 4D tensor-parallel matmul (paper Algorithm 1 + z axis)
# ---------------------------------------------------------------------- #

@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def tp_matmul(x, w, axes: M.MeshAxes, in_shard: Optional[str] = "x",
              out_shard: Optional[str] = "y"):
    """Y = X @ W with the paper's 4D collective schedule.

    x: (..., k_local)  — features sharded over ``in_shard`` (or replicated),
                         replicated over ``out_shard``.
    w: (k_local, n_local/z) — z-sharded storage, rows over ``in_shard``,
                              cols over ``out_shard``.
    returns (..., n_local) sharded over ``out_shard``, replicated over
    ``in_shard``.

    (in_shard='x', out_shard='y') is a paper "normal" layer, ('y', 'x') a
    paper "transposed" layer (§4.1); (x, None)/(None, y)/... cover shared
    projections (MLA latents, MoE routers, modality projectors).

    With ``axes.overlap.matmul`` set, the z-axis weight collectives run as
    ring-decomposed collective matmuls (core/collective_matmul.py): the
    forward AG_z becomes per-chunk GEMMs interleaved with ``ppermute``
    hops, the backward dW reduce-scatter a fused RS-matmul. With
    ``axes.overlap.all_reduce`` the x/y *activation* all-reduces (fwd
    line 6, bwd line 13) additionally decompose into reduce-scatter +
    all-gather rings — fused with the producing GEMM whenever the full
    weight is materialized. The collective *schedule* (what is reduced
    where) is unchanged — only its decomposition, so results match
    within fp32-accum reassociation.
    """
    in_ax = _logical(axes, in_shard)
    ov = axes.overlap
    ring = _zring(axes, ov.matmul)
    ar = _arring(axes, in_ax)
    if ring is None:
        wf = M.all_gather(w, axes.z, dim=1)        # AG_z (4D)
        if ar is not None:                          # fused GEMM + AR ring
            return CMM.ar_matmul(x, wf, ar, chunks=ov.ar_chunks)
        y = _mm(x, wf)                              # local GEMM (line 6)
    else:
        y = CMM.ag_matmul(x, w, ring, chunks=ov.z_chunks)
    return _ar(y, axes, in_ax)                      # All-Reduce_c (line 6)


def _tpmm_fwd(x, w, axes, in_shard, out_shard):
    in_ax = _logical(axes, in_shard)
    ov = axes.overlap
    ring = _zring(axes, ov.matmul)
    ar = _arring(axes, in_ax)
    # paper line 7 caches the *local* partitions; by default we re-gather
    # over z in the backward pass to keep the z-sharded weight footprint
    # (overlap.cache_weight_gather keeps wf and saves one AG_z).
    if ov.cache_weight_gather:
        wf = (M.ring_all_gather(w, axes.z, dim=1) if ring is not None
              else M.all_gather(w, axes.z, dim=1))
        y = (CMM.ar_matmul(x, wf, ar, chunks=ov.ar_chunks)
             if ar is not None else M.psum(_mm(x, wf), in_ax))
        return y, (x, w, wf)
    if ring is None:
        wf = M.all_gather(w, axes.z, dim=1)
        if ar is not None:
            return CMM.ar_matmul(x, wf, ar, chunks=ov.ar_chunks), (x, w, None)
        y = _mm(x, wf)
    else:
        y = CMM.ag_matmul(x, w, ring, chunks=ov.z_chunks)
    return _ar(y, axes, in_ax), (x, w, None)


def _tpmm_bwd(axes, in_shard, out_shard, res, dy):
    x, w, wf = res
    ov = axes.overlap
    ring = _zring(axes, ov.matmul)
    out_ax = _logical(axes, out_shard)
    ar = _arring(axes, out_ax)
    # dX = All-Reduce_r(dY @ W^T)  (line 13); the z re-gather of W fuses
    # into the GEMM as a ring over the contraction segments
    if wf is None and ring is not None:
        dx = CMM.accum_matmul_dx(dy, w, ring,
                                 chunks=ov.z_chunks).astype(x.dtype)
        dx = _ar(dx, axes, out_ax)
    elif ar is not None:
        if wf is None:
            wf = M.all_gather(w, axes.z, dim=1)    # re-gather (AG_z)
        dx = CMM.ar_matmul_t(dy, wf, ar, chunks=ov.ar_chunks)
    else:
        if wf is None:
            wf = M.all_gather(w, axes.z, dim=1)    # re-gather (AG_z)
        dx = jax.lax.dot_general(
            dy, wf, (((dy.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
        dx = M.psum(dx, out_ax)
    # dW = X^T @ dY, reduce-scattered over z (line 14 + 4D)
    k = x.shape[-1]
    n = dy.shape[-1]
    if ring is not None:
        dw = CMM.rs_matmul_dw(x.reshape(-1, k), dy.reshape(-1, n), ring,
                              block_w=w.shape[1], chunks=ov.z_chunks)
    else:
        dw = jax.lax.dot_general(
            x.reshape(-1, k), dy.reshape(-1, n),
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dw = M.psum_scatter(dw, axes.z, dim=1)
    return dx, dw.astype(w.dtype)


tp_matmul.defvjp(_tpmm_fwd, _tpmm_bwd)


def tp_matmul_t(x, w, axes: M.MeshAxes):
    """Paper 'transposed' layer: contract over y, output over x."""
    return tp_matmul(x, w, axes, "y", "x")


# ---------------------------------------------------------------------- #
# batched (per-expert) tp matmul: x (E, ..., k) @ w (E, k, n/z)
# ---------------------------------------------------------------------- #

def _bmm(a, b):
    """(E, m, k) @ (E, k, n) with fp32 accumulation."""
    return jax.lax.dot_general(
        a, b, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).astype(a.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def tp_batched_matmul(x, w, axes: M.MeshAxes, in_shard: Optional[str],
                      out_shard: Optional[str]):
    """Per-expert matmul with the same 4D collective schedule as tp_matmul.

    x: (E_local, C, k_local); w: (E_local, k_local, n_local/z).
    The expert dim E is itself sharded over ``y`` by the caller (MoE), so
    ``in_shard``/``out_shard`` here are 'x' or None.

    ``axes.overlap.batched_matmul`` rings the z collectives exactly as in
    tp_matmul; ``axes.overlap.all_reduce`` rings the activation
    all-reduces."""
    ov = axes.overlap
    in_ax = _logical(axes, in_shard)
    ring = _zring(axes, ov.batched_matmul)
    ar = _arring(axes, in_ax)
    if ring is None:
        wf = M.all_gather(w, axes.z, dim=2)
        if ar is not None:
            return CMM.ar_matmul_batched(x, wf, ar, chunks=ov.ar_chunks)
        y = _bmm(x, wf)
    else:
        y = CMM.ag_matmul_batched(x, w, ring, chunks=ov.z_chunks)
    return _ar(y, axes, in_ax)


def _tpbmm_fwd(x, w, axes, in_shard, out_shard):
    y = tp_batched_matmul.__wrapped__(x, w, axes, in_shard, out_shard)
    return y, (x, w)


def _tpbmm_bwd(axes, in_shard, out_shard, res, dy):
    x, w = res
    ov = axes.overlap
    ring = _zring(axes, ov.batched_matmul)
    out_ax = _logical(axes, out_shard)
    ar = _arring(axes, out_ax)
    if ring is None:
        wf = M.all_gather(w, axes.z, dim=2)
        if ar is not None:
            dx = CMM.ar_matmul_batched_t(dy, wf, ar, chunks=ov.ar_chunks)
        else:
            dx = jax.lax.dot_general(
                dy, wf, (((2,), (2,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)
            dx = M.psum(dx.astype(x.dtype), out_ax)
    else:
        dx = CMM.accum_matmul_dx_batched(dy, w, ring, chunks=ov.z_chunks)
        dx = _ar(dx.astype(x.dtype), axes, out_ax)
    if ring is None:
        dw = jax.lax.dot_general(
            x, dy, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        dw = M.psum_scatter(dw, axes.z, dim=2)
    else:
        dw = CMM.rs_matmul_dw_batched(x, dy, ring, block_w=w.shape[2],
                                      chunks=ov.z_chunks)
    return dx, dw.astype(w.dtype)


tp_batched_matmul.defvjp(_tpbmm_fwd, _tpbmm_bwd)


def tp_expert_init(key, n_experts: int, k: int, n: int,
                   axes: M.MeshAxes, *, in_shard: Optional[str] = "x",
                   out_shard: Optional[str] = None, dtype=jnp.float32,
                   stack: Tuple[int, ...] = (),
                   abstract: bool = False) -> Boxed:
    """Expert weight bank (E, k, n): E over (y, expert) — y-major,
    expert-inner, so the layout reduces to today's y-only placement at
    g_expert = 1 — k over in_shard, n over (out_shard, z)."""
    in_ax = _logical(axes, in_shard)
    out_names = M._names(_logical(axes, out_shard)) + M._names(axes.z)
    e_names = M._names(axes.y) + M._names(axes.expert)
    spec = P(*([None] * len(stack)),
             *axes.pspec(e_names if e_names else None, in_ax,
                         out_names if out_names else None))
    shape = (*stack, n_experts, k, n)
    if abstract:
        return Boxed(jax.ShapeDtypeStruct(shape, dtype), spec, z_reduced=True)
    v = (jax.random.normal(key, shape, jnp.float32) / math.sqrt(k)
         ).astype(dtype)
    return Boxed(v, spec, z_reduced=True)


def tp_linear_init(key, k: int, n: int, axes: M.MeshAxes, *,
                   in_shard: Optional[str] = "x",
                   out_shard: Optional[str] = "y", dtype=jnp.float32,
                   stack: Tuple[int, ...] = (), scale: Optional[float] = None,
                   abstract: bool = False) -> Boxed:
    """Initialize a (stack of) tp weight(s) with its PartitionSpec.

    Raises if n cannot shard over (out_shard x z) — the factor chooser
    (launch/dryrun.choose_factors) probes feasibility via abstract init
    and skips infeasible decompositions."""
    shape = (*stack, k, n)
    out_ax = _logical(axes, out_shard)
    denom = axes.size(out_ax) * axes.size(axes.z)
    if denom and n % denom:
        raise ValueError(f"weight n={n} not divisible by out*z={denom}")
    in_ax = _logical(axes, in_shard)
    if axes.size(in_ax) and k % max(axes.size(in_ax), 1):
        raise ValueError(f"weight k={k} not divisible by in={in_ax}")
    spec = wspec(axes, in_shard, out_shard)
    spec = P(*([None] * len(stack)), *spec)
    if abstract:
        return Boxed(jax.ShapeDtypeStruct(shape, dtype), spec,
                     z_reduced=True)
    s = scale if scale is not None else 1.0 / math.sqrt(k)
    v = (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
    return Boxed(v, spec, z_reduced=True)


def tp_bias_init(n: int, axes: M.MeshAxes, *, out_shard: Optional[str] = "y",
                 dtype=jnp.float32, stack: Tuple[int, ...] = (),
                 abstract: bool = False) -> Boxed:
    spec = P(*([None] * len(stack)), *axes.pspec(_logical(axes, out_shard)))
    shape = (*stack, n)
    if abstract:
        return Boxed(jax.ShapeDtypeStruct(shape, dtype), spec)
    return Boxed(jnp.zeros(shape, dtype), spec)


# ---------------------------------------------------------------------- #
# vocab-parallel embedding (rows over y, cols over (x, z))
# ---------------------------------------------------------------------- #

@partial(jax.custom_vjp, nondiff_argnums=(2,))
def embedding_lookup(tokens, table, axes: M.MeshAxes):
    """tokens (B, S) int32; table (V_local, H_local/z).

    Output: (B, S, H_local) — features sharded over x, replicated over y."""
    out, _ = _emb_fwd(tokens, table, axes)
    return out


def _emb_fwd(tokens, table, axes):
    with trace.scope("embed_gather", axes.z):
        if axes.overlap.embed_gather:
            # ring-decomposed AG_z: same blocks in the same positions
            # (bitwise-identical result), but as a ppermute chain the
            # scheduler can start the lookup on resident shards early
            tf = M.ring_all_gather(table, axes.z, dim=1)
        else:
            tf = M.all_gather(table, axes.z, dim=1)
    v_local = tf.shape[0]
    start = M.axis_index(axes.y) * v_local
    local = tokens - start
    ok = (local >= 0) & (local < v_local)
    emb = jnp.take(tf, jnp.clip(local, 0, v_local - 1), axis=0)
    emb = jnp.where(ok[..., None], emb, 0).astype(tf.dtype)
    emb = ar_bwd_identity(emb, axes.y)   # assemble across vocab shards
    return emb, (tokens, table)


def _emb_bwd(axes, res, demb):
    tokens, table = res
    tf_shape0 = table.shape[0]
    start = M.axis_index(axes.y) * tf_shape0
    local = tokens - start
    ok = (local >= 0) & (local < tf_shape0)
    idx = jnp.where(ok, local, tf_shape0)  # out-of-range rows dropped
    h_full = demb.shape[-1]
    dtab = jnp.zeros((tf_shape0 + 1, h_full), jnp.float32)
    dtab = dtab.at[idx.reshape(-1)].add(
        demb.reshape(-1, h_full).astype(jnp.float32))[:-1]
    dtab = M.psum_scatter(dtab, axes.z, dim=1).astype(table.dtype)
    return None, dtab


embedding_lookup.defvjp(lambda t, tab, axes: _emb_fwd(t, tab, axes),
                        _emb_bwd)


def embedding_init(key, vocab: int, hidden: int, axes: M.MeshAxes, *,
                   dtype=jnp.float32, abstract: bool = False) -> Boxed:
    spec = axes.pspec(axes.y, M._names(axes.x) + M._names(axes.z))
    if abstract:
        return Boxed(jax.ShapeDtypeStruct((vocab, hidden), dtype), spec,
                     z_reduced=True)
    v = (jax.random.normal(key, (vocab, hidden), jnp.float32) * 0.02
         ).astype(dtype)
    return Boxed(v, spec, z_reduced=True)


# ---------------------------------------------------------------------- #
# layout rotation: full (x-replicated) features -> local x shard
# ---------------------------------------------------------------------- #

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def to_x_shard(v, axes: M.MeshAxes):
    """Slice this rank's x-shard from a feature dim that is replicated
    over x (e.g. the output of a tp_matmul with out_shard=None). Backward
    all-gathers the sharded cotangents back to the replicated layout."""
    d_local = v.shape[-1] // max(axes.gx, 1)
    start = M.axis_index(axes.x) * d_local
    return jax.lax.dynamic_slice_in_dim(v, start, d_local, axis=-1)


def _toxs_fwd(v, axes):
    return to_x_shard.__wrapped__(v, axes), None


def _toxs_bwd(axes, _, dy):
    return (M.all_gather(dy, axes.x, dim=dy.ndim - 1),)


to_x_shard.defvjp(_toxs_fwd, _toxs_bwd)


# ---------------------------------------------------------------------- #
# tied-embedding LM head: logits = h @ table^T with the paper schedule
# ---------------------------------------------------------------------- #

@partial(jax.custom_vjp, nondiff_argnums=(2,))
def tied_lm_logits(h, table, axes: M.MeshAxes):
    """h (..., d/x) x-sharded; table (V/y, d/(x z)) — the embedding layout.

    Returns logits (..., V/y) replicated over x (same layout as an untied
    lm_head tp_matmul('x','y'))."""
    out, _ = _tied_fwd(h, table, axes)
    return out


def _tied_fwd(h, table, axes):
    ov = axes.overlap
    ring = _zring(axes, ov.tied_logits)
    ar = _arring(axes, axes.x)
    if ring is None:
        tf = M.all_gather(table, axes.z, dim=1)      # (V/y, d/x)
        if ar is not None:
            # reduced (V) dim indexes the table's rows: fused AR-matmul
            return CMM.ar_matmul_t(h, tf, ar, chunks=ov.ar_chunks), (h, table)
        logits = jax.lax.dot_general(
            h, tf, (((h.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        # the gathered (d) dim is the contraction dim here: ring-
        # accumulate over the z segments of h against the table blocks
        logits = CMM.accum_matmul_tied(h, table, ring,
                                       chunks=ov.z_chunks)
    logits = _ar(logits.astype(h.dtype), axes, axes.x)
    return logits, (h, table)


def _tied_bwd(axes, res, dlogits):
    h, table = res
    ov = axes.overlap
    ring = _zring(axes, ov.tied_logits)
    ar = _arring(axes, axes.y)
    if ring is None:
        tf = M.all_gather(table, axes.z, dim=1)
        if ar is not None:
            dh = CMM.ar_matmul(dlogits, tf, ar, chunks=ov.ar_chunks)
        else:
            dh = jax.lax.dot_general(
                dlogits, tf, (((dlogits.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dh = M.psum(dh.astype(h.dtype), axes.y)
    else:
        dh = CMM.ag_matmul_tied_dh(dlogits, table, ring,
                                   chunks=ov.z_chunks)
        dh = _ar(dh.astype(h.dtype), axes, axes.y)
    v = dlogits.shape[-1]
    d = h.shape[-1]
    if ring is None:
        dt = jax.lax.dot_general(
            dlogits.reshape(-1, v), h.reshape(-1, d),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dt = M.psum_scatter(dt, axes.z, dim=1)
    else:
        dt = CMM.rs_matmul_tied_dt(dlogits.reshape(-1, v),
                                   h.reshape(-1, d), ring,
                                   block_w=table.shape[1],
                                   chunks=ov.z_chunks)
    return dh, dt.astype(table.dtype)


tied_lm_logits.defvjp(lambda h, t, axes: _tied_fwd(h, t, axes), _tied_bwd)


# ---------------------------------------------------------------------- #
# vocab-parallel softmax cross-entropy (fused, hand-written backward)
# ---------------------------------------------------------------------- #

@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def vocab_parallel_xent(logits, labels, axes: M.MeshAxes,
                        valid_vocab: int = 0):
    """logits (..., V_local) sharded over y (replicated over x);
    labels (...) global ids. ``valid_vocab``: true vocab size (padded
    columns beyond it are masked out). Returns per-token loss (...)."""
    loss, _ = _xent_fwd(logits, labels, axes, valid_vocab)
    return loss


def _valid_mask(v_local, start, valid_vocab):
    if not valid_vocab:
        return None
    cols = start + jnp.arange(v_local)
    return cols < valid_vocab


def _xent_stats(logits, labels, axes, valid_vocab):
    lg = logits.astype(jnp.float32)
    v_local_ = lg.shape[-1]
    start_ = M.axis_index(axes.y) * v_local_
    vm = _valid_mask(v_local_, start_, valid_vocab)
    if vm is not None:
        lg = jnp.where(vm, lg, -1e30)
    m = M.pmax(jnp.max(lg, axis=-1), axes.y)
    se = M.psum(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1), axes.y)
    lse = jnp.log(se) + m
    v_local = lg.shape[-1]
    start = M.axis_index(axes.y) * v_local
    local = labels - start
    ok = (local >= 0) & (local < v_local)
    tgt = jnp.take_along_axis(
        lg, jnp.clip(local, 0, v_local - 1)[..., None], axis=-1)[..., 0]
    tgt = M.psum(jnp.where(ok, tgt, 0.0), axes.y)
    return lse, tgt, local, ok, m


def _xent_fwd(logits, labels, axes, valid_vocab):
    lse, tgt, local, ok, _ = _xent_stats(logits, labels, axes, valid_vocab)
    return (lse - tgt), (logits, labels, lse)


def _xent_bwd(axes, valid_vocab, res, dloss):
    logits, labels, lse = res
    lg = logits.astype(jnp.float32)
    v_local_ = lg.shape[-1]
    start_ = M.axis_index(axes.y) * v_local_
    vm = _valid_mask(v_local_, start_, valid_vocab)
    if vm is not None:
        lg = jnp.where(vm, lg, -1e30)
    probs = jnp.exp(lg - lse[..., None])
    v_local = lg.shape[-1]
    start = M.axis_index(axes.y) * v_local
    local = labels - start
    ok = (local >= 0) & (local < v_local)
    onehot = jax.nn.one_hot(jnp.where(ok, local, -1), v_local,
                            dtype=jnp.float32)
    dlogits = (probs - onehot) * dloss[..., None].astype(jnp.float32)
    return dlogits.astype(logits.dtype), None


vocab_parallel_xent.defvjp(
    lambda l, t, axes, vv: _xent_fwd(l, t, axes, vv), _xent_bwd)


# ---------------------------------------------------------------------- #
# feature-sharded RMSNorm / LayerNorm (stats psum'd over x)
# ---------------------------------------------------------------------- #

@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def rms_norm(x, gamma, axes: M.MeshAxes, full_dim: int, eps: float = 1e-6):
    """RMSNorm over a feature dim sharded across ``x``."""
    y, _ = _rms_fwd(x, gamma, axes, full_dim, eps)
    return y


def _rms_fwd(x, gamma, axes, full_dim, eps):
    xf = x.astype(jnp.float32)
    ms = M.psum(jnp.sum(xf * xf, axis=-1), axes.x) / full_dim
    r = jax.lax.rsqrt(ms + eps)
    y = (xf * r[..., None] * gamma.astype(jnp.float32)).astype(x.dtype)
    return y, (x, gamma, r)


def _rms_bwd(axes, full_dim, eps, res, dy):
    x, gamma, r = res
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32) * gamma.astype(jnp.float32)
    xhat = xf * r[..., None]
    # mean over the FULL feature dim -> psum over x
    dot = M.psum(jnp.sum(dyf * xhat, axis=-1), axes.x) / full_dim
    dx = (r[..., None] * (dyf - xhat * dot[..., None])).astype(x.dtype)
    dg = jnp.sum((dy.astype(jnp.float32) * xhat).reshape(-1, x.shape[-1]),
                 axis=0).astype(gamma.dtype)
    return dx, dg


rms_norm.defvjp(lambda x, g, axes, fd, eps: _rms_fwd(x, g, axes, fd, eps),
                _rms_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def layer_norm(x, gamma, beta, axes: M.MeshAxes, full_dim: int,
               eps: float = 1e-5):
    y, _ = _ln_fwd(x, gamma, beta, axes, full_dim, eps)
    return y


def _ln_fwd(x, gamma, beta, axes, full_dim, eps):
    xf = x.astype(jnp.float32)
    mu = M.psum(jnp.sum(xf, axis=-1), axes.x) / full_dim
    xc = xf - mu[..., None]
    var = M.psum(jnp.sum(xc * xc, axis=-1), axes.x) / full_dim
    r = jax.lax.rsqrt(var + eps)
    xhat = xc * r[..., None]
    y = (xhat * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
         ).astype(x.dtype)
    return y, (xhat, gamma, r)


def _ln_bwd(axes, full_dim, eps, res, dy):
    xhat, gamma, r = res
    dyf = dy.astype(jnp.float32) * gamma.astype(jnp.float32)
    mean_dy = M.psum(jnp.sum(dyf, axis=-1), axes.x) / full_dim
    mean_dyx = M.psum(jnp.sum(dyf * xhat, axis=-1), axes.x) / full_dim
    dx = (r[..., None] * (dyf - mean_dy[..., None]
                          - xhat * mean_dyx[..., None])).astype(dy.dtype)
    dg = jnp.sum((dy.astype(jnp.float32) * xhat).reshape(-1, dy.shape[-1]),
                 axis=0).astype(gamma.dtype)
    db = jnp.sum(dy.astype(jnp.float32).reshape(-1, dy.shape[-1]),
                 axis=0).astype(gamma.dtype)
    return dx, dg, db


layer_norm.defvjp(lambda x, g, b, axes, fd, eps: _ln_fwd(x, g, b, axes, fd, eps),
                  _ln_bwd)


def norm_param_init(hidden: int, axes: M.MeshAxes, *, dtype=jnp.float32,
                    value: float = 1.0, stack: Tuple[int, ...] = (),
                    abstract: bool = False) -> Boxed:
    """A per-feature parameter sharded over x (residual layout)."""
    spec = P(*([None] * len(stack)), *axes.pspec(axes.x))
    shape = (*stack, hidden)
    if abstract:
        return Boxed(jax.ShapeDtypeStruct(shape, dtype), spec)
    return Boxed(jnp.full(shape, value, dtype), spec)
