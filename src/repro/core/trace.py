"""Named-scope trace attribution for the overlap schedules.

Every ring schedule in this repo — z weight AG/RS rings, x/y activation
all-reduce rings, DP gradient bucket rings, ZeRO-3 param-shard streams,
the seq-axis KV circulation — lowers to anonymous ``collective-permute``
chains. A profiler trace (or an HLO dump) of a training step therefore
cannot say WHICH schedule a given hop belongs to, which makes the
"collectives hidden under compute" claim unverifiable op by op.

:func:`scope` fixes that: a context manager / decorator that wraps
``jax.named_scope`` (names land in every op's ``metadata op_name``, so
they survive into the optimized HLO and the profiler's HLO-op view) plus
``jax.profiler.TraceAnnotation`` (host-side trace events around the
tracing work itself). Scope names mirror the ``comm_model`` collective
classes so a Perfetto trace maps one-to-one onto the analytic model's
terms:

    ring_ag[z]/hop2          z weight all-gather ring, hop 2
    ring_rs[z]/hop0          z weight-grad reduce-scatter ring
    ring_ar[x]/exchange      x activation all-reduce (p=2 fast path)
    dp_rs/bucket3            DP gradient bucket 3's reduce-scatter
    zero3_ag[data]/leaf7     ZeRO-3 just-in-time gather of leaf 7
    ring_exchange[seq]/hop1  ring-attention KV circulation, hop 1
    embed_gather[z]          embedding-table z gather

**Zero overhead when disabled** (the default): :func:`scope` returns a
shared no-op context manager — no ``named_scope`` is entered, so the
lowered HLO is byte-identical to an uninstrumented build
(tests/test_telemetry.py pins this). Enable with :func:`enable` or
``REPRO_TRACE=1`` in the environment; ``train.py --profile-steps``
enables it so the captured trace window carries attribution.

Caveat: ``jit`` caches do not key on this flag — a function traced while
disabled stays scope-free until retraced. Toggle before the first call
(the CLIs do). The decorator form binds at decoration time for the same
reason; instrumentation sites in this repo all use the ``with`` form.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import Optional, Sequence, Union

AxisLike = Union[None, str, Sequence[str]]

_ENABLED = os.environ.get("REPRO_TRACE", "").strip() not in ("", "0")


def enabled() -> bool:
    return _ENABLED


def enable(on: bool = True) -> None:
    """Turn scope emission on (or back off). Takes effect for functions
    traced AFTER the call — see the jit-cache caveat in the module doc."""
    global _ENABLED
    _ENABLED = bool(on)


def _axis_str(axis: AxisLike) -> str:
    if axis is None:
        return ""
    if isinstance(axis, (tuple, list)):
        return "+".join(str(a) for a in axis)
    return str(axis)


def label(kind: str, axis: AxisLike = None, detail: Optional[str] = None
          ) -> str:
    """``kind[axis]/detail`` — the scope naming convention
    (docs/telemetry.md). ``axis`` may be a mesh axis name or a tuple of
    names (flattened rings render as ``a+b``); both parts optional."""
    name = kind
    s = _axis_str(axis)
    if s:
        name += f"[{s}]"
    if detail:
        name += f"/{detail}"
    return name


class _NullScope:
    """Shared no-op: nothing enters ``named_scope``, so tracing under it
    is bit-for-bit the uninstrumented lowering."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False

    def __call__(self, fn):
        return fn


_NULL = _NullScope()


class _Scope:
    __slots__ = ("name", "_stack")

    def __init__(self, name: str):
        self.name = name
        self._stack = None

    def __enter__(self):
        import jax
        self._stack = contextlib.ExitStack()
        self._stack.enter_context(jax.named_scope(self.name))
        self._stack.enter_context(jax.profiler.TraceAnnotation(self.name))
        return self.name

    def __exit__(self, *exc):
        stack, self._stack = self._stack, None
        return stack.__exit__(*exc)

    def __call__(self, fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with _Scope(self.name):
                return fn(*args, **kwargs)
        return wrapped


def scope(kind: str, axis: AxisLike = None, detail: Optional[str] = None):
    """Context manager / decorator naming everything traced inside it
    ``label(kind, axis, detail)``. A shared no-op when disabled."""
    if not _ENABLED:
        return _NULL
    return _Scope(label(kind, axis, detail))
