"""Hardware calibration: measured α/β/overlap fits for the analytic model.

``comm_model.HardwareParams`` prices every collective as
``steps · α + wire_bytes / link_bw`` and every GEMM as ``flops_needed /
flops`` — but its defaults are *guessed* TPU-v5e constants. This module
closes the loop the ROADMAP kept deferring: time the real primitives on
the live backend, least-squares-fit the constants, and persist them as a
:class:`CalibrationProfile` that ``--calib <path|auto>`` loads back into
:class:`~repro.core.comm_model.HardwareParams` on the dryrun / train /
hillclimb / benchmark CLIs.

What is measured (``benchmarks/calibrate.py`` is the CLI harness):

  * **γ/α/β per axis class** — ring all-gather / reduce-scatter /
    all-reduce (``core.mesh`` ring helpers), the blocking ``psum`` and
    the ``ring_exchange`` KV circulation of ring attention (the seq
    axis' collective class: p−1 ppermute hops of a full per-rank block)
    over each mapped mesh axis AND the flattened tuple ring (two hop
    counts separate the constants), across a message-size sweep. Each
    timing is one sample ``t = γ + steps · α + wire_bytes · β`` with
    the hop counts and bandwidth-optimal wire bytes of
    ``comm_model.collective_time`` (AR = 2(p−1) hops, AG/RS = p−1; γ
    is the per-call launch overhead, LogGP's ``o`` — it dominates on
    CPU backends, α on ring interconnects); :func:`fit_constants`
    solves the stacked system by least squares, so on synthetic data
    generated from the model the fit recovers the constants exactly
    (tests/test_calibrate.py pins this).
  * **GEMM throughput** — achieved matmul FLOP/s over a size sweep
    (the ``flops`` constant; the best size wins, matching how the model
    prices a layer's well-shaped GEMMs).
  * **Overlap probe** — the same ring issued *under* an independent
    matmul vs back-to-back: the hidden fraction is the measured
    ``overlap_efficiency``. Probed separately for an all-gather ring
    (the z-axis weight pattern) and an all-reduce ring (the x/y
    activation pattern); comparing the two answers the z-rings-claim-
    first question (``z_claims_first`` — ``layer_time`` consults it).
  * **Cross-step probe** — a terminal all-gather followed by an
    independent "next-step" matmul, fused vs sequential: the hidden
    fraction calibrates ``cross_step_efficiency``, which scales the
    cross-step window of ``comm_model.dp_sync_time``.

Units: α in seconds per ring hop, γ in seconds per collective call, β
in seconds per wire byte (``link_bw = 1/β`` bytes/s), ``flops`` in
FLOP/s, efficiencies in [0, 1].
An *uncalibrated* run is bitwise unchanged: ``resolve_hw(None)`` returns
the ``TPU_V5E`` defaults and the new ``HardwareParams`` fields default to
the pre-calibration behaviour (``z_claims_first=True``,
``cross_step_efficiency=1.0``).

Profiles persist to ``runs/calib/<backend>.json`` (:meth:`Calibration
Profile.save`); ``resolve("auto")`` finds the live backend's file.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import comm_model as CM

DEFAULT_DIR = os.path.join("runs", "calib")

#: (kind -> (hop count, wire-byte factor)) as functions of ring size p and
#: the *full* buffer bytes, matching comm_model.collective_time's
#: conventions: all_reduce takes the reduced buffer, AG/RS the full one.
_KINDS = ("all_gather", "reduce_scatter", "all_reduce", "psum",
          "ring_exchange", "all_to_all")


def collective_geometry(kind: str, p: int, buf_bytes: float
                        ) -> Tuple[int, float]:
    """(ring hops, wire bytes) of one bandwidth-optimal collective —
    the regressor row of the α/β fit. ``psum`` is priced as the
    all-reduce it is (same wire bytes; the blocking lowering still pays
    per-hop latency on a ring topology). ``ring_exchange`` is the
    seq-axis KV circulation of ring attention (p-1 ppermute hops each
    forwarding the rank's 1/p block of ``buf_bytes``; note
    ``comm_model.collective_time`` takes the per-rank *block* for this
    kind while the harness here times the full buffer)."""
    if p <= 1:
        return 0, 0.0
    if kind in ("all_reduce", "psum"):
        return 2 * (p - 1), 2.0 * (p - 1) / p * buf_bytes
    if kind in ("all_gather", "reduce_scatter", "ring_exchange"):
        return p - 1, (p - 1) / p * buf_bytes
    if kind == "all_to_all":
        # MoE dispatch: every rank keeps its 1/p block and sends the
        # other (p-1)/p of the buffer, one pairwise exchange per hop
        return p - 1, (p - 1) / p * buf_bytes
    raise ValueError(f"unknown collective kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class Sample:
    """One timed collective: the fit's (steps, wire_bytes) -> seconds row."""

    kind: str
    axis: str
    p: int
    elems: int          # buffer elements (comm_model conventions)
    steps: int
    wire_bytes: float
    seconds: float

    def as_dict(self):
        return dataclasses.asdict(self)


def fit_constants(samples: Sequence[Sample]
                  ) -> Tuple[float, float, float, float]:
    """Least-squares (γ, α, β, R²) over
    ``t = γ + steps·α + wire_bytes·β`` (one call per sample).

    γ is the per-collective launch overhead (LogGP's ``o`` — hop-count
    independent), α the per-ring-hop latency, β seconds per wire byte.
    Identifiable when the samples span at least two distinct hop counts
    (AG/RS vs AR at one ring size already do; mixing ring sizes — the
    tuple-axis sweep of :func:`run_calibration` — sharpens it). Exact on
    noiseless synthetic data; negative solutions are clamped to 0 by
    coordinate re-solve — a fit cannot claim negative latency or
    bandwidth time."""
    rows = [s for s in samples if s.steps > 0]
    if len(rows) < 3:
        raise ValueError("need >= 3 samples with p > 1 to fit "
                         "gamma/alpha/beta")
    A = np.array([[1.0, s.steps, s.wire_bytes] for s in rows],
                 dtype=np.float64)
    t = np.array([s.seconds for s in rows], dtype=np.float64)
    sol, *_ = np.linalg.lstsq(A, t, rcond=None)
    if np.any(sol < 0.0):
        # re-solve with the negative coordinates pinned to zero
        keep = [i for i in range(3) if sol[i] > 0.0] or [2]
        sub, *_ = np.linalg.lstsq(A[:, keep], t, rcond=None)
        sol = np.zeros(3)
        for i, v in zip(keep, sub):
            sol[i] = max(float(v), 0.0)
    gamma, alpha, beta = (float(sol[0]), float(sol[1]), float(sol[2]))
    pred = A @ np.array([gamma, alpha, beta])
    ss_res = float(np.sum((t - pred) ** 2))
    ss_tot = float(np.sum((t - np.mean(t)) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    return gamma, alpha, beta, r2


@dataclasses.dataclass(frozen=True)
class AxisFit:
    """Fitted γ/α/β for one mesh-axis class (or a flattened tuple)."""

    axis: str
    p: int
    alpha: float        # seconds per ring hop
    beta: float         # seconds per wire byte (1/bandwidth)
    r2: float
    n_samples: int
    gamma: float = 0.0  # seconds per collective call

    @property
    def link_bw(self) -> float:
        return 1.0 / self.beta if self.beta > 0 else float("inf")

    def as_dict(self):
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Measured hardware constants, persistable and loadable into
    :class:`~repro.core.comm_model.HardwareParams`.

    ``alpha``/``link_bw``/``flops``/``overlap_efficiency`` are the
    aggregate fits the analytic model consumes; ``axis_fits`` keeps the
    per-axis-class α/β so per-axis pricing stays available to readers of
    the JSON (EXPERIMENTS.md §Calibration tabulates them)."""

    backend: str
    n_devices: int
    mesh_shape: Tuple[int, ...]
    alpha: float
    link_bw: float
    flops: float
    overlap_efficiency: float
    gamma: float = 0.0
    z_claims_first: bool = True
    cross_step_efficiency: float = 1.0
    bytes_per_elem: float = 2.0
    fit_r2: float = 0.0
    axis_fits: Tuple[AxisFit, ...] = ()
    probes: Dict[str, float] = dataclasses.field(default_factory=dict)
    samples: Tuple[Sample, ...] = ()
    created: str = ""

    # ------------------------------------------------------------------ #
    def hardware_params(self) -> CM.HardwareParams:
        """The fitted constants in the analytic model's terms."""
        return CM.HardwareParams(
            alpha=self.alpha, gamma=self.gamma, link_bw=self.link_bw,
            flops=self.flops, bytes_per_elem=self.bytes_per_elem,
            overlap_efficiency=self.overlap_efficiency,
            z_claims_first=self.z_claims_first,
            cross_step_efficiency=self.cross_step_efficiency)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["mesh_shape"] = list(self.mesh_shape)
        d["axis_fits"] = [f.as_dict() for f in self.axis_fits]
        d["samples"] = [s.as_dict() for s in self.samples]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationProfile":
        kw = dict(d)
        kw["mesh_shape"] = tuple(kw.get("mesh_shape", ()))
        kw["axis_fits"] = tuple(AxisFit(**f) for f in kw.get("axis_fits", ()))
        kw["samples"] = tuple(Sample(**s) for s in kw.get("samples", ()))
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in kw.items() if k in known})

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationProfile":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def default_path(backend: Optional[str] = None) -> str:
    if backend is None:
        import jax
        backend = jax.default_backend()
    return os.path.join(DEFAULT_DIR, f"{backend}.json")


def resolve(spec: Optional[str]) -> Optional[CalibrationProfile]:
    """``--calib`` semantics: None -> None, 'auto' -> the live backend's
    ``runs/calib/<backend>.json`` if present (None otherwise — an
    uncalibrated run must keep working), else a profile path (must
    exist)."""
    if not spec:
        return None
    if spec == "auto":
        p = default_path()
        return CalibrationProfile.load(p) if os.path.exists(p) else None
    return CalibrationProfile.load(spec)


def resolve_hw(spec: Optional[str]) -> CM.HardwareParams:
    """HardwareParams for a ``--calib`` value; the TPU_V5E guesses when
    uncalibrated (the bitwise-unchanged degenerate point)."""
    prof = resolve(spec)
    return prof.hardware_params() if prof is not None else CM.TPU_V5E


def merge_drift(profile: CalibrationProfile, record: Dict
                ) -> CalibrationProfile:
    """Fold a telemetry drift record (``launch.telemetry.DriftMonitor
    .record()``) into the profile's ``probes``.

    Keyed per workload (``drift:<workload>``) so each (arch, mesh) run
    overwrites its own entry while ``drift_ratio`` tracks the latest
    aggregate. The fitted α/β/γ constants are deliberately NOT rescaled
    here — a drifting end-to-end ratio says the model is wrong for this
    workload, not which constant is wrong; the recorded ratio is the
    evidence a recalibration (benchmarks.calibrate) acts on, and readers
    of the JSON (dryrun/hillclimb) can surface it next to predictions."""
    for field in ("ratio", "predicted_s", "n"):
        if field not in record:
            raise ValueError(f"drift record missing {field!r}: {record}")
    probes = dict(profile.probes)
    key = str(record.get("workload") or "step")
    probes[f"drift:{key}"] = float(record["ratio"])
    probes["drift_ratio"] = float(record["ratio"])
    probes["drift_n"] = float(record["n"])
    return dataclasses.replace(profile, probes=probes)


def merge_probes(profile: CalibrationProfile, records: Sequence[Dict]
                 ) -> CalibrationProfile:
    """Fold a batch of drift records into ``profile.probes`` — the
    per-collective-class verdicts of ``launch.probes.CollectiveProbes``
    (workloads ``collective:<class>``) land as ``drift:collective:<class>``
    keys next to the whole-step ``drift:<workload>`` entries."""
    for rec in records:
        profile = merge_drift(profile, rec)
    return profile


# ---------------------------------------------------------------------- #
# Microbenchmark harness (host-backend timings; needs >= 2 devices)
# ---------------------------------------------------------------------- #

def _timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """min-of-reps wall time of a jitted call (min rejects scheduler
    noise — the fit wants the deterministic α/β floor)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _axis_label(axis) -> str:
    return "+".join(axis) if isinstance(axis, tuple) else axis


def _axis_p(mesh, axis) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    names = axis if isinstance(axis, tuple) else (axis,)
    return int(math.prod(sizes[n] for n in names))


def _collective_fns(mesh, axis):
    """Jitted shard_map wrappers of each timed collective over ``axis``
    (a mesh axis name or a tuple of names — the flattened ring).

    Inputs/outputs follow comm_model's buffer conventions: the argument
    of ``all_gather`` is the 1/p shard of the full buffer, of
    ``reduce_scatter``/``all_reduce``/``psum`` the rank's full-size
    partial."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.core import mesh as M
    from repro.core.compat import shard_map

    def wrap(body, in_spec, out_spec):
        return jax.jit(shard_map(body, mesh=mesh, in_specs=(in_spec,),
                                 out_specs=out_spec, check_vma=False))

    p_ax = math.prod(
        dict(zip(mesh.axis_names, mesh.devices.shape))[n]
        for n in (axis if isinstance(axis, tuple) else (axis,)))

    def ring_exchange(v):
        # the ring-attention KV schedule: each rank's block circulates
        # the whole ring, one ppermute hop at a time, every hop consumed
        # (the sum stands in for the hop's partial-attention read)
        cur, acc = v, v
        for _ in range(p_ax - 1):
            cur = M.ppermute_ring(cur, axis)
            acc = acc + cur
        return acc

    return {
        "all_gather": wrap(lambda v: M.ring_all_gather(v, axis, dim=0),
                           P(axis), P(None)),
        "reduce_scatter": wrap(lambda v: M.ring_reduce_scatter(v, axis,
                                                               dim=0),
                               P(None), P(axis)),
        "all_reduce": wrap(lambda v: M.ring_all_reduce(v, axis, dim=0),
                           P(None), P(None)),
        "psum": wrap(lambda v: M.psum(v, axis), P(None), P(None)),
        "ring_exchange": wrap(ring_exchange, P(axis), P(axis)),
        # all_to_all: each rank holds the full buffer, exchanges the
        # (p-1)/p of it destined elsewhere (pairwise ppermute ring)
        "all_to_all": wrap(lambda v: M.ring_all_to_all(v, axis, dim=0),
                           P(None), P(None)),
    }


def measure_axis(mesh, axis, sizes: Sequence[int], *,
                 dtype=None, reps: int = 5) -> List[Sample]:
    """Time every collective kind over ``axis`` (name or tuple of names)
    across ``sizes`` (buffer elements, comm_model conventions: full
    buffer for AG/RS, reduced buffer for AR/psum)."""
    import jax
    import jax.numpy as jnp

    dtype = dtype or jnp.float32
    p = _axis_p(mesh, axis)
    if p <= 1:
        return []
    fns = _collective_fns(mesh, axis)
    itemsize = jnp.dtype(dtype).itemsize
    # harness floor: a jitted identity pays the Python->runtime dispatch
    # the timing loop itself costs but an *in-program* collective never
    # does — subtract it so γ means per-collective cost, not per-jit-call
    ident = jax.jit(lambda v: v)
    out: List[Sample] = []
    for n in sizes:
        n = int(math.ceil(n / p) * p)  # AG/RS need p | elems
        full = jnp.arange(n, dtype=dtype)
        t0 = _timeit(ident, full, reps=reps)
        shard_arg = {"all_gather": full, "reduce_scatter": full,
                     "all_reduce": full, "psum": full,
                     "ring_exchange": full, "all_to_all": full}
        for kind in _KINDS:
            t = max(_timeit(fns[kind], shard_arg[kind], reps=reps) - t0,
                    0.0)
            steps, wire = collective_geometry(kind, p, n * itemsize)
            out.append(Sample(kind=kind, axis=_axis_label(axis), p=p,
                              elems=n, steps=steps, wire_bytes=wire,
                              seconds=t))
    return out


def measure_gemm(sizes: Sequence[int] = (256, 512, 1024), *,
                 reps: int = 5) -> float:
    """Achieved matmul FLOP/s (best over the size sweep)."""
    import jax
    import jax.numpy as jnp

    best = 0.0
    mm = jax.jit(lambda a, b: a @ b)
    for n in sizes:
        a = jnp.ones((n, n), jnp.float32)
        b = jnp.ones((n, n), jnp.float32)
        t = _timeit(mm, a, b, reps=reps)
        best = max(best, 2.0 * n ** 3 / t)
    return best


def _hidden_fraction(t_comm: float, t_mm: float, t_both: float) -> float:
    """Fraction of the shorter leg the fused program hid: 1.0 means the
    rings rode entirely under the matmul, 0.0 means fully serialized."""
    denom = min(t_comm, t_mm)
    if denom <= 0:
        return 0.0
    return max(0.0, min(1.0, (t_comm + t_mm - t_both) / denom))


def overlap_probe(mesh, axis: str, *, elems: int = 1 << 16,
                  mm_n: int = 512, reps: int = 5) -> Dict[str, float]:
    """Measured comm/compute overlap: ring hops issued alongside an
    *independent* matmul vs back-to-back.

    Probes the z-weight pattern (all-gather ring under a GEMM) and the
    x/y-activation pattern (all-reduce ring under a GEMM) separately:
    their hidden fractions decide ``overlap_efficiency`` (the max — the
    window the scheduler proved it can use) and ``z_claims_first``
    (keep the z-first claim order unless the AR ring demonstrably hides
    better; ``layer_time`` consults the verdict)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import mesh as M
    from repro.core.compat import shard_map

    p = int(dict(zip(mesh.axis_names, mesh.devices.shape))[axis])
    if p <= 1:
        return {}
    elems = int(math.ceil(elems / p) * p)
    v = jnp.arange(elems, dtype=jnp.float32)
    a = jnp.ones((mm_n, mm_n), jnp.float32)

    def probe(ring_body):
        ring = jax.jit(shard_map(ring_body, mesh=mesh, in_specs=(P(None),),
                                 out_specs=P(None), check_vma=False))
        mm = jax.jit(lambda x: x @ x)
        both_body = shard_map(ring_body, mesh=mesh, in_specs=(P(None),),
                              out_specs=P(None), check_vma=False)
        both = jax.jit(lambda x, y: (both_body(x), y @ y))
        t_ring = _timeit(ring, v, reps=reps)
        t_mm = _timeit(mm, a, reps=reps)
        t_both = _timeit(both, v, a, reps=reps)
        return t_ring, t_mm, t_both, _hidden_fraction(t_ring, t_mm, t_both)

    zr, zm, zb, z_hidden = probe(
        lambda x: M.ring_all_gather(
            x.reshape(p, -1)[M.axis_index(axis)], axis, dim=0))
    ar, am, ab, ar_hidden = probe(
        lambda x: M.ring_all_reduce(x, axis, dim=0))
    return {"axis": p, "z_ring_s": zr, "z_mm_s": zm, "z_both_s": zb,
            "z_hidden": z_hidden, "ar_ring_s": ar, "ar_mm_s": am,
            "ar_both_s": ab, "ar_hidden": ar_hidden}


def cross_step_probe(mesh, axis: str, *, elems: int = 1 << 16,
                     mm_n: int = 512, reps: int = 5) -> Dict[str, float]:
    """Measured cross-step window: a step's *terminal* all-gather fused
    with the (independent) next step's first matmul vs run sequentially.
    The hidden fraction calibrates ``cross_step_efficiency`` — how much
    of the terminal collectives ``comm_model.dp_sync_time``'s
    ``cross_step`` window may actually claim."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import mesh as M
    from repro.core.compat import shard_map

    p = int(dict(zip(mesh.axis_names, mesh.devices.shape))[axis])
    if p <= 1:
        return {}
    elems = int(math.ceil(elems / p) * p)
    v = jnp.arange(elems, dtype=jnp.float32)
    a = jnp.ones((mm_n, mm_n), jnp.float32)

    ag_body = shard_map(
        lambda x: M.ring_all_gather(x.reshape(p, -1)[M.axis_index(axis)],
                                    axis, dim=0),
        mesh=mesh, in_specs=(P(None),), out_specs=P(None), check_vma=False)
    ag = jax.jit(ag_body)
    mm = jax.jit(lambda x: x @ x)
    fused = jax.jit(lambda x, y: (ag_body(x), y @ y))
    t_ag = _timeit(ag, v, reps=reps)
    t_mm = _timeit(mm, a, reps=reps)
    t_fused = _timeit(fused, v, a, reps=reps)
    return {"ag_s": t_ag, "next_mm_s": t_mm, "fused_s": t_fused,
            "hidden": _hidden_fraction(t_ag, t_mm, t_fused)}


def run_calibration(mesh=None, *, sizes: Sequence[int] = (1 << 12, 1 << 14,
                                                          1 << 16, 1 << 18),
                    reps: int = 5, quick: bool = False
                    ) -> CalibrationProfile:
    """Time the primitives on the live backend and fit a profile.

    ``mesh`` defaults to a 4D smoke mesh over all host devices (z mapped
    when the device count allows). ``quick`` shrinks the sweep for CI."""
    import jax

    from repro.launch import mesh as LM

    if quick:
        sizes, reps = tuple(sizes[:3]), max(2, reps - 3)
    if mesh is None:
        n = jax.device_count()
        if n < 2:
            raise RuntimeError(
                "calibration needs >= 2 devices (set XLA_FLAGS="
                "--xla_force_host_platform_device_count=8 on CPU)")
        shape = {8: (1, 2, 2, 2), 4: (1, 1, 2, 2), 2: (2, 1, 1, 1)}.get(
            n, (n // 2, 1, 2, 1))
        mesh = LM.make_smoke_mesh(shape, ("data", "x", "y", "z"))

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    mapped = [ax for ax, p in axis_sizes.items() if p > 1]
    sweep_axes: List = list(mapped)
    if len(mapped) >= 2:
        # the flattened tuple ring (p = product) adds a second hop count
        # to the sample set, separating γ (per call) from α (per hop)
        sweep_axes.append(tuple(mapped[:2]))
    samples: List[Sample] = []
    fits: List[AxisFit] = []
    for axis in sweep_axes:
        ax_samples = measure_axis(mesh, axis, sizes, reps=reps)
        samples.extend(ax_samples)
        g, a, b, r2 = fit_constants(ax_samples)
        fits.append(AxisFit(axis=_axis_label(axis), p=_axis_p(mesh, axis),
                            alpha=a, beta=b, r2=r2,
                            n_samples=len(ax_samples), gamma=g))
    gamma, alpha, beta, r2 = fit_constants(samples)
    flops = measure_gemm(reps=reps)

    # probe the widest mapped axis (most ring hops = clearest signal)
    probe_axis = max(mapped, key=lambda ax: axis_sizes[ax])
    ov = overlap_probe(mesh, probe_axis, reps=reps)
    cs = cross_step_probe(mesh, probe_axis, reps=reps)
    overlap_eff = max(ov.get("z_hidden", 0.0), ov.get("ar_hidden", 0.0))
    # keep the z-first prior unless the AR ring hides strictly better by
    # a >10% (absolute) margin — CPU-noise ties must not flip the order
    z_first = ov.get("ar_hidden", 0.0) <= ov.get("z_hidden", 0.0) + 0.10

    probes = {f"overlap_{k}": float(x) for k, x in ov.items()}
    probes.update({f"cross_step_{k}": float(x) for k, x in cs.items()})
    return CalibrationProfile(
        backend=jax.default_backend(),
        n_devices=int(mesh.devices.size),
        mesh_shape=tuple(int(s) for s in mesh.devices.shape),
        alpha=alpha, gamma=gamma,
        link_bw=(1.0 / beta if beta > 0 else CM.TPU_V5E.link_bw),
        flops=flops, overlap_efficiency=overlap_eff,
        z_claims_first=z_first,
        cross_step_efficiency=cs.get("hidden", 1.0),
        fit_r2=r2, axis_fits=tuple(fits), probes=probes,
        samples=tuple(samples),
        created=time.strftime("%Y-%m-%dT%H:%M:%S"))


# ---------------------------------------------------------------------- #
# Model validation: predicted vs measured rank correlation
# ---------------------------------------------------------------------- #

def spearman(xs: Sequence[float], ys: Sequence[float]) -> float:
    """Spearman rank correlation (Pearson on average ranks; no scipy)."""
    def ranks(v):
        order = np.argsort(np.asarray(v, dtype=np.float64))
        r = np.empty(len(v), dtype=np.float64)
        r[order] = np.arange(len(v), dtype=np.float64)
        # average ties so equal times share a rank
        vv = np.asarray(v, dtype=np.float64)
        for u in np.unique(vv):
            m = vv == u
            r[m] = r[m].mean()
        return r
    rx, ry = ranks(xs), ranks(ys)
    sx, sy = rx.std(), ry.std()
    if sx == 0 or sy == 0:
        return 0.0
    return float(np.mean((rx - rx.mean()) * (ry - ry.mean())) / (sx * sy))
