"""JAX version-compat shims.

The repo targets the modern spellings (``jax.shard_map`` with a
``check_vma`` kwarg, ``jax.make_mesh(..., axis_types=...)``); older
installed JAX releases (e.g. 0.4.x) only ship
``jax.experimental.shard_map.shard_map`` with ``check_rep`` and a
``make_mesh`` without ``axis_types``. Every import site goes through this
module so the rest of the codebase can use one spelling.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence

import jax

try:  # jax >= 0.6: public top-level export
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x/0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the modern ``check_vma`` kwarg on any JAX.

    On older releases the same knob is called ``check_rep``; on newer ones
    ``check_rep`` is gone. We translate to whatever the installed version
    accepts (dropping it entirely if neither name exists).
    """
    kw: dict = {}
    if "check_vma" in _SM_PARAMS:
        kw["check_vma"] = check_vma
    elif "check_rep" in _SM_PARAMS:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


_MM_PARAMS = set(inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types: Optional[Sequence[Any]] = None,
              devices=None):
    """``jax.make_mesh`` tolerating the ``axis_types`` kwarg everywhere.

    ``axis_types`` (``jax.sharding.AxisType``) only exists on newer JAX;
    older versions treat every axis the way ``Auto`` does, so dropping the
    argument preserves behaviour.
    """
    if devices is not None and "devices" not in _MM_PARAMS:
        # old JAX: jax.make_mesh has no devices kwarg. A device-subset
        # mesh (MeshLifecycle rebuilding after a simulated rank loss)
        # falls back to the raw Mesh constructor, which also gives the
        # deterministic device order the elastic tests rely on.
        import numpy as np
        arr = np.asarray(devices, dtype=object).reshape(tuple(axis_shapes))
        return jax.sharding.Mesh(arr, tuple(axis_names))
    kw: dict = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and "axis_types" in _MM_PARAMS:
        kw["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(name):
        """Static size of a named mesh axis inside a shard_map body.

        ``jax.lax.axis_size`` only exists on newer JAX; on 0.4.x the
        axis-env lookup spells it ``jax.core.axis_frame(name)`` (which
        returns the size directly)."""
        return jax.core.axis_frame(name)


def default_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where available, else None."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return None
    return (at.Auto,) * n
