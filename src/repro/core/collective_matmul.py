"""Ring-decomposed collective matmuls (overlapped z-axis schedule).

The blocking 4D schedule in :mod:`repro.core.parallel` materializes the
z-gathered weight (``AG_z`` then one big GEMM) and reduce-scatters the
full weight gradient (one big GEMM then ``RS_z``). Both serialize an
expensive collective against an expensive GEMM. Following the decomposed
collective-matmul technique (AxoNN, arXiv:2110.13005; survey
arXiv:2403.07585), the three drivers here re-express those collectives as
``lax.ppermute`` ring steps whose per-chunk GEMMs interleave with the
permutes, so each hop's communication hides under the previous chunk's
compute (XLA's latency-hiding scheduler sees p data-independent
(permute, GEMM) pairs instead of one barrier).

Ring convention (matches core/mesh ring helpers and the TPU RDMA idiom):
send right (rank i -> i+1), so after ``s`` hops rank ``i`` holds the block
originally owned by rank ``(i - s) mod p``.

Three dataflow patterns cover every z collective on the hot path:

  * place      — gathered dim is the GEMM's *output* dim:
                 ``out[..., slot_j] = mm(block_j)``            (AG-matmul)
  * accumulate — gathered dim is the GEMM's *contraction* dim:
                 ``out = sum_j mm(lhs[..., seg_j], block_j)``  (AG-matmul)
  * reduce-scatter — scatter dim is the GEMM's output dim:
                 partial sums ride the ring, each rank's GEMM contribution
                 is added just-in-time                         (RS-matmul)

``chunks > 1`` splits each per-rank block into independent sub-rings for
finer-grained permute/GEMM pairs (OverlapConfig.z_chunks).

All drivers accumulate in fp32 (``preferred_element_type``), so results
match the blocking schedule within fp32-accumulation reassociation only.
Only single-name mesh axes take the fused path (callers fall back to the
blocking schedule for tuple axes); ``p == 1`` degrades to the plain local
GEMM with zero collectives.
"""
from __future__ import annotations

from typing import Callable, List

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import axis_size
from repro.core.mesh import ring_perm as _ring_perm


def effective_chunks(width: int, chunks: int) -> int:
    """Largest c <= chunks dividing width (so odd shards never error)."""
    c = max(1, min(chunks, width))
    while width % c:
        c -= 1
    return c


# ---------------------------------------------------------------------- #
# generic drivers
# ---------------------------------------------------------------------- #

def ring_place(block, name: str, mm: Callable, *, gdim: int,
               chunks: int = 1):
    """``concat_j mm(block_of_rank_j)`` along the output's last dim.

    ``mm(piece) -> (..., piece_out)`` must map a block piece (sliced along
    ``gdim``) to its output chunk; rank j's block lands at slot j, pieces
    in slice order within the slot (identical to the blocking
    gather-then-GEMM layout).
    """
    p = axis_size(name)
    if p == 1:
        return mm(block)
    idx = lax.axis_index(name)
    perm = _ring_perm(p)
    gdim = gdim % block.ndim
    chunks = effective_chunks(block.shape[gdim], chunks)
    m = block.shape[gdim] // chunks
    curs = [lax.slice_in_dim(block, q * m, (q + 1) * m, axis=gdim)
            for q in range(chunks)]
    out = None
    piece_w = 0
    for s in range(p):
        j = (idx - s) % p
        nxt: List = []
        for q, cur in enumerate(curs):
            y = mm(cur)
            if out is None:
                piece_w = y.shape[-1]
                out = jnp.zeros(y.shape[:-1] + (p * chunks * piece_w,),
                                y.dtype)
            out = lax.dynamic_update_slice_in_dim(
                out, y, (j * chunks + q) * piece_w, axis=-1)
            if s < p - 1:
                nxt.append(lax.ppermute(cur, name, perm))
        curs = nxt
    return out


def ring_accumulate(lhs, block, name: str, mm: Callable, *, gdim: int,
                    ldim: int = -1, chunks: int = 1):
    """``sum_j mm(lhs_seg_j, block_of_rank_j)`` — gathered contraction.

    ``lhs``'s ``ldim`` is segmented to match the gathered layout of the
    blocks: rank j's piece q contracts with ``lhs[..., (j*chunks+q)*m :]``.
    ``mm`` must return fp32 (partials are summed across the ring).
    """
    p = axis_size(name)
    if p == 1:
        return mm(lhs, block)
    idx = lax.axis_index(name)
    perm = _ring_perm(p)
    gdim = gdim % block.ndim
    ldim = ldim % lhs.ndim
    chunks = effective_chunks(block.shape[gdim], chunks)
    m = block.shape[gdim] // chunks
    m_l = lhs.shape[ldim] // (p * chunks)
    curs = [lax.slice_in_dim(block, q * m, (q + 1) * m, axis=gdim)
            for q in range(chunks)]
    acc = None
    for s in range(p):
        j = (idx - s) % p
        nxt: List = []
        for q, cur in enumerate(curs):
            seg = lax.dynamic_slice_in_dim(
                lhs, (j * chunks + q) * m_l, m_l, axis=ldim)
            y = mm(seg, cur)
            acc = y if acc is None else acc + y
            if s < p - 1:
                nxt.append(lax.ppermute(cur, name, perm))
        curs = nxt
    return acc


def ring_reduce_scatter_mm(name: str, mm: Callable, *, block_w: int,
                           chunks: int = 1):
    """Fused ``psum_scatter(full_contribution, name, dim=-1)`` where the
    full contribution never materializes.

    ``mm(start, width) -> fp32 (..., width)`` computes this rank's GEMM
    contribution to slice ``[start, start+width)`` of the scatter dim;
    ``block_w`` is the per-rank output block width. The partial destined
    for rank j is computed just-in-time as the running sum passes through
    (p GEMMs, p-1 permutes per sub-ring).
    """
    p = axis_size(name)
    if p == 1:
        return mm(jnp.int32(0), block_w)
    idx = lax.axis_index(name)
    perm = _ring_perm(p)
    chunks = effective_chunks(block_w, chunks)
    m = block_w // chunks
    outs = []
    for q in range(chunks):
        recv = None
        for s in range(1, p):
            j = (idx - s) % p
            g = mm(j * block_w + q * m, m)
            part = g if recv is None else recv + g
            recv = lax.ppermute(part, name, perm)
        g = mm(idx * block_w + q * m, m)
        outs.append(g if recv is None else recv + g)
    return outs[0] if chunks == 1 else jnp.concatenate(outs, axis=-1)


# ---------------------------------------------------------------------- #
# concrete overlapped primitives (called from core/parallel.py)
# ---------------------------------------------------------------------- #

def ag_matmul(x, w, name: str, *, chunks: int = 1):
    """``x @ AG_name(w, dim=1)`` (fwd of tp_matmul), ring-overlapped.

    x (..., k); w (k, n_loc). Returns (..., p*n_loc) in x.dtype."""
    def mm(wb):
        return lax.dot_general(
            x, wb, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
    return ring_place(w, name, mm, gdim=1, chunks=chunks)


def ag_matmul_batched(x, w, name: str, *, chunks: int = 1):
    """Per-expert fwd: x (E, C, k) @ AG_name(w (E, k, n_loc), dim=2)."""
    def mm(wb):
        return lax.dot_general(
            x, wb, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).astype(x.dtype)
    return ring_place(w, name, mm, gdim=2, chunks=chunks)


def accum_matmul_dx(dy, w, name: str, *, chunks: int = 1):
    """``dy @ AG_name(w, dim=1)^T`` (bwd dX of tp_matmul) without
    materializing the gathered weight. Returns fp32 (..., k)."""
    def mm(seg, wb):
        return lax.dot_general(
            seg, wb, (((seg.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_accumulate(dy, w, name, mm, gdim=1, chunks=chunks)


def accum_matmul_dx_batched(dy, w, name: str, *, chunks: int = 1):
    """Per-expert bwd dX: dy (E, C, n_use) x w (E, k, n_loc). fp32."""
    def mm(seg, wb):
        return lax.dot_general(
            seg, wb, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return ring_accumulate(dy, w, name, mm, gdim=2, chunks=chunks)


def rs_matmul_dw(x2d, dy2d, name: str, *, block_w: int, chunks: int = 1):
    """``RS_name(x^T @ dy, dim=1)`` (bwd dW of tp_matmul) fused: each
    rank's (k, block) GEMM slice is computed as the ring partial for that
    block passes through. x2d (T, k); dy2d (T, n_use). Returns fp32
    (k, block_w)."""
    def mm(start, width):
        seg = lax.dynamic_slice_in_dim(dy2d, start, width, axis=1)
        return lax.dot_general(
            x2d, seg, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_reduce_scatter_mm(name, mm, block_w=block_w, chunks=chunks)


def rs_matmul_dw_batched(x, dy, name: str, *, block_w: int,
                         chunks: int = 1):
    """Per-expert bwd dW: RS over dim 2 of x (E,C,k)^T @ dy (E,C,n_use)."""
    def mm(start, width):
        seg = lax.dynamic_slice_in_dim(dy, start, width, axis=2)
        return lax.dot_general(
            x, seg, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return ring_reduce_scatter_mm(name, mm, block_w=block_w, chunks=chunks)


def accum_matmul_tied(h, table, name: str, *, chunks: int = 1):
    """Tied LM head fwd: ``h @ AG_name(table, dim=1)^T`` — the gathered
    dim is the contraction (d) dim. h (..., d/x); table (V/y, d_loc).
    Returns fp32 (..., V/y)."""
    def mm(seg, tb):
        return lax.dot_general(
            seg, tb, (((seg.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_accumulate(h, table, name, mm, gdim=1, chunks=chunks)


def ag_matmul_tied_dh(dlogits, table, name: str, *, chunks: int = 1):
    """Tied LM head bwd dh: ``dlogits @ AG_name(table, dim=1)`` — the
    gathered dim is the *output* (d) dim. Returns (..., d/x) fp32."""
    def mm(tb):
        return lax.dot_general(
            dlogits, tb, (((dlogits.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_place(table, name, mm, gdim=1, chunks=chunks)


def rs_matmul_tied_dt(dl2d, h2d, name: str, *, block_w: int,
                      chunks: int = 1):
    """Tied LM head bwd dtable: ``RS_name(dlogits^T @ h, dim=1)`` fused.
    dl2d (T, V/y); h2d (T, d/x). Returns fp32 (V/y, block_w)."""
    def mm(start, width):
        seg = lax.dynamic_slice_in_dim(h2d, start, width, axis=1)
        return lax.dot_general(
            dl2d, seg, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_reduce_scatter_mm(name, mm, block_w=block_w, chunks=chunks)
