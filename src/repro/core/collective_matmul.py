"""Ring-decomposed collective matmuls (overlapped z-axis schedule).

The blocking 4D schedule in :mod:`repro.core.parallel` materializes the
z-gathered weight (``AG_z`` then one big GEMM) and reduce-scatters the
full weight gradient (one big GEMM then ``RS_z``). Both serialize an
expensive collective against an expensive GEMM. Following the decomposed
collective-matmul technique (AxoNN, arXiv:2110.13005; survey
arXiv:2403.07585), the three drivers here re-express those collectives as
``lax.ppermute`` ring steps whose per-chunk GEMMs interleave with the
permutes, so each hop's communication hides under the previous chunk's
compute (XLA's latency-hiding scheduler sees p data-independent
(permute, GEMM) pairs instead of one barrier).

Ring convention (matches core/mesh ring helpers and the TPU RDMA idiom):
send right (rank i -> i+1), so after ``s`` hops rank ``i`` holds the block
originally owned by rank ``(i - s) mod p``.

Four dataflow patterns cover every x/y/z collective on the hot path:

  * place      — gathered dim is the GEMM's *output* dim:
                 ``out[..., slot_j] = mm(block_j)``            (AG-matmul)
  * accumulate — gathered dim is the GEMM's *contraction* dim:
                 ``out = sum_j mm(lhs[..., seg_j], block_j)``  (AG-matmul)
  * reduce-scatter — scatter dim is the GEMM's output dim:
                 partial sums ride the ring, each rank's GEMM contribution
                 is added just-in-time                         (RS-matmul)
  * all-reduce — the x/y *activation* all-reduce of a tp matmul as a
                 reduce-scatter ring fed per-chunk by the producing GEMM,
                 then an all-gather ring                       (AR-matmul)

``chunks > 1`` splits each per-rank block into independent sub-rings for
finer-grained permute/GEMM pairs (OverlapConfig.z_chunks / ar_chunks).

All drivers accumulate in fp32 (``preferred_element_type``), so results
match the blocking schedule within fp32-accumulation reassociation only.
Tuple (multi-name) mesh axes ring once over the flattened group — the
same FIRST-name-major linearization as a PartitionSpec tuple and
core/mesh's blocking helpers, so layouts stay interchangeable; ``p == 1``
degrades to the plain local GEMM with zero collectives.

Knob units and degeneracy guarantees (DESIGN.md §Overlapped schedule;
pinned by tests/test_overlap.py):

  * ``chunks`` — **sub-rings per per-rank block** (dimensionless;
    ``effective_chunks`` rounds down to the largest divisor of the block
    width, so any value is safe). ``chunks=1`` is one ring whose hops
    already interleave one GEMM each.
  * Every ring driver moves exactly the wire bytes of its blocking
    collective — the rings change *exposure*, never volume
    (``comm_model.layer_volume`` is ring-agnostic for this reason).
  * The forward place-ring is bitwise identical to AG-then-GEMM; the
    accumulate/reduce-scatter/all-reduce rings are bitwise on
    exactly-summable values and within fp32 reassociation otherwise.
  * In the α-β model a ring costs ``(p-1)·α`` (AG/RS) or ``2(p-1)·α``
    (AR) plus bandwidth-optimal bytes; measured α/β replacements come
    from core/calibrate.py.
"""
from __future__ import annotations

from typing import Callable, List, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import trace
from repro.core.mesh import flat_ring_axis, flat_ring_index, \
    ring_all_gather, ring_perm as _ring_perm

AxisRef = Union[str, Tuple[str, ...]]


def effective_chunks(width: int, chunks: int) -> int:
    """Largest c <= chunks dividing width (so odd shards never error)."""
    c = max(1, min(chunks, width))
    while width % c:
        c -= 1
    return c


# ---------------------------------------------------------------------- #
# generic drivers
# ---------------------------------------------------------------------- #

def ring_place(block, name: AxisRef, mm: Callable, *, gdim: int,
               chunks: int = 1):
    """``concat_j mm(block_of_rank_j)`` along the output's last dim.

    ``mm(piece) -> (..., piece_out)`` must map a block piece (sliced along
    ``gdim``) to its output chunk; rank j's block lands at slot j, pieces
    in slice order within the slot (identical to the blocking
    gather-then-GEMM layout).
    """
    p, axn = flat_ring_axis(name)
    if p == 1:
        return mm(block)
    idx = flat_ring_index(name)
    perm = _ring_perm(p)
    gdim = gdim % block.ndim
    chunks = effective_chunks(block.shape[gdim], chunks)
    m = block.shape[gdim] // chunks
    curs = [lax.slice_in_dim(block, q * m, (q + 1) * m, axis=gdim)
            for q in range(chunks)]
    out = None
    piece_w = 0
    for s in range(p):
        with trace.scope("ring_ag", name, f"hop{s}"):
            j = (idx - s) % p
            nxt: List = []
            for q, cur in enumerate(curs):
                with trace.scope("gemm", None, f"chunk{q}"):
                    y = mm(cur)
                if out is None:
                    piece_w = y.shape[-1]
                    out = jnp.zeros(y.shape[:-1] + (p * chunks * piece_w,),
                                    y.dtype)
                out = lax.dynamic_update_slice_in_dim(
                    out, y, (j * chunks + q) * piece_w, axis=-1)
                if s < p - 1:
                    nxt.append(lax.ppermute(cur, axn, perm))
            curs = nxt
    return out


def ring_accumulate(lhs, block, name: AxisRef, mm: Callable, *, gdim: int,
                    ldim: int = -1, chunks: int = 1):
    """``sum_j mm(lhs_seg_j, block_of_rank_j)`` — gathered contraction.

    ``lhs``'s ``ldim`` is segmented to match the gathered layout of the
    blocks: rank j's piece q contracts with ``lhs[..., (j*chunks+q)*m :]``.
    ``mm`` must return fp32 (partials are summed across the ring).
    """
    p, axn = flat_ring_axis(name)
    if p == 1:
        return mm(lhs, block)
    idx = flat_ring_index(name)
    perm = _ring_perm(p)
    gdim = gdim % block.ndim
    ldim = ldim % lhs.ndim
    chunks = effective_chunks(block.shape[gdim], chunks)
    m = block.shape[gdim] // chunks
    m_l = lhs.shape[ldim] // (p * chunks)
    curs = [lax.slice_in_dim(block, q * m, (q + 1) * m, axis=gdim)
            for q in range(chunks)]
    acc = None
    for s in range(p):
        with trace.scope("ring_ag", name, f"hop{s}"):
            j = (idx - s) % p
            nxt: List = []
            for q, cur in enumerate(curs):
                seg = lax.dynamic_slice_in_dim(
                    lhs, (j * chunks + q) * m_l, m_l, axis=ldim)
                with trace.scope("gemm", None, f"chunk{q}"):
                    y = mm(seg, cur)
                acc = y if acc is None else acc + y
                if s < p - 1:
                    nxt.append(lax.ppermute(cur, axn, perm))
            curs = nxt
    return acc


def ring_reduce_scatter_mm(name: AxisRef, mm: Callable, *, block_w: int,
                           chunks: int = 1):
    """Fused ``psum_scatter(full_contribution, name, dim=-1)`` where the
    full contribution never materializes.

    ``mm(start, width) -> fp32 (..., width)`` computes this rank's GEMM
    contribution to slice ``[start, start+width)`` of the scatter dim;
    ``block_w`` is the per-rank output block width. The partial destined
    for rank j is computed just-in-time as the running sum passes through
    (p GEMMs, p-1 permutes per sub-ring).
    """
    p, axn = flat_ring_axis(name)
    if p == 1:
        return mm(jnp.int32(0), block_w)
    idx = flat_ring_index(name)
    perm = _ring_perm(p)
    chunks = effective_chunks(block_w, chunks)
    m = block_w // chunks
    outs = []
    for q in range(chunks):
        recv = None
        for s in range(1, p):
            with trace.scope("ring_rs", name, f"hop{s - 1}"):
                j = (idx - s) % p
                with trace.scope("gemm", None, f"chunk{q}"):
                    g = mm(j * block_w + q * m, m)
                part = g if recv is None else recv + g
                recv = lax.ppermute(part, axn, perm)
        with trace.scope("ring_rs", name, "local"):
            g = mm(idx * block_w + q * m, m)
            outs.append(g if recv is None else recv + g)
    return outs[0] if chunks == 1 else jnp.concatenate(outs, axis=-1)


def ring_all_reduce_mm(name: AxisRef, mm: Callable, *, out_w: int,
                       dtype, chunks: int = 1):
    """Fused ``psum(full_mm_output, name)`` where the output is produced
    chunk by chunk, just in time for its reduce-scatter hop, then rebuilt
    by an all-gather ring (the decomposed activation all-reduce, AxoNN
    arXiv:2110.13005).

    ``mm(start, width) -> fp32 (..., width)`` computes this rank's
    partial for slice ``[start, start+width)`` of the reduced dim;
    ``out_w`` is that dim's full width. Partials are summed in fp32
    across the scatter ring and cast to ``dtype`` before the (pure data
    movement) gather ring, mirroring the blocking GEMM→cast→psum order.
    p == 2 takes the bidirectional-exchange fast path (one full-width
    GEMM + one hop each way; bitwise psum — two-term fp addition
    commutes); rings that do not split ``out_w`` evenly fall back to the
    blocking psum.
    """
    p, axn = flat_ring_axis(name)
    if p == 1:
        return mm(jnp.int32(0), out_w).astype(dtype)
    if p == 2:
        with trace.scope("ring_ar", name, "exchange"):
            y = mm(jnp.int32(0), out_w).astype(dtype)
            return y + lax.ppermute(y, axn, _ring_perm(2))
    if out_w % p:
        return jax.lax.psum(mm(jnp.int32(0), out_w).astype(dtype), name)
    with trace.scope("ring_ar", name):
        scat = ring_reduce_scatter_mm(name, mm, block_w=out_w // p,
                                      chunks=chunks).astype(dtype)
        return ring_all_gather(scat, name, dim=-1)


# ---------------------------------------------------------------------- #
# concrete overlapped primitives (called from core/parallel.py)
# ---------------------------------------------------------------------- #

def ag_matmul(x, w, name: AxisRef, *, chunks: int = 1):
    """``x @ AG_name(w, dim=1)`` (fwd of tp_matmul), ring-overlapped.

    x (..., k); w (k, n_loc). Returns (..., p*n_loc) in x.dtype."""
    def mm(wb):
        return lax.dot_general(
            x, wb, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
    return ring_place(w, name, mm, gdim=1, chunks=chunks)


def ag_matmul_batched(x, w, name: AxisRef, *, chunks: int = 1):
    """Per-expert fwd: x (E, C, k) @ AG_name(w (E, k, n_loc), dim=2)."""
    def mm(wb):
        return lax.dot_general(
            x, wb, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).astype(x.dtype)
    return ring_place(w, name, mm, gdim=2, chunks=chunks)


def accum_matmul_dx(dy, w, name: AxisRef, *, chunks: int = 1):
    """``dy @ AG_name(w, dim=1)^T`` (bwd dX of tp_matmul) without
    materializing the gathered weight. Returns fp32 (..., k)."""
    def mm(seg, wb):
        return lax.dot_general(
            seg, wb, (((seg.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_accumulate(dy, w, name, mm, gdim=1, chunks=chunks)


def accum_matmul_dx_batched(dy, w, name: AxisRef, *, chunks: int = 1):
    """Per-expert bwd dX: dy (E, C, n_use) x w (E, k, n_loc). fp32."""
    def mm(seg, wb):
        return lax.dot_general(
            seg, wb, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return ring_accumulate(dy, w, name, mm, gdim=2, chunks=chunks)


def rs_matmul_dw(x2d, dy2d, name: AxisRef, *, block_w: int, chunks: int = 1):
    """``RS_name(x^T @ dy, dim=1)`` (bwd dW of tp_matmul) fused: each
    rank's (k, block) GEMM slice is computed as the ring partial for that
    block passes through. x2d (T, k); dy2d (T, n_use). Returns fp32
    (k, block_w)."""
    def mm(start, width):
        seg = lax.dynamic_slice_in_dim(dy2d, start, width, axis=1)
        return lax.dot_general(
            x2d, seg, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_reduce_scatter_mm(name, mm, block_w=block_w, chunks=chunks)


def rs_matmul_dw_batched(x, dy, name: AxisRef, *, block_w: int,
                         chunks: int = 1):
    """Per-expert bwd dW: RS over dim 2 of x (E,C,k)^T @ dy (E,C,n_use)."""
    def mm(start, width):
        seg = lax.dynamic_slice_in_dim(dy, start, width, axis=2)
        return lax.dot_general(
            x, seg, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return ring_reduce_scatter_mm(name, mm, block_w=block_w, chunks=chunks)


def accum_matmul_tied(h, table, name: AxisRef, *, chunks: int = 1):
    """Tied LM head fwd: ``h @ AG_name(table, dim=1)^T`` — the gathered
    dim is the contraction (d) dim. h (..., d/x); table (V/y, d_loc).
    Returns fp32 (..., V/y)."""
    def mm(seg, tb):
        return lax.dot_general(
            seg, tb, (((seg.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_accumulate(h, table, name, mm, gdim=1, chunks=chunks)


def ag_matmul_tied_dh(dlogits, table, name: AxisRef, *, chunks: int = 1):
    """Tied LM head bwd dh: ``dlogits @ AG_name(table, dim=1)`` — the
    gathered dim is the *output* (d) dim. Returns (..., d/x) fp32."""
    def mm(tb):
        return lax.dot_general(
            dlogits, tb, (((dlogits.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_place(table, name, mm, gdim=1, chunks=chunks)


def rs_matmul_tied_dt(dl2d, h2d, name: AxisRef, *, block_w: int,
                      chunks: int = 1):
    """Tied LM head bwd dtable: ``RS_name(dlogits^T @ h, dim=1)`` fused.
    dl2d (T, V/y); h2d (T, d/x). Returns fp32 (V/y, block_w)."""
    def mm(start, width):
        seg = lax.dynamic_slice_in_dim(h2d, start, width, axis=1)
        return lax.dot_general(
            dl2d, seg, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_reduce_scatter_mm(name, mm, block_w=block_w, chunks=chunks)


# ---------------------------------------------------------------------- #
# decomposed x/y activation all-reduces (called from core/parallel.py)
# ---------------------------------------------------------------------- #

def ar_matmul(x, w, name: AxisRef, *, chunks: int = 1):
    """``psum_name(x @ w)`` (fwd of tp_matmul / tied-head bwd dh) with the
    activation all-reduce decomposed into a fused RS-matmul ring + AG
    ring: the GEMM produces each output slice just in time for its
    reduce-scatter hop. x (..., c); w (c, n). Returns (..., n), x.dtype."""
    def mm(start, width):
        wseg = lax.dynamic_slice_in_dim(w, start, width, axis=1)
        return lax.dot_general(
            x, wseg, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_all_reduce_mm(name, mm, out_w=w.shape[1], dtype=x.dtype,
                              chunks=chunks)


def ar_matmul_t(x, w, name: AxisRef, *, chunks: int = 1):
    """``psum_name(x @ w^T)`` — transposed rhs: the reduced output dim
    indexes ``w``'s *rows* (bwd dX of tp_matmul against the gathered
    weight; fwd of the tied head against the embedding table).
    x (..., c); w (n, c). Returns (..., n), x.dtype."""
    def mm(start, width):
        wseg = lax.dynamic_slice_in_dim(w, start, width, axis=0)
        return lax.dot_general(
            x, wseg, (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    return ring_all_reduce_mm(name, mm, out_w=w.shape[0], dtype=x.dtype,
                              chunks=chunks)


def ar_matmul_batched(x, w, name: AxisRef, *, chunks: int = 1):
    """Per-expert ``psum_name(x @ w)``: x (E, C, c); w (E, c, n).
    Returns (E, C, n), x.dtype."""
    def mm(start, width):
        wseg = lax.dynamic_slice_in_dim(w, start, width, axis=2)
        return lax.dot_general(
            x, wseg, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return ring_all_reduce_mm(name, mm, out_w=w.shape[2], dtype=x.dtype,
                              chunks=chunks)


def ar_matmul_batched_t(x, w, name: AxisRef, *, chunks: int = 1):
    """Per-expert ``psum_name(x @ w^T)`` (bwd dX of tp_batched_matmul):
    x (E, C, c); w (E, n, c) -- i.e. the gathered weight contracted over
    its last dim. Returns (E, C, n), x.dtype."""
    def mm(start, width):
        wseg = lax.dynamic_slice_in_dim(w, start, width, axis=1)
        return lax.dot_general(
            x, wseg, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return ring_all_reduce_mm(name, mm, out_w=w.shape[1], dtype=x.dtype,
                              chunks=chunks)


# ---------------------------------------------------------------------- #
# expert-axis a2a (called from layers/moe.py)
# ---------------------------------------------------------------------- #

def ring_a2a_expert(buf, name: AxisRef, ffn: Callable):
    """MoE dispatch → expert FFN → combine with both expert-axis
    all-to-alls decomposed into pairwise ``ppermute`` exchanges
    interleaved with the per-source expert GEMMs.

    ``buf`` (p, ...) is the dispatch buffer, dim 0 indexed by destination
    expert-rank; ``ffn(block) -> block`` applies this rank's local expert
    bank to one source rank's token block. Returns ``out`` with
    ``out[j]`` = rank j's experts' output for ``buf[j]`` — the layout the
    blocking ``a2a → ffn → a2a`` round trip produces, block for block.
    Each block crosses the wire exactly once each way (same wire bytes as
    the two blocking all-to-alls: 2·(p-1)/p of the buffer), so the result
    is bitwise identical; the p-1 exchange pairs are mutually
    data-independent, which is what lets XLA's latency-hiding scheduler
    ride shift s+1's permutes under shift s's GEMMs. Lowers to
    collective-permutes only — zero all-to-all HLO ops.
    """
    p, axn = flat_ring_axis(name)
    if buf.shape[0] != p:
        raise ValueError(
            f"dispatch buffer dim 0 ({buf.shape[0]}) must equal the "
            f"expert-axis ring size ({p})")
    if p == 1:
        return ffn(buf[0])[None]
    idx = flat_ring_index(name)
    # shift 0: this rank's own block never crosses the wire
    with trace.scope("ring_a2a", name, "local"):
        own = ffn(lax.dynamic_index_in_dim(buf, idx, axis=0,
                                           keepdims=False))
    out = jnp.zeros(buf.shape, own.dtype)
    out = lax.dynamic_update_index_in_dim(out, own, idx, axis=0)
    for s in range(1, p):
        with trace.scope("ring_a2a", name, f"shift{s}"):
            dst = (idx + s) % p
            send = lax.dynamic_index_in_dim(buf, dst, axis=0,
                                            keepdims=False)
            recv = lax.ppermute(send, axn, _ring_perm(p, s))
            y = ffn(recv)
            back = lax.ppermute(y.astype(out.dtype), axn,
                                _ring_perm(p, p - s))
            out = lax.dynamic_update_index_in_dim(out, back, dst, axis=0)
    return out
