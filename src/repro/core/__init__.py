"""Core of the 4D hybrid tensor+data parallel algorithm (the paper's
primary contribution): mesh axis conventions, the communication model and
decomposition optimizer, the tensor-parallel primitives with the paper's
collective schedule, and the overdecomposition overlap machinery."""
from repro.core import comm_model, gradsync, mesh, overdecompose, \
    parallel, partition

__all__ = ["comm_model", "gradsync", "mesh", "overdecompose", "parallel",
           "partition"]
