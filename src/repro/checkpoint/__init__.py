"""npz pytree checkpointing with sharding metadata."""
from repro.checkpoint.ckpt import CheckpointError, restore, \
    restore_sharded, save, save_sharded, verify
