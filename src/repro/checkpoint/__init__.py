"""npz pytree checkpointing with sharding metadata."""
from repro.checkpoint.ckpt import restore, save
