"""npz pytree checkpointing with sharding metadata."""
from repro.checkpoint.ckpt import restore, restore_sharded, save, \
    save_sharded
