"""Checkpointing: pytree -> .npz with structure + sharding metadata.

No orbax dependency (offline container). Arrays are gathered to host
(fine for the CPU-scale models this runs on; on a real pod you would swap
the io layer for per-host shards — the format already records the
PartitionSpec per leaf so resharding on restore is mechanical).

Robustness (the properties the elastic recovery loop leans on):

* **Atomic writes.** ``save`` serializes to a temp file in the target
  directory, fsyncs, then ``os.replace``s onto the final path — a crash
  mid-save leaves the previous checkpoint intact, never a half-written
  one. A stray ``*.tmp-*`` file is the only possible debris.
* **Per-leaf checksums.** Every leaf's crc32 is recorded in the meta
  block at save time and re-verified on restore, on top of the zip
  container's own member CRCs. Corruption errors are raised as
  :class:`CheckpointError` naming the offending leaf, never a raw
  deserialization traceback.
* **verify()** walks every leaf of a checkpoint without materializing
  the trees, so the recovery loop can vet a file before trusting it.

Checkpoints written by older versions (no ``checksums`` in meta) still
restore; only the extra verification layer is skipped.
"""
from __future__ import annotations

import json
import os
import zipfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint file is truncated, corrupt, or fails verification."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _resolve(path: str) -> str:
    # np.savez appends .npz to bare string paths; mirror that on the read
    # side so save/restore stay symmetric for extensionless callers.
    if not os.path.exists(path) and os.path.exists(path + ".npz"):
        return path + ".npz"
    return path


def _open(path: str):
    path = _resolve(path)
    try:
        data = np.load(path, allow_pickle=False)
        meta = json.loads(str(data["__meta__"]))
    except (zipfile.BadZipFile, ValueError, OSError, EOFError,
            KeyError) as e:
        raise CheckpointError(
            f"checkpoint {path!r} is unreadable (truncated or corrupt "
            f"container): {type(e).__name__}: {e}") from e
    return data, meta


def _read_leaf(data, meta: dict, key: str) -> np.ndarray:
    """Read one member, converting container-level corruption into a
    CheckpointError that names the leaf, and re-checking our own crc."""
    try:
        arr = data[key]
    except KeyError:
        raise KeyError(f"checkpoint missing leaf {key!r}")
    except (zipfile.BadZipFile, zlib.error, ValueError, OSError,
            EOFError) as e:
        raise CheckpointError(
            f"checkpoint leaf {key!r} is corrupt: "
            f"{type(e).__name__}: {e}") from e
    want = meta.get("checksums", {}).get(key)
    if want is not None:
        got = _crc(arr)
        if got != int(want):
            raise CheckpointError(
                f"checkpoint leaf {key!r} failed checksum verification "
                f"(recorded {int(want):#010x}, recomputed {got:#010x})")
    return arr


def save(path: str, params, opt_state=None, *, step: int = 0,
         pspecs=None, extra: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    if not path.endswith(".npz"):
        path = path + ".npz"
    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    meta = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "treedef": str(jax.tree.structure(tree)),
        "specs": ({k: str(v) for k, v in _flatten(
            {"params": pspecs}).items()} if pspecs is not None else {}),
        "extra": extra or {},
        "checksums": {k: _crc(v) for k, v in arrays.items()},
    }
    # temp file in the same directory (os.replace must not cross
    # filesystems), atomic rename onto the final path
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "wb") as fh:
            np.savez(fh, __meta__=json.dumps(meta), **arrays)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def restore(path: str, like, *, root: str = "params") -> Tuple[Any, int]:
    """Restore the subtree saved under ``root`` into the structure of
    ``like`` (a pytree template of arrays or ShapeDtypeStructs)."""
    data, meta = _open(path)
    leaves = []
    for path_, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
        key = root + "/" + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        arr = _read_leaf(data, meta, key)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
    return tree, int(meta["step"])


def verify(path: str) -> dict:
    """Read every leaf of a checkpoint and check all checksums.

    Returns ``{"step": int, "leaves": int, "checksummed": bool}``.
    Raises :class:`CheckpointError` naming the first bad leaf (or the
    container) if anything is truncated or corrupt — the recovery loop
    calls this before trusting a checkpoint for restore.
    """
    data, meta = _open(path)
    keys = meta.get("keys") or [k for k in getattr(data, "files", [])
                                if k != "__meta__"]
    for key in keys:
        _read_leaf(data, meta, key)
    return {"step": int(meta.get("step", 0)), "leaves": len(keys),
            "checksummed": bool(meta.get("checksums"))}


# ---------------------------------------------------------------------- #
# ZeRO-sharded optimizer state (core/gradsync.py)
# ---------------------------------------------------------------------- #
#
# The on-disk format for the data-axis-sharded AdamW state is the
# REPLICATED per-leaf layout (m/v/master with the param's global shape):
# shard boundaries depend on the bucket plan, which depends on G_data, so
# persisting raw shards would pin the checkpoint to one mesh. The same
# rule covers ZeRO-3 param shards: callers unshard the param tree before
# ``save_sharded`` and re-shard after restore. The gather/scatter (and
# zero3 shard/unshard) converters are the jitted shard_map helpers of
# ``launch.steps.make_gradsync_tools`` — built against whatever mesh is
# current on each side, which is exactly what lets a run saved at one
# g_data resume at another. launch.mesh.MeshLifecycle re-shards through
# this same replicated layout in memory (launch.steps.snapshot_state /
# restore_state), so the online elastic path is bitwise-equal to a
# save_sharded/restore_sharded round trip by construction.

def save_sharded(path: str, params, sharded_state, gather_fn, *,
                 step: int = 0, pspecs=None, extra: Optional[dict] = None
                 ) -> None:
    """Save params + a ZeRO-sharded opt state via its ``gather`` tool."""
    full = jax.device_get(gather_fn(sharded_state))
    save(path, params, full, step=step, pspecs=pspecs,
         extra=dict(extra or {}, zero=True))


def restore_sharded(path: str, like_params, like_full_state, scatter_fn
                    ) -> Tuple[Any, Any, int]:
    """Restore (params, sharded opt state, step); ``like_full_state`` is
    a template of the replicated state layout (``optim.adamw.init_state``
    abstract output) and ``scatter_fn`` the restoring mesh's scatter
    tool — its bucket plan may come from a different g_data than the
    saving run's."""
    params, step = restore(path, like_params)
    full, _ = restore(path, like_full_state, root="opt_state")
    return params, scatter_fn(full), step
