"""Checkpointing: pytree -> .npz with structure + sharding metadata.

No orbax dependency (offline container). Arrays are gathered to host
(fine for the CPU-scale models this runs on; on a real pod you would swap
the io layer for per-host shards — the format already records the
PartitionSpec per leaf so resharding on restore is mechanical).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(path: str, params, opt_state=None, *, step: int = 0,
         pspecs=None, extra: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    meta = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "treedef": str(jax.tree.structure(tree)),
        "specs": ({k: str(v) for k, v in _flatten(
            {"params": pspecs}).items()} if pspecs is not None else {}),
        "extra": extra or {},
    }
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def restore(path: str, like, *, root: str = "params") -> Tuple[Any, int]:
    """Restore the subtree saved under ``root`` into the structure of
    ``like`` (a pytree template of arrays or ShapeDtypeStructs)."""
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    leaves = []
    for path_, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
        key = root + "/" + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
    return tree, int(meta["step"])


# ---------------------------------------------------------------------- #
# ZeRO-sharded optimizer state (core/gradsync.py)
# ---------------------------------------------------------------------- #
#
# The on-disk format for the data-axis-sharded AdamW state is the
# REPLICATED per-leaf layout (m/v/master with the param's global shape):
# shard boundaries depend on the bucket plan, which depends on G_data, so
# persisting raw shards would pin the checkpoint to one mesh. The same
# rule covers ZeRO-3 param shards: callers unshard the param tree before
# ``save_sharded`` and re-shard after restore. The gather/scatter (and
# zero3 shard/unshard) converters are the jitted shard_map helpers of
# ``launch.steps.make_gradsync_tools`` — built against whatever mesh is
# current on each side, which is exactly what lets a run saved at one
# g_data resume at another.

def save_sharded(path: str, params, sharded_state, gather_fn, *,
                 step: int = 0, pspecs=None, extra: Optional[dict] = None
                 ) -> None:
    """Save params + a ZeRO-sharded opt state via its ``gather`` tool."""
    full = jax.device_get(gather_fn(sharded_state))
    save(path, params, full, step=step, pspecs=pspecs,
         extra=dict(extra or {}, zero=True))


def restore_sharded(path: str, like_params, like_full_state, scatter_fn
                    ) -> Tuple[Any, Any, int]:
    """Restore (params, sharded opt state, step); ``like_full_state`` is
    a template of the replicated state layout (``optim.adamw.init_state``
    abstract output) and ``scatter_fn`` the restoring mesh's scatter
    tool — its bucket plan may come from a different g_data than the
    saving run's."""
    params, step = restore(path, like_params)
    full, _ = restore(path, like_full_state, root="opt_state")
    return params, scatter_fn(full), step
