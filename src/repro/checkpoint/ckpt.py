"""Checkpointing: pytree -> .npz with structure + sharding metadata.

No orbax dependency (offline container). Arrays are gathered to host
(fine for the CPU-scale models this runs on; on a real pod you would swap
the io layer for per-host shards — the format already records the
PartitionSpec per leaf so resharding on restore is mechanical).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(path: str, params, opt_state=None, *, step: int = 0,
         pspecs=None, extra: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    meta = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "treedef": str(jax.tree.structure(tree)),
        "specs": ({k: str(v) for k, v in _flatten(
            {"params": pspecs}).items()} if pspecs is not None else {}),
        "extra": extra or {},
    }
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree template)."""
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    leaves = []
    for path_, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
        key = "params/" + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
    return tree, int(meta["step"])
