"""Architecture configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`; the
decoder/enc-dec assemblers in this package consume only the config, so new
architectures are pure data. ``reduced()`` produces the small same-family
variant used by the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.comm_model import Constraints, LayerShape


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    z_loss_coef: float = 1e-3
    score_fn: str = "softmax"        # "softmax" | "sigmoid" (dsv3)
    routed_scale: float = 1.0
    first_dense: int = 0             # leading dense layers
    period: int = 1                  # MoE every `period` layers (jamba: 2)


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                 # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class EncoderCfg:
    """Whisper audio encoder / VLM vision-stub settings."""
    n_layers: int = 0                # 0: frontend only (vlm)
    n_ctx: int = 1500                # encoder positions / image tokens
    input_dim: int = 0               # stub embedding dim (0 = d_model)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                   # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu | squared_relu
    gated_mlp: bool = True
    qk_norm: bool = False
    sliding_window: int = 0          # 0 = full attention
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    attn_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    max_seq: int = 131072
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    encoder: Optional[EncoderCfg] = None
    mixer_pattern: Tuple[str, ...] = ()   # per-layer mixer kinds (or period)
    ffn_pattern: Tuple[str, ...] = ()     # explicit per-layer ffn kinds
    mtp_depth: int = 0               # deepseek-v3 multi-token prediction
    source: str = ""                 # citation

    # ------------------------------------------------------------------ #
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 512 multiple so the LM head / embedding shard
        over any (y, z) factorization; the padded columns are masked in
        vocab_parallel_xent."""
        return -(-self.vocab_size // 512) * 512

    def mixers(self) -> Tuple[str, ...]:
        """Per-layer mixer kind, length n_layers."""
        if self.mixer_pattern:
            p = self.mixer_pattern
            if self.n_layers % len(p):
                raise ValueError("mixer_pattern must divide n_layers")
            return tuple(p) * (self.n_layers // len(p))
        if self.xlstm is not None:
            return tuple("slstm" if i % 8 == 7 else "mlstm"
                         for i in range(self.n_layers))
        if self.mla is not None:
            return ("mla",) * self.n_layers
        return ("attn",) * self.n_layers

    def ffns(self) -> Tuple[str, ...]:
        """Per-layer FFN kind ('mlp' | 'moe' | 'none'), length n_layers."""
        if self.ffn_pattern:
            p = self.ffn_pattern
            if self.n_layers % len(p):
                raise ValueError("ffn_pattern must divide n_layers")
            return tuple(p) * (self.n_layers // len(p))
        if self.xlstm is not None:
            return ("none",) * self.n_layers  # xLSTM blocks are self-contained
        out = []
        for i in range(self.n_layers):
            if (self.moe is not None and i >= self.moe.first_dense
                    and (i - self.moe.first_dense) % self.moe.period == 0):
                out.append("moe")
            else:
                out.append("mlp")
        return tuple(out)

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        return tuple(zip(self.mixers(), self.ffns()))

    def scan_period(self) -> int:
        """Smallest repeating period of layer kinds (for stacked scan)."""
        kinds = self.layer_kinds()
        for p in range(1, self.n_layers + 1):
            if self.n_layers % p:
                continue
            if kinds == kinds[:p] * (self.n_layers // p):
                return p
        return self.n_layers

    def with_segment_counts(self, counts: Tuple[int, ...]) -> "ArchConfig":
        """Depth-reduced variant: segment s repeated counts[s] times
        (used by the dry-run's probe lowerings for exact per-depth HLO
        cost extrapolation)."""
        segs = self.segments()
        assert len(counts) == len(segs)
        mix, ffn = [], []
        for (kinds, _), c in zip(segs, counts):
            for _ in range(c):
                for m, f in kinds:
                    mix.append(m)
                    ffn.append(f)
        return dataclasses.replace(
            self, n_layers=len(mix), mixer_pattern=tuple(mix),
            ffn_pattern=tuple(ffn))

    def segments(self) -> Tuple[Tuple[Tuple[Tuple[str, str], ...], int], ...]:
        """Greedy segmentation of layer_kinds() into (period_kinds,
        n_periods) runs, so e.g. DeepSeek-V3's 3-dense prefix + 58 MoE
        body becomes two scanned segments instead of 61 distinct layers."""
        kinds = self.layer_kinds()
        n = len(kinds)
        segs = []
        i = 0
        while i < n:
            best = (1, 1)
            for p in range(1, min(8, n - i) + 1):
                pat = kinds[i:i + p]
                r = 1
                while kinds[i + r * p: i + (r + 1) * p] == pat:
                    r += 1
                if (p * r > best[0] * best[1]
                        or (p * r == best[0] * best[1] and p < best[0])):
                    best = (p, r)
            p, r = best
            segs.append((kinds[i:i + p], r))
            i += p * r
        return tuple(segs)

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Approximate parameter count (for docs / comm-model weighting)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim_
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.arch_type == "audio" and self.encoder:
            # encoder stack: self-attn (4 d^2) + mlp (2 d d_ff)
            total += self.encoder.n_layers * (4 * d * d + 2 * d * self.d_ff)
            # decoder cross-attention adds q,k,v,o per layer
            total += self.n_layers * 4 * d * d
        for mixer, ffn in self.layer_kinds():
            if mixer == "attn":
                total += d * (self.n_heads + 2 * self.n_kv_heads) * hd
                total += self.n_heads * hd * d
            elif mixer == "mla":
                m = self.mla
                qk = m.qk_nope_dim + m.qk_rope_dim
                total += d * (m.q_lora_rank or 0)
                total += (m.q_lora_rank or d) * self.n_heads * qk
                total += d * (m.kv_lora_rank + m.qk_rope_dim)
                total += m.kv_lora_rank * self.n_heads * (
                    m.qk_nope_dim + m.v_dim)
                total += self.n_heads * m.v_dim * d
            elif mixer == "mamba":
                di = self.mamba.expand * d
                dtr = self.mamba.dt_rank or -(-d // 16)
                total += d * 2 * di + di * (dtr + 2 * self.mamba.d_state)
                total += dtr * di + di * d
            elif mixer == "mlstm":
                di = int(self.xlstm.proj_factor_mlstm * d)
                total += d * 2 * di + 3 * di * (di // self.n_heads) + di * d
            elif mixer == "slstm":
                dff = -(-int(self.xlstm.proj_factor_slstm * d) // 64) * 64
                total += 4 * d * d + 4 * d * (d // self.n_heads)
                total += d * d + 2 * d * dff + dff * d
            if ffn == "mlp":
                mult = 2 if self.gated_mlp else 1
                total += (mult + 1) * d * self.d_ff
            elif ffn == "moe":
                mc = self.moe
                mult = 2 if self.gated_mlp else 1
                per = (mult + 1) * d * mc.d_expert
                total += mc.n_experts * per + mc.n_shared * per + d * mc.n_experts
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        mc = self.moe
        mult = 2 if self.gated_mlp else 1
        per = (mult + 1) * self.d_model * mc.d_expert
        n_moe = sum(1 for f in self.ffns() if f == "moe")
        inactive = n_moe * (mc.n_experts - mc.top_k) * per
        return self.param_count() - inactive

    # ------------------------------------------------------------------ #
    def comm_layers(self) -> Tuple[LayerShape, ...]:
        """LayerShapes for the communication model (paper §5)."""
        d = self.d_model
        hd = self.head_dim_
        out = []
        for mixer, ffn in self.layer_kinds():
            if mixer in ("attn", "mla"):
                nq = self.n_heads * hd
                if mixer == "mla":
                    m = self.mla
                    nq = self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    out.append(LayerShape(d, nq))
                    out.append(LayerShape(self.n_heads * m.v_dim, d,
                                          transposed=True))
                else:
                    # the QKV projection's kv_ring_width prices the
                    # context-parallel KV circulation (2*kv_heads*hd
                    # elements per token forwarded per ring hop)
                    out.append(LayerShape(
                        d, (self.n_heads + 2 * self.n_kv_heads) * hd,
                        kv_ring_width=2 * self.n_kv_heads * hd))
                    out.append(LayerShape(self.n_heads * hd, d,
                                          transposed=True))
            elif mixer == "mamba":
                di = self.mamba.expand * d
                out.append(LayerShape(d, 2 * di))
                out.append(LayerShape(di, d, transposed=True))
            elif mixer == "mlstm":
                di = int(self.xlstm.proj_factor_mlstm * d)
                out.append(LayerShape(d, 2 * di))
                out.append(LayerShape(di, d, transposed=True))
            elif mixer == "slstm":
                dff = -(-int(self.xlstm.proj_factor_slstm * d) // 64) * 64
                out.append(LayerShape(d, 4 * d))
                out.append(LayerShape(d, d, transposed=True))
                out.append(LayerShape(d, 2 * dff))
                out.append(LayerShape(dff, d, transposed=True))
            if ffn == "mlp":
                mult = 2 if self.gated_mlp else 1
                out.append(LayerShape(d, mult * self.d_ff))
                out.append(LayerShape(self.d_ff, d, transposed=True))
            elif ffn == "moe":
                mc = self.moe
                mult = 2 if self.gated_mlp else 1
                # per-token activated expert width (+ shared)
                fa = mc.top_k * mc.d_expert + mc.n_shared * mc.d_expert
                # expert=True: the routed bank shards over g_expert (no
                # expert-axis grad allreduce); a2a_width on the up-proj
                # prices the dispatch+combine all-to-all once per MoE
                # block (capacity slots x d elements per token)
                out.append(LayerShape(
                    d, mult * fa, expert=True,
                    a2a_width=mc.capacity_factor * mc.top_k * d))
                out.append(LayerShape(fa, d, transposed=True, expert=True))
        return tuple(out)

    def tp_constraints(self, global_batch: int) -> Constraints:
        divs = [self.d_model, self.d_ff or self.d_model]
        # kv heads may be *duplicated* over y (kv_layout), so y is only
        # constrained by q heads (+ experts); duplication beyond kv heads
        # wastes KV-cache memory, so the optimizer still prefers small y.
        y_divs = [self.n_heads]
        if self.moe:
            y_divs.append(self.moe.n_experts)
        if self.xlstm:
            y_divs = [self.n_heads]
        return Constraints(global_batch=global_batch,
                           x_divides=tuple(divs),
                           y_divides=tuple(y_divs))

    def axes_ok(self, axes) -> Optional[str]:
        if self.d_model % axes.gx:
            return f"d_model {self.d_model} % gx {axes.gx}"
        if self.n_heads % axes.gy:
            return f"heads {self.n_heads} % gy {axes.gy}"
        if (self.mla is None and self.n_kv_heads % axes.gy
                and axes.gy % self.n_kv_heads):
            return f"kv heads {self.n_kv_heads} vs gy {axes.gy}"
        if self.moe and self.moe.n_experts % axes.gy:
            return f"experts {self.moe.n_experts} % gy {axes.gy}"
        if axes.gseq > 1:
            # context parallelism needs softmax attention everywhere:
            # recurrent mixers (mamba/xlstm) and MLA's materialized path
            # mix across the full sequence on-device and would silently
            # truncate to the local shard
            if set(self.mixers()) != {"attn"}:
                return (f"seq axis (g_seq={axes.gseq}) needs all-attention "
                        f"mixers, got {sorted(set(self.mixers()))}")
            if self.arch_type in ("vlm", "audio"):
                return (f"seq axis unsupported for arch_type "
                        f"{self.arch_type} (contiguous-prefix inputs)")
            if self.max_seq % axes.gseq:
                return f"max_seq {self.max_seq} % g_seq {axes.gseq}"
        if axes.gexpert > 1:
            if self.moe is None:
                return (f"expert axis (g_expert={axes.gexpert}) needs an "
                        f"MoE architecture")
            if self.moe.n_experts % (axes.gy * axes.gexpert):
                return (f"experts {self.moe.n_experts} % gy*g_expert "
                        f"{axes.gy * axes.gexpert}")
        return None

    def validate_axes(self, axes) -> None:
        err = self.axes_ok(axes)
        assert err is None, f"{self.name}: {err}"

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """2-layer, d_model<=512, <=4-expert variant for CPU smoke tests."""
        n_layers = max(2, self.scan_period())
        if n_layers > 8:
            n_layers = 2
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        kw = dict(
            name=self.name + "-smoke", n_layers=n_layers, d_model=d,
            n_heads=heads, n_kv_heads=kv, head_dim=d // heads,
            d_ff=(min(self.d_ff, 512) if self.d_ff else 0),
            vocab_size=min(self.vocab_size, 512), max_seq=512,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_expert=128, n_shared=min(self.moe.n_shared, 1),
                first_dense=min(self.moe.first_dense, 1)
                if n_layers > 1 else 0)
        if self.mla:
            kw["mla"] = MLACfg(kv_lora_rank=64, q_lora_rank=(
                32 if self.mla.q_lora_rank else 0), qk_nope_dim=32,
                qk_rope_dim=16, v_dim=32)
            kw["head_dim"] = 0
        if self.encoder:
            kw["encoder"] = dataclasses.replace(
                self.encoder, n_layers=min(self.encoder.n_layers, 2),
                n_ctx=16 if self.arch_type == "vlm" else 64,
                input_dim=min(self.encoder.input_dim, 96)
                if self.encoder.input_dim else 0)
        if self.xlstm is not None:
            # one of each cell kind; the full 7:1 period would blow the
            # 1-core CPU collective-rendezvous budget in smoke tests
            kw["mixer_pattern"] = ("mlstm", "slstm")
            kw["n_layers"] = 2
        elif self.mixer_pattern:
            kw["mixer_pattern"] = tuple(
                m for m, _ in self.layer_kinds())[:n_layers]
        if self.sliding_window:
            kw["sliding_window"] = 64
        return dataclasses.replace(self, **kw)
