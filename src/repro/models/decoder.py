"""Generic decoder-only LM assembled from 4D-parallel layers.

Layers are executed with ``lax.scan`` over the architecture's repeating
period (params stacked over periods) so HLO size / compile time stays flat
in depth — 61-layer DeepSeek-V3 compiles the same program as a 2-layer
smoke model. Heterogeneous patterns (jamba's mamba/attn interleave, MoE
every-other-layer, xLSTM's 7:1) unroll the period *inside* the scan body.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.partition import Boxed
from repro.layers import attention as A
from repro.layers import mamba as MB
from repro.layers import mlp as FF
from repro.layers import moe as MOE
from repro.layers import xlstm as XL
from repro.models.base import ArchConfig


# ---------------------------------------------------------------------- #
# init
# ---------------------------------------------------------------------- #

def _norm_init(cfg, axes, dtype, stack, abstract):
    if cfg.norm == "layernorm":
        return {"g": PP.norm_param_init(cfg.d_model, axes, dtype=dtype,
                                        stack=stack, abstract=abstract),
                "b": PP.norm_param_init(cfg.d_model, axes, dtype=dtype,
                                        value=0.0, stack=stack,
                                        abstract=abstract)}
    return {"g": PP.norm_param_init(cfg.d_model, axes, dtype=dtype,
                                    stack=stack, abstract=abstract)}


def _apply_norm(p, h, cfg, axes):
    if cfg.norm == "layernorm":
        return PP.layer_norm(h, p["g"], p["b"], axes, cfg.d_model)
    return PP.rms_norm(h, p["g"], axes, cfg.d_model)


def _mixer_init(kind, key, cfg, axes, dtype, stack, abstract):
    if kind == "attn":
        return A.attn_init(key, cfg, axes, dtype=dtype, stack=stack,
                           abstract=abstract)
    if kind == "mla":
        return A.mla_init(key, cfg, axes, dtype=dtype, stack=stack,
                          abstract=abstract)
    if kind == "mamba":
        return MB.mamba_init(key, cfg, axes, dtype=dtype, stack=stack,
                             abstract=abstract)
    if kind == "mlstm":
        return XL.mlstm_init(key, cfg, axes, dtype=dtype, stack=stack,
                             abstract=abstract)
    if kind == "slstm":
        return XL.slstm_init(key, cfg, axes, dtype=dtype, stack=stack,
                             abstract=abstract)
    raise ValueError(kind)


def _ffn_init(kind, key, cfg, axes, dtype, stack, abstract):
    if kind == "mlp":
        return FF.mlp_init(key, cfg.d_model, cfg.d_ff, cfg.act, axes,
                           gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                           dtype=dtype, stack=stack, abstract=abstract)
    if kind == "moe":
        return MOE.moe_init(key, cfg, axes, dtype=dtype, stack=stack,
                            abstract=abstract)
    return None


def decoder_init(key, cfg: ArchConfig, axes: M.MeshAxes, *,
                 dtype=jnp.bfloat16, abstract: bool = False
                 ) -> Dict[str, Any]:
    cfg.validate_axes(axes)
    segs = cfg.segments()
    keys = jax.random.split(key, 4 + 2 * sum(len(k) for k, _ in segs))
    ki = 4

    segments = {}
    for s, (kinds, n_periods) in enumerate(segs):
        stack = (n_periods,)
        blocks = {}
        for i, (mixer, ffn) in enumerate(kinds):
            blk = {"norm1": _norm_init(cfg, axes, dtype, stack, abstract),
                   "mixer": _mixer_init(mixer, keys[ki], cfg, axes,
                                        dtype, stack, abstract)}
            ki += 1
            if ffn != "none":
                blk["norm2"] = _norm_init(cfg, axes, dtype, stack, abstract)
                blk["ffn"] = _ffn_init(ffn, keys[ki], cfg, axes, dtype,
                                       stack, abstract)
            ki += 1
            blocks[f"pos{i}"] = blk
        segments[f"seg{s}"] = blocks

    params = {
        "embed": PP.embedding_init(keys[0], cfg.padded_vocab, cfg.d_model,
                                   axes, dtype=dtype, abstract=abstract),
        "segments": segments,
        "final_norm": _norm_init(cfg, axes, dtype, (), abstract),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = PP.tp_linear_init(
            keys[1], cfg.d_model, cfg.padded_vocab, axes, dtype=dtype,
            scale=0.02, abstract=abstract)
    if cfg.mtp_depth > 0:
        # DeepSeek-V3 multi-token prediction (depth 1): combine the main
        # stream with the next token's embedding, run one extra block,
        # predict t+2 through the shared head.
        mkeys = jax.random.split(keys[2], 3)
        params["mtp"] = {
            "norm_h": _norm_init(cfg, axes, dtype, (), abstract),
            "norm_e": _norm_init(cfg, axes, dtype, (), abstract),
            # combine h and emb(next) -> d as a normal+transposed tp pair
            # (the paper-layout-clean equivalent of DSv3's concat linear)
            "w_comb_h": PP.tp_linear_init(mkeys[0], cfg.d_model,
                                          cfg.d_model, axes, dtype=dtype,
                                          abstract=abstract),
            "w_comb_e": PP.tp_linear_init(
                jax.random.fold_in(mkeys[0], 1), cfg.d_model, cfg.d_model,
                axes, dtype=dtype, abstract=abstract),
            "w_comb_o": PP.tp_linear_init(
                jax.random.fold_in(mkeys[0], 2), cfg.d_model, cfg.d_model,
                axes, in_shard="y", out_shard="x", dtype=dtype,
                abstract=abstract),
            "block": {
                "norm1": _norm_init(cfg, axes, dtype, (), abstract),
                "mixer": _mixer_init(cfg.mixers()[-1], mkeys[1], cfg,
                                     axes, dtype, (), abstract),
                "norm2": _norm_init(cfg, axes, dtype, (), abstract),
                "ffn": _ffn_init("mlp", mkeys[2], cfg, axes, dtype, (),
                                 abstract),
            },
        }
    if cfg.arch_type == "vlm":
        vd = cfg.encoder.input_dim or cfg.d_model
        params["projector"] = {
            "w1": PP.tp_linear_init(keys[2], vd, cfg.d_model, axes,
                                    in_shard=None, out_shard="y",
                                    dtype=dtype, abstract=abstract),
            "w2": PP.tp_linear_init(keys[3], cfg.d_model, cfg.d_model,
                                    axes, in_shard="y", out_shard="x",
                                    dtype=dtype, abstract=abstract),
        }
    return params


# ---------------------------------------------------------------------- #
# forward
# ---------------------------------------------------------------------- #

def _block_apply(blk, kinds_i, h, cfg, axes, *, positions, mode, cache,
                 aux, paged=None):
    mixer, ffn = kinds_i
    # seq-sharded decode only changes the attention cache layout; the
    # recurrent mixers always do a plain single-step state update.
    # (mode 'paged' reaches softmax-attention mixers only —
    # decoder_paged_cache_specs gates the architecture up front.)
    sub_mode = "decode" if mode.startswith("decode") else mode
    hn = _apply_norm(blk["norm1"], h, cfg, axes)
    if mixer == "attn":
        o, cache = A.attn_apply(blk["mixer"], hn, cfg, axes,
                                positions=positions, mode=mode, cache=cache,
                                window=cfg.sliding_window, paged=paged)
    elif mixer == "mla":
        o, cache = A.mla_apply(blk["mixer"], hn, cfg, axes,
                               positions=positions, mode=sub_mode,
                               cache=cache)
    elif mixer == "mamba":
        o, cache = MB.mamba_apply(blk["mixer"], hn, cfg, axes,
                                  mode=sub_mode, state=cache)
    elif mixer == "mlstm":
        o, cache = XL.mlstm_apply(blk["mixer"], hn, cfg, axes,
                                  mode=sub_mode, state=cache)
    elif mixer == "slstm":
        o, cache = XL.slstm_apply(blk["mixer"], hn, cfg, axes,
                                  mode=sub_mode, state=cache)
    else:
        raise ValueError(mixer)
    h = h + o
    if ffn != "none":
        hn = _apply_norm(blk["norm2"], h, cfg, axes)
        if ffn == "moe":
            o, a = MOE.moe_apply(blk["ffn"], hn, cfg, axes)
            aux = aux + a
        else:
            o = FF.mlp_apply(blk["ffn"], hn, cfg.act, axes,
                             gated=cfg.gated_mlp)
        h = h + o
    return h, cache, aux


def _checkpoint(fn, policy: str):
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def decoder_hidden(params, cfg: ArchConfig, axes: M.MeshAxes, tokens, *,
                   positions=None, mode: str = "train", caches=None,
                   image_embeds=None, remat: bool = True,
                   unroll: bool = False, remat_policy: str = "full",
                   pstream=None, paged=None):
    """Run embedding + all blocks. Returns (h, new_caches, aux_loss).

    ``pstream`` (a ``gradsync.ParamStreamer``, zero3 training only)
    switches the segment params to the ZeRO-3 shard layout: each scan
    iteration assembles just its layer's working copy by a ring
    all-gather over the data axis — inside the rematerialized body
    (released after the layer, re-gathered by remat for the backward)
    or, with ``pstream.prefetch``, one layer ahead via the carry (its
    ring hops overlap the current layer's compute; the copy is retained
    for the backward). Non-segment leaves must already be materialized
    (``pstream.resident`` — ``lm_loss`` does this)."""
    assert pstream is None or (mode == "train" and caches is None), \
        "zero3 param streaming is a training-path feature"
    if axes.gseq > 1 and mode != "train":
        raise NotImplementedError(
            f"seq (context) parallelism is a training-path feature: the "
            f"{mode!r} path keeps its KV cache whole per batch shard, so "
            f"a seq axis of g_seq={axes.gseq} has nothing to shard "
            f"(ROADMAP residual 'seq-parallel serving'). Serve on a mesh "
            f"with g_seq == 1 — e.g. pass a 4-tuple --mesh d,x,y,z, or "
            f"drop --seq-parallel/--g-seq from the launch flags.")
    B, T = tokens.shape
    if positions is None:
        if mode == "train" and axes.gseq > 1:
            # striped context-parallel layout (mesh.stripe_seq fed the
            # batch): local token j on seq-rank r is global position
            # j*g_seq + r — RoPE and causal masks both key off these
            positions = jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32) * axes.gseq
                + M.axis_index(axes.seq), (B, T))
        else:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32),
                                         (B, T))
    h = PP.embedding_lookup(tokens, params["embed"], axes)
    if cfg.arch_type == "vlm" and image_embeds is not None:
        assert axes.gseq == 1, \
            "image_embeds need a contiguous token prefix (no seq sharding)"
        assert image_embeds.shape[1] <= T, \
            f"image tokens {image_embeds.shape[1]} exceed seq {T}"
        pj = params["projector"]
        v = PP.tp_matmul(image_embeds, pj["w1"], axes, None, "y")
        v = PP.tp_matmul(jax.nn.gelu(v), pj["w2"], axes, "y", "x")
        h = jax.lax.dynamic_update_slice(
            h, v.astype(h.dtype), (0, 0, 0))

    aux0 = jnp.zeros((), jnp.float32)

    def make_period_fn(kinds):
        def period_fn(h, aux, blk_params, blk_caches):
            new_caches = {}
            for i in range(len(kinds)):
                c = None if blk_caches is None else blk_caches[f"pos{i}"]
                h, c, aux = _block_apply(
                    blk_params[f"pos{i}"], kinds[i], h, cfg, axes,
                    positions=positions, mode=mode, cache=c, aux=aux,
                    paged=paged)
                new_caches[f"pos{i}"] = c
            return h, aux, new_caches
        return period_fn

    aux = aux0
    new_caches = {} if caches is not None else None
    sbuckets = (pstream.buckets_like()["segments"]
                if pstream is not None else None)
    prefetch = pstream is not None and pstream.prefetch
    for s, (kinds, n_periods) in enumerate(cfg.segments()):
        seg_params = params["segments"][f"seg{s}"]
        seg_caches = None if caches is None else caches[f"seg{s}"]
        seg_bk = None if sbuckets is None else sbuckets[f"seg{s}"]
        # a segment streams only when its leaves are scan-stacked
        # (stack > 1). n_periods == 1 segments plan as unstacked —
        # ``pstream.resident`` already materialized their single layer
        # (= one layer's working set, the floor the schedule holds
        # anyway), so they run the plain non-streamed path below.
        streamed = (seg_bk is not None
                    and any(b.stack > 1 for b in jax.tree.leaves(seg_bk)))
        pre = streamed and prefetch
        period_fn = make_period_fn(kinds)
        if unroll:
            # python-unrolled layers: exact HLO flop/collective accounting
            # for the dry-run (XLA cost analysis counts a scan body once)
            ncs = [] if caches is not None else None

            def blk_fn(h, aux, blk, bc, _pf=period_fn, _bk=seg_bk,
                       _stream=streamed and not prefetch):
                # the just-in-time gather lives INSIDE the rematerialized
                # block: released after the layer's forward, re-gathered
                # by remat for its backward
                if _stream:
                    blk = pstream.gather_tree(blk, _bk)
                return _pf(h, aux, blk, bc)
            fn = blk_fn
            if remat and mode == "train":
                fn = _checkpoint(blk_fn, remat_policy)
            nxt = (pstream.gather_tree(
                jax.tree.map(lambda x: x[0], seg_params), seg_bk)
                if pre else None)
            for i in range(n_periods):
                if pre:
                    # issue layer i+1's gathers before layer i's compute:
                    # data-independent, so the scheduler overlaps them;
                    # the gathered copy is a block input -> retained for
                    # the backward (no re-gather)
                    blk, nxt = nxt, (pstream.gather_tree(
                        jax.tree.map(lambda x: x[i + 1], seg_params),
                        seg_bk) if i + 1 < n_periods else None)
                else:
                    blk = jax.tree.map(lambda x: x[i], seg_params)
                bc = (jax.tree.map(lambda x: x[i], seg_caches)
                      if caches is not None else None)
                h, aux, nc = fn(h, aux, blk, bc)
                if caches is not None:
                    ncs.append(nc)
            if caches is not None:
                new_caches[f"seg{s}"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *ncs)
        elif caches is None:
            if pre:
                # gathered layer i+1 rides the carry while layer i
                # computes (retained as a saved carry for the backward);
                # the scan runs layers 0..n-2 over layer 1..n-1's shards
                # and the LAST layer applies outside it, so no gather is
                # ever issued for a layer that does not run
                first = pstream.gather_tree(
                    jax.tree.map(lambda x: x[0], seg_params), seg_bk)
                rest = jax.tree.map(lambda x: x[1:], seg_params)

                def body(carry, nxt_shards, _pf=period_fn, _bk=seg_bk):
                    h, aux, blk = carry
                    nxt = pstream.gather_tree(nxt_shards, _bk)
                    h, aux, _ = _pf(h, aux, blk, None)
                    return (h, aux, nxt), 0

                def last_fn(h, aux, blk, _pf=period_fn):
                    h, aux, _ = _pf(h, aux, blk, None)
                    return h, aux
                if remat and mode == "train":
                    body = _checkpoint(body, remat_policy)
                    last_fn = _checkpoint(last_fn, remat_policy)
                (h, aux, last), _ = jax.lax.scan(body, (h, aux, first),
                                                 rest)
                h, aux = last_fn(h, aux, last)
            else:
                def body(h_aux, blk_params, _pf=period_fn, _bk=seg_bk,
                         _stream=streamed):
                    if _stream:
                        blk_params = pstream.gather_tree(blk_params, _bk)
                    h, aux, _ = _pf(*h_aux, blk_params, None)
                    return (h, aux), 0
                if remat and mode == "train":
                    body = _checkpoint(body, remat_policy)
                (h, aux), _ = jax.lax.scan(body, (h, aux), seg_params)
        else:
            def body(h_aux, xs, _pf=period_fn):
                blk_params, blk_caches = xs
                h, aux, nc = _pf(*h_aux, blk_params, blk_caches)
                return (h, aux), nc
            (h, aux), nc = jax.lax.scan(body, (h, aux),
                                        (seg_params, seg_caches))
            new_caches[f"seg{s}"] = nc

    h = _apply_norm(params["final_norm"], h, cfg, axes)
    return h, new_caches, aux


def lm_logits(params, cfg: ArchConfig, axes: M.MeshAxes, h):
    """(B, T, d/x) -> (B, T, V/y) logits (replicated over x)."""
    if cfg.tie_embeddings:
        return PP.tied_lm_logits(h, params["embed"], axes)
    return PP.tp_matmul(h, params["lm_head"], axes, "x", "y")


def lm_loss(params, cfg: ArchConfig, axes: M.MeshAxes, tokens, labels, *,
            image_embeds=None, remat: bool = True,
            xent_chunks: int = 1, unroll: bool = False,
            remat_policy: str = "full", mtp_weight: float = 0.0,
            pstream=None):
    """Mean cross-entropy over the *global* batch (+ MoE aux loss,
    + optional DeepSeek-style MTP loss when configured and weighted).

    With ``pstream`` (zero3) ``params`` arrive as the ZeRO-3 shard tree:
    the non-streamed leaves (embedding, head, norms, mtp, projector) are
    materialized once here, the segment leaves stay sharded and stream
    per-layer through ``decoder_hidden``."""
    if pstream is not None:
        params = pstream.resident(params)
    h, _, aux = decoder_hidden(params, cfg, axes, tokens, mode="train",
                               image_embeds=image_embeds, remat=remat,
                               unroll=unroll, remat_policy=remat_policy,
                               pstream=pstream)
    B, T = labels.shape

    def chunk_loss(hc, lc):
        logits = lm_logits(params, cfg, axes, hc)
        return jnp.sum(PP.vocab_parallel_xent(logits, lc, axes,
                                              cfg.vocab_size))

    if xent_chunks > 1 and T % xent_chunks == 0:
        hs = h.reshape(B, xent_chunks, T // xent_chunks, -1)
        ls = labels.reshape(B, xent_chunks, T // xent_chunks)
        total = 0.0
        for i in range(xent_chunks):
            total = total + chunk_loss(hs[:, i], ls[:, i])
    else:
        total = chunk_loss(h, labels)

    # token_axes() == batch_axes() + seq: under context parallelism each
    # seq-rank holds T/g_seq tokens, so the mean reduces over both.  With
    # seq unmapped these degenerate bitwise to the old batch reductions.
    total = PP.ar_bwd_identity(total, axes.token_axes())
    n_tokens_global = B * T * axes.token_shards
    loss = total / n_tokens_global
    aux_mean = PP.ar_bwd_identity(aux, axes.token_axes()) / axes.token_shards
    out_loss = loss + aux_mean
    metrics = {"xent": loss, "aux": aux_mean}
    if mtp_weight > 0.0 and "mtp" in params and T > 2:
        assert axes.gseq == 1, \
            "MTP needs contiguous token shifts (no seq sharding)"
        mtp = params["mtp"]
        # predict token t+2 from (h_t, emb(token_{t+1}))  [DSv3 MTP d=1]
        hn = _apply_norm(mtp["norm_h"], h[:, :-2, :], cfg, axes)
        emb = PP.embedding_lookup(tokens[:, 1:-1], params["embed"], axes)
        en = _apply_norm(mtp["norm_e"], emb, cfg, axes)
        u = PP.tp_matmul(hn, mtp["w_comb_h"], axes, "x", "y") \
            + PP.tp_matmul(en, mtp["w_comb_e"], axes, "x", "y")
        hm = PP.tp_matmul(jax.nn.gelu(u), mtp["w_comb_o"], axes, "y", "x")
        Bm, Tm = hm.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(Tm, dtype=jnp.int32), (Bm, Tm))
        hm, _, _ = (lambda hh: _block_apply(
            mtp["block"], (cfg.mixers()[-1], "mlp"), hh, cfg, axes,
            positions=pos, mode="train", cache=None,
            aux=jnp.zeros((), jnp.float32)))(hm)
        logits_m = lm_logits(params, cfg, axes, hm)
        mtp_tok = PP.vocab_parallel_xent(logits_m, labels[:, 1:-1], axes,
                                         cfg.vocab_size)
        mtp_total = PP.ar_bwd_identity(jnp.sum(mtp_tok), axes.batch_axes())
        mtp_loss = mtp_total / (Bm * Tm * axes.batch_shards)
        out_loss = out_loss + mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    return out_loss, metrics


# ---------------------------------------------------------------------- #
# serving: cache specs + decode step
# ---------------------------------------------------------------------- #

def decoder_cache_specs(cfg: ArchConfig, axes: M.MeshAxes, batch_global: int,
                        seq: int, *, seqshard: bool = False,
                        dtype=jnp.bfloat16):
    """GLOBAL (ShapeDtypeStruct, PartitionSpec) trees for the decode cache,
    stacked (n_periods, ...) per segment position for the layer scans."""
    out = {}
    for s, (kinds, n_periods) in enumerate(cfg.segments()):
        seg = {}
        for i, (mixer, _) in enumerate(kinds):
            if mixer == "attn":
                spec = A.attn_cache_spec(cfg, axes, batch_global, seq,
                                         dtype=dtype, seqshard=seqshard)
            elif mixer == "mla":
                assert not seqshard, "MLA long-context seqshard unsupported"
                spec = A.mla_cache_spec(cfg, axes, batch_global, seq,
                                        dtype=dtype)
            elif mixer == "mamba":
                spec = MB.mamba_state_spec(cfg, axes, batch_global,
                                           dtype=dtype, seqshard=seqshard)
            elif mixer in ("mlstm", "slstm"):
                spec = XL.xlstm_state_spec(cfg, axes, batch_global, mixer,
                                           seqshard=seqshard)
            else:
                raise ValueError(mixer)
            seg[f"pos{i}"] = jax.tree.map(
                lambda sp: (jax.ShapeDtypeStruct(
                    (n_periods, *sp[0].shape), sp[0].dtype),
                    P(None, *sp[1])),
                spec, is_leaf=lambda t: isinstance(t, tuple)
                and len(t) == 2 and isinstance(t[0], jax.ShapeDtypeStruct))
        out[f"seg{s}"] = seg
    return out


def decode_step(params, cfg: ArchConfig, axes: M.MeshAxes, tokens, caches,
                pos, *, seqshard: bool = False, unroll: bool = False):
    """One serving step: tokens (B, 1) at absolute position ``pos``.

    Returns (logits (B, 1, V/y), new_caches)."""
    B = tokens.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B, 1))
    mode = "decode_seqshard" if seqshard else "decode"
    h, new_caches, _ = decoder_hidden(params, cfg, axes, tokens,
                                      positions=positions, mode=mode,
                                      caches=caches, remat=False,
                                      unroll=unroll)
    logits = lm_logits(params, cfg, axes, h)
    return logits, new_caches


def decoder_paged_cache_specs(cfg: ArchConfig, axes: M.MeshAxes,
                              n_pages_global: int, page_size: int, *,
                              dtype=jnp.bfloat16):
    """GLOBAL (struct, spec) trees for the PAGED serving cache: one
    physical KV page pool per attention layer (pages sharded over
    data x z, KV heads over y — ``A.paged_attn_cache_spec``), stacked
    (n_periods, ...) per segment position like ``decoder_cache_specs``.

    Paged serving gates to text decoders whose mixers are all softmax
    attention: recurrent mixers (mamba/xlstm) keep per-slot dense state
    with no page analogue, and MLA's absorbed decode reads its compressed
    cache contiguously."""
    bad = sorted({m for m in cfg.mixers() if m != "attn"})
    if bad or cfg.arch_type in ("vlm", "audio"):
        what = (f"mixer(s) {bad}" if bad
                else f"arch_type {cfg.arch_type!r}")
        raise NotImplementedError(
            f"{cfg.name}: paged continuous-batching serving supports "
            f"text decoders with softmax-attention mixers only (got "
            f"{what}). Use the fixed-batch path instead: "
            f"python -m repro.launch.serve --mode fixed --arch {cfg.name}")
    out = {}
    for s, (kinds, n_periods) in enumerate(cfg.segments()):
        seg = {}
        for i, _ in enumerate(kinds):
            spec = A.paged_attn_cache_spec(cfg, axes, n_pages_global,
                                           page_size, dtype=dtype)
            seg[f"pos{i}"] = jax.tree.map(
                lambda sp: (jax.ShapeDtypeStruct(
                    (n_periods, *sp[0].shape), sp[0].dtype),
                    P(None, *sp[1])),
                spec, is_leaf=lambda t: isinstance(t, tuple)
                and len(t) == 2 and isinstance(t[0], jax.ShapeDtypeStruct))
        out[f"seg{s}"] = seg
    return out


def paged_step(params, cfg: ArchConfig, axes: M.MeshAxes, tokens, pools,
               positions, q_len, table):
    """One continuous-batching serving step over the paged KV cache.

    tokens (R, T): slot r's rows 0..q_len[r]-1 carry its prefill chunk
    (or single decode token) at global ``positions`` (R, T); rows past
    q_len[r] are padding (idle slots have q_len 0). ``table`` (R,
    max_pages) holds shard-local physical page ids. Returns (per-slot
    next-token logits from the last *valid* row, (R, 1, V/y), new
    pools). See docs/serving.md for the schedule this slots into."""
    paged = {"table": table, "q_len": q_len}
    h, new_pools, _ = decoder_hidden(params, cfg, axes, tokens,
                                     positions=positions, mode="paged",
                                     caches=pools, remat=False, paged=paged)
    idx = jnp.clip(q_len.astype(jnp.int32) - 1, 0, tokens.shape[1] - 1)
    hl = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    logits = lm_logits(params, cfg, axes, hl)
    return logits, new_pools


def prefill(params, cfg: ArchConfig, axes: M.MeshAxes, tokens, caches, *,
            image_embeds=None, unroll: bool = False):
    """Fill the cache from a prompt; returns (logits_last, caches)."""
    h, new_caches, _ = decoder_hidden(params, cfg, axes, tokens,
                                      mode="prefill", caches=caches,
                                      image_embeds=image_embeds,
                                      remat=False, unroll=unroll)
    logits = lm_logits(params, cfg, axes, h[:, -1:, :])
    return logits, new_caches
