"""Encoder-decoder transformer (Whisper-style) under the 4D layout.

The conv/mel frontend is a stub per the assignment: ``input_specs`` feeds
precomputed post-conv frame embeddings (B, n_ctx, d_model). Everything from
there on — sinusoidal positions, the 12-layer encoder, the causal decoder
with cross attention, the tied LM head — is built here with the same 4D
tp layers as the decoder-only models.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.partition import Boxed
from repro.layers import attention as A
from repro.layers import mlp as FF
from repro.models.base import ArchConfig
from repro.models.decoder import _apply_norm, _norm_init


def _sinusoid(n_ctx: int, d: int):
    pos = jnp.arange(n_ctx, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _local_xslice(full, axes):
    """Slice the x-shard of a (..., d_model) replicated array."""
    d_local = full.shape[-1] // max(axes.gx, 1)
    start = M.axis_index(axes.x) * d_local
    return jax.lax.dynamic_slice_in_dim(full, start, d_local, axis=-1)


def encdec_init(key, cfg: ArchConfig, axes: M.MeshAxes, *,
                dtype=jnp.bfloat16, abstract: bool = False
                ) -> Dict[str, Any]:
    cfg.validate_axes(axes)
    ec = cfg.encoder
    ks = jax.random.split(key, 8)
    enc_stack = (ec.n_layers,)
    dec_stack = (cfg.n_layers,)

    enc_blocks = {
        "norm1": _norm_init(cfg, axes, dtype, enc_stack, abstract),
        "attn": A.attn_init(ks[0], cfg, axes, dtype=dtype, stack=enc_stack,
                            abstract=abstract),
        "norm2": _norm_init(cfg, axes, dtype, enc_stack, abstract),
        "mlp": FF.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, axes,
                           gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                           dtype=dtype, stack=enc_stack, abstract=abstract),
    }
    dec_blocks = {
        "norm1": _norm_init(cfg, axes, dtype, dec_stack, abstract),
        "self_attn": A.attn_init(ks[2], cfg, axes, dtype=dtype,
                                 stack=dec_stack, abstract=abstract),
        "norm_x": _norm_init(cfg, axes, dtype, dec_stack, abstract),
        "cross_attn": A.attn_init(ks[3], cfg, axes, dtype=dtype,
                                  stack=dec_stack, abstract=abstract,
                                  cross=True),
        "norm2": _norm_init(cfg, axes, dtype, dec_stack, abstract),
        "mlp": FF.mlp_init(ks[4], cfg.d_model, cfg.d_ff, cfg.act, axes,
                           gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                           dtype=dtype, stack=dec_stack, abstract=abstract),
    }
    pos_spec = axes.pspec(None, axes.x)
    pos_shape = (cfg.max_seq, cfg.d_model)
    params = {
        "encoder": {"blocks": enc_blocks,
                    "final_norm": _norm_init(cfg, axes, dtype, (), abstract)},
        "decoder": {
            "embed": PP.embedding_init(ks[5], cfg.padded_vocab, cfg.d_model,
                                       axes, dtype=dtype, abstract=abstract),
            "pos": Boxed(jax.ShapeDtypeStruct(pos_shape, dtype) if abstract
                         else (jax.random.normal(ks[6], pos_shape) * 0.01
                               ).astype(dtype), pos_spec),
            "blocks": dec_blocks,
            "final_norm": _norm_init(cfg, axes, dtype, (), abstract),
        },
    }
    return params


def encoder_apply(params, cfg: ArchConfig, axes: M.MeshAxes, frames,
                  unroll: bool = False, remat: bool = False):
    """frames: (B, n_ctx, d_model/x) — post-conv stub features, x-sharded."""
    ec = cfg.encoder
    B, n_ctx = frames.shape[:2]
    pe = _local_xslice(_sinusoid(n_ctx, cfg.d_model), axes)
    h = frames + pe[None].astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(n_ctx, dtype=jnp.int32),
                                 (B, n_ctx))

    def body(h, blk):
        hn = _apply_norm(blk["norm1"], h, cfg, axes)
        o, _ = A.attn_apply(blk["attn"], hn, cfg, axes, positions=positions,
                            mode="train", causal=False)
        h = h + o
        hn = _apply_norm(blk["norm2"], h, cfg, axes)
        h = h + FF.mlp_apply(blk["mlp"], hn, cfg.act, axes,
                             gated=cfg.gated_mlp)
        return h, 0

    fn = jax.checkpoint(body) if remat else body
    if unroll:
        for i in range(cfg.encoder.n_layers):
            blk = jax.tree.map(lambda x: x[i], params["encoder"]["blocks"])
            h, _ = fn(h, blk)
    else:
        h, _ = jax.lax.scan(fn, h, params["encoder"]["blocks"])
    return _apply_norm(params["encoder"]["final_norm"], h, cfg, axes)


def _dec_positions(B, T, pos0=0):
    return jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32) + pos0, (B, T))


def decoder_apply(params, cfg: ArchConfig, axes: M.MeshAxes, tokens,
                  enc_out, *, mode="train", caches=None, pos0=0,
                  unroll: bool = False, remat: bool = False):
    """tokens (B, T); enc_out (B, n_ctx, d/x). Returns (logits, caches)."""
    dp = params["decoder"]
    B, T = tokens.shape
    positions = _dec_positions(B, T, pos0)
    h = PP.embedding_lookup(tokens, dp["embed"], axes)
    if mode == "decode":
        pe = jax.lax.dynamic_slice_in_dim(dp["pos"], pos0, 1, axis=0)
    else:
        pe = jax.lax.dynamic_slice_in_dim(dp["pos"], 0, T, axis=0)
    h = h + pe[None].astype(h.dtype)

    def body(h_c, xs):
        h, _ = h_c
        blk, cache = xs
        hn = _apply_norm(blk["norm1"], h, cfg, axes)
        c_self = None if cache is None else cache["self"]
        o, c_self = A.attn_apply(blk["self_attn"], hn, cfg, axes,
                                 positions=positions, mode=mode,
                                 cache=c_self)
        h = h + o
        hn = _apply_norm(blk["norm_x"], h, cfg, axes)
        if mode in ("train",):
            enc_kv = A.cross_attn_kv(blk["cross_attn"], enc_out, cfg, axes)
        elif mode == "prefill":
            enc_kv = A.cross_attn_kv(blk["cross_attn"], enc_out, cfg, axes)
        else:  # decode: cached cross kv
            enc_kv = (cache["cross_k"], cache["cross_v"])
        h = h + A.cross_attn_apply(blk["cross_attn"], hn, enc_kv, cfg, axes)
        hn = _apply_norm(blk["norm2"], h, cfg, axes)
        h = h + FF.mlp_apply(blk["mlp"], hn, cfg.act, axes,
                             gated=cfg.gated_mlp)
        new_cache = None
        if cache is not None:
            new_cache = {"self": c_self, "cross_k": enc_kv[0],
                         "cross_v": enc_kv[1]}
        return (h, 0), new_cache

    fn = jax.checkpoint(body) if remat else body
    if unroll:
        hc = (h, 0)
        ncs = [] if caches is not None else None
        for i in range(cfg.n_layers):
            blk = jax.tree.map(lambda x: x[i], dp["blocks"])
            bc = (jax.tree.map(lambda x: x[i], caches)
                  if caches is not None else None)
            hc, nc = fn(hc, (blk, bc))
            if caches is not None:
                ncs.append(nc)
        h = hc[0]
        new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
                      if caches is not None else None)
    elif caches is None:
        def body_nc(h_c, blk):
            out, _ = fn(h_c, (blk, None))
            return out, 0
        (h, _), _ = jax.lax.scan(body_nc, (h, 0), dp["blocks"])
        new_caches = None
    else:
        (h, _), new_caches = jax.lax.scan(body, (h, 0),
                                          (dp["blocks"], caches))
    h = _apply_norm(dp["final_norm"], h, cfg, axes)
    logits = PP.tied_lm_logits(h, dp["embed"], axes)
    return logits, new_caches


def encdec_loss(params, cfg: ArchConfig, axes: M.MeshAxes, frames, tokens,
                labels, unroll: bool = False, remat: bool = True):
    enc_out = encoder_apply(params, cfg, axes, frames, unroll=unroll,
                            remat=remat)
    logits, _ = decoder_apply(params, cfg, axes, tokens, enc_out,
                              mode="train", unroll=unroll, remat=remat)
    tok_loss = PP.vocab_parallel_xent(logits, labels, axes,
                                      cfg.vocab_size)
    total = PP.ar_bwd_identity(jnp.sum(tok_loss), axes.batch_axes())
    n_tokens = labels.shape[0] * labels.shape[1] * axes.batch_shards
    loss = total / n_tokens
    return loss, {"xent": loss}


def encdec_cache_specs(cfg: ArchConfig, axes: M.MeshAxes, batch_global: int,
                       seq: int, *, dtype=jnp.bfloat16):
    hd = cfg.head_dim_
    self_spec = A.attn_cache_spec(cfg, axes, batch_global, seq, dtype=dtype)
    kv_shape = (batch_global, cfg.encoder.n_ctx, cfg.n_kv_heads, hd)
    kv_spec = axes.pspec(axes.batch_axes(), None, axes.y, None)
    one = {
        "self": self_spec,
        "cross_k": (jax.ShapeDtypeStruct(kv_shape, dtype), kv_spec),
        "cross_v": (jax.ShapeDtypeStruct(kv_shape, dtype), kv_spec),
    }
    return jax.tree.map(
        lambda sp: (jax.ShapeDtypeStruct((cfg.n_layers, *sp[0].shape),
                                         sp[0].dtype), P(None, *sp[1])),
        one, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
        and isinstance(t[0], jax.ShapeDtypeStruct))


def encdec_decode_step(params, cfg: ArchConfig, axes: M.MeshAxes, tokens,
                       caches, pos, unroll: bool = False):
    logits, new_caches = decoder_apply(params, cfg, axes, tokens, None,
                                       mode="decode", caches=caches,
                                       pos0=pos, unroll=unroll)
    return logits, new_caches