"""Channel-parallel U-Net (the paper's own primary evaluation model,
Nichol & Dhariwal-style) under the 4D layout, trained as a DDPM noise
predictor — the paper's §6.1 task.

Structure (compact but faithful): conv stem -> L levels of [res, res,
downsample] -> middle res -> L levels of [upsample, res(+skip), res] ->
GN -> out conv. Each residual block is the paper's normal/transposed conv
pair (conv1: contract x -> y; conv2: contract y -> x) so layer boundaries
cost zero communication, exactly as in the transformer case; the timestep
embedding enters between them (projected to the y-sharded intermediate).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.partition import Boxed
from repro.layers.conv import group_norm_local, tp_conv, tp_conv_init


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str = "unet-paper-280m"
    channels: int = 384          # base width (paper 280M validator scale)
    levels: int = 3
    res_blocks: int = 2
    image_size: int = 32
    in_channels: int = 3
    temb_dim: int = 512
    groups: int = 32
    source: str = "paper §6.1 / Nichol & Dhariwal [arXiv:2102.09672]"

    def reduced(self) -> "UNetConfig":
        return dataclasses.replace(self, name=self.name + "-smoke",
                                   channels=64, levels=2, res_blocks=1,
                                   image_size=16, groups=8)


def _gn_params(c, axes, dtype, abstract, shard="x"):
    spec = axes.pspec(axes.x if shard == "x" else axes.y)
    if abstract:
        z = jax.ShapeDtypeStruct((c,), dtype)
        return {"g": Boxed(z, spec), "b": Boxed(z, spec)}
    return {"g": Boxed(jnp.ones((c,), dtype), spec),
            "b": Boxed(jnp.zeros((c,), dtype), spec)}


def _res_block_init(key, cin, cout, cfg, axes, dtype, abstract):
    ks = jax.random.split(key, 4)
    p = {
        "gn1": _gn_params(cin, axes, dtype, abstract),
        "conv1": tp_conv_init(ks[0], 3, cin, cout, axes, in_shard="x",
                              out_shard="y", dtype=dtype,
                              abstract=abstract),
        # timestep projection lands on the y-sharded intermediate
        "temb": PP.tp_linear_init(ks[1], cfg.temb_dim, cout, axes,
                                  in_shard=None, out_shard="y",
                                  dtype=dtype, abstract=abstract),
        "gn2": _gn_params(cout, axes, dtype, abstract, shard="y"),
        "conv2": tp_conv_init(ks[2], 3, cout, cout, axes, in_shard="y",
                              out_shard="x", dtype=dtype,
                              abstract=abstract),
    }
    if cin != cout:
        # x -> full (psum over x), then slice back to the x shard
        p["skip"] = tp_conv_init(ks[3], 1, cin, cout, axes, in_shard="x",
                                 out_shard=None, dtype=dtype,
                                 abstract=abstract)
    return p


def _gn(x, prm, cfg, axes, c_shard: str):
    # groups aligned to the shard of the channel dim (see conv.py)
    g_total = cfg.groups
    gsz = axes.gx if c_shard == "x" else axes.gy
    n_local = max(g_total // max(gsz, 1), 1)
    return group_norm_local(x, prm["g"], prm["b"], n_local)


def _res_block(p, x, temb, cfg, axes):
    h = _gn(x, p["gn1"], cfg, axes, "x")
    h = tp_conv(jax.nn.silu(h), p["conv1"], axes, "x", "y")
    h = h + PP.tp_matmul(jax.nn.silu(temb), p["temb"], axes, None, "y"
                         )[:, None, None, :]
    h = _gn(h, p["gn2"], cfg, axes, "y")
    h = tp_conv(jax.nn.silu(h), p["conv2"], axes, "y", "x")
    if "skip" in p:
        x = PP.to_x_shard(tp_conv(x, p["skip"], axes, "x", None), axes)
    return x + h


def unet_init(key, cfg: UNetConfig, axes: M.MeshAxes, *,
              dtype=jnp.float32, abstract=False) -> Dict[str, Any]:
    C = cfg.channels
    ks = iter(jax.random.split(key, 64))
    p: Dict[str, Any] = {
        "stem": tp_conv_init(next(ks), 3, cfg.in_channels, C, axes,
                             in_shard=None, out_shard="x", dtype=dtype,
                             abstract=abstract),
        "temb1": PP.tp_linear_init(next(ks), cfg.temb_dim, cfg.temb_dim,
                                   axes, in_shard=None, out_shard=None,
                                   dtype=dtype, abstract=abstract),
        "temb2": PP.tp_linear_init(next(ks), cfg.temb_dim, cfg.temb_dim,
                                   axes, in_shard=None, out_shard=None,
                                   dtype=dtype, abstract=abstract),
        "out_gn": _gn_params(C, axes, dtype, abstract),
        "out": tp_conv_init(next(ks), 3, C, cfg.in_channels, axes,
                            in_shard="x", out_shard=None, dtype=dtype,
                            z_shard=False, abstract=abstract),
    }
    down, up = [], []
    widths = [C * (2 ** i) for i in range(cfg.levels)]
    cin = C
    for lv, w in enumerate(widths):
        blocks = []
        for b in range(cfg.res_blocks):
            blocks.append(_res_block_init(next(ks), cin, w, cfg, axes,
                                          dtype, abstract))
            cin = w
        down.append({"blocks": dict(enumerate_map(blocks))})
    p["mid"] = _res_block_init(next(ks), cin, cin, cfg, axes, dtype,
                               abstract)
    for lv, w in reversed(list(enumerate(widths))):
        blocks = []
        for b in range(cfg.res_blocks):
            # skip concat halves handled by addition (compact variant)
            blocks.append(_res_block_init(next(ks), cin + 0, w, cfg, axes,
                                          dtype, abstract))
            cin = w
        up.append({"blocks": dict(enumerate_map(blocks))})
    p["down"] = dict(enumerate_map(down))
    p["up"] = dict(enumerate_map(up))
    return p


def enumerate_map(items):
    return ((f"b{i}", v) for i, v in enumerate(items))


def _timestep_embedding(t, dim):
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _pool2(x):
    B, H, W, C = x.shape
    return x.reshape(B, H // 2, 2, W // 2, 2, C).mean(axis=(2, 4))


def _up2(x):
    return jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)


def unet_apply(p, cfg: UNetConfig, axes: M.MeshAxes, x, t):
    """x: (B, H, W, Cin) full channels (small, replicated); t: (B,)."""
    temb = _timestep_embedding(t, cfg.temb_dim)
    temb = PP.tp_matmul(jax.nn.silu(
        PP.tp_matmul(temb, p["temb1"], axes, None, None)),
        p["temb2"], axes, None, None)
    h = tp_conv(x, p["stem"], axes, None, "x")
    skips = []
    for lv in range(cfg.levels):
        for b in range(cfg.res_blocks):
            h = _res_block(p["down"][f"b{lv}"]["blocks"][f"b{b}"], h,
                           temb, cfg, axes)
        skips.append(h)
        if lv < cfg.levels - 1:
            h = _pool2(h)
    h = _res_block(p["mid"], h, temb, cfg, axes)
    for i, lv in enumerate(reversed(range(cfg.levels))):
        if i > 0:
            h = _up2(h)
        for b in range(cfg.res_blocks):
            h = _res_block(p["up"][f"b{i}"]["blocks"][f"b{b}"], h, temb,
                           cfg, axes)
            if b == 0:
                h = h + skips[lv]  # additive skip (compact variant)
    h = _gn(h, p["out_gn"], cfg, axes, "x")
    return tp_conv(jax.nn.silu(h), p["out"], axes, "x", None, 1, False)


def ddpm_loss(p, cfg: UNetConfig, axes: M.MeshAxes, images, t, noise):
    """DDPM noise-prediction MSE (paper §6.1's U-Net training task).
    images/noise: (B, H, W, C); t: (B,) in [0, 1000)."""
    abar = jnp.cos(0.5 * jnp.pi * t.astype(jnp.float32) / 1000) ** 2
    xt = (jnp.sqrt(abar)[:, None, None, None] * images
          + jnp.sqrt(1 - abar)[:, None, None, None] * noise)
    pred = unet_apply(p, cfg, axes, xt.astype(images.dtype), t)
    se = jnp.sum((pred.astype(jnp.float32) - noise) ** 2)
    total = PP.ar_bwd_identity(se, axes.batch_axes())
    n = images.size * axes.batch_shards
    return total / n
