"""Model assemblers (decoder-only, enc-dec) + the config system."""
