"""Neural network layers under the 4D tensor-parallel layout."""
