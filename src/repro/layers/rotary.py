"""Rotary position embeddings (full and partial-rotary variants)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float, rotary_pct: float = 1.0):
    """Inverse frequencies for the rotary fraction of the head dim."""
    rot = int(head_dim * rotary_pct)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, rotary_pct: float = 1.0):
    """x: (B, T, n_heads, head_dim); positions: (B, T) int32."""
    inv, rot = rope_freqs(x.shape[-1], theta, rotary_pct)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, T, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


def apply_rope_interleaved_neox(x, positions, theta: float):
    """NeoX-style half-rotation (used by MLA's rope sub-dim)."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)
