"""Feed-forward layers: gated (SiLU/GeLU) and squared-ReLU variants.

The up projection is a paper "normal" layer (contract x, output over y) and
the down projection a paper "transposed" layer (contract y, output over x) —
the §4.1 alternation that keeps layer boundaries communication-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mesh as M
from repro.core import parallel as PP


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "squared_relu":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def mlp_init(key, d_model: int, d_ff: int, act: str, axes: M.MeshAxes, *,
             gated: bool, bias: bool = False, dtype=jnp.bfloat16, stack=(),
             abstract=False):
    # gate and up are separate weights: a fused (2*d_ff) matrix column-
    # sharded over y would change global layout meaning with G_y
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": PP.tp_linear_init(k1, d_model, d_ff, axes, dtype=dtype,
                                stack=stack, abstract=abstract),
        "wo": PP.tp_linear_init(k2, d_ff, d_model, axes, in_shard="y",
                                out_shard="x", dtype=dtype, stack=stack,
                                abstract=abstract),
    }
    if gated:
        p["wg"] = PP.tp_linear_init(k3, d_model, d_ff, axes, dtype=dtype,
                                    stack=stack, abstract=abstract)
    if bias:
        p["bi"] = PP.tp_bias_init(d_ff, axes, dtype=dtype, stack=stack,
                                  abstract=abstract)
        p["bo"] = PP.tp_bias_init(d_model, axes, out_shard="x", dtype=dtype,
                                  stack=stack, abstract=abstract)
    return p


def mlp_apply(p, h, act: str, axes: M.MeshAxes, *, gated: bool):
    u = PP.tp_matmul(h, p["wi"], axes, "x", "y")
    if "bi" in p:
        u = u + p["bi"]
    if gated:
        g = PP.tp_matmul(h, p["wg"], axes, "x", "y")
        hidden = _act(act, g) * u
    else:
        hidden = _act(act, u)
    o = PP.tp_matmul(hidden, p["wo"], axes, "y", "x")
    if "bo" in p:
        o = o + p["bo"]
    return o
