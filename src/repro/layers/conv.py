"""Channel-parallel 2D convolution — the paper's §3 extension of
Algorithm 1 to conv layers ("treating k and n as the number of input and
output channels").

A 3x3 conv is the contraction Y[p, Cout] = sum_k X_k[p, Cin] W[k, Cin,
Cout] over the 9 shifted views X_k. The weight is stored (K*K, Cin, Cout)
with Cin over the contraction axis and Cout over (out_axis, z) — the
offset dim is NOT fused into Cin (a fused (9*Cin) row shard would change
global layout meaning with G_x, the same trap as fused QKV). The local
partial sums over all 9 offsets happen *before* the single all-reduce, so
the collective volume matches the paper's per-layer model exactly (one AR
of the output per conv).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.partition import Boxed


def _shifted_views(x, K: int, stride: int = 1):
    """x: (B, H, W, C) -> list of K*K views (B, H', W', C), zero-padded."""
    pad = K // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    B, Hp, Wp, C = xp.shape
    H, W = x.shape[1], x.shape[2]
    Ho, Wo = -(-H // stride), -(-W // stride)
    views = []
    for di in range(K):
        for dj in range(K):
            v = xp[:, di:di + H:stride, dj:dj + W:stride, :]
            views.append(v)
    return views, Ho, Wo


def _conv_partial(x, w, K: int, stride: int):
    """Local partial conv: sum_k view_k @ w[k]. x (B,H,W,Cin_l);
    w (K*K, Cin_l, Cout_l)."""
    views, Ho, Wo = _shifted_views(x, K, stride)
    B = x.shape[0]
    acc = None
    for k, v in enumerate(views):
        t = jax.lax.dot_general(
            v.reshape(B * Ho * Wo, -1), w[k],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc = t if acc is None else acc + t
    return acc.reshape(B, Ho, Wo, w.shape[-1]).astype(x.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def tp_conv(x, w, axes: M.MeshAxes, in_shard: Optional[str] = "x",
            out_shard: Optional[str] = "y", stride: int = 1,
            z_shard: bool = True):
    """Channel-parallel KxK conv with the paper's collective schedule:
    local partials over all offsets, one all-reduce over the contraction
    axis; backward all-reduce over the output axis (Algorithm 1).
    ``z_shard=False`` for tiny cout (e.g. the 3-channel output head)."""
    wf = M.all_gather(w, axes.z, dim=2) if z_shard else w
    y = _conv_partial(x, wf, int(math.isqrt(w.shape[0])), stride)
    return M.psum(y, PP._logical(axes, in_shard))


def _tpc_fwd(x, w, axes, in_shard, out_shard, stride, z_shard):
    wf = M.all_gather(w, axes.z, dim=2) if z_shard else w
    y = M.psum(_conv_partial(x, wf, int(math.isqrt(w.shape[0])), stride),
               PP._logical(axes, in_shard))
    return y, (x, w)


def _tpc_bwd(axes, in_shard, out_shard, stride, z_shard, res, dy):
    x, w = res
    K = int(math.isqrt(w.shape[0]))
    assert stride == 1, "stride>1 backward handled via explicit pooling"
    wf = M.all_gather(w, axes.z, dim=2) if z_shard else w
    # dX = sum_k shift_{-k}(dY) @ w[k]^T  (a correlation = conv with the
    # spatially-flipped kernel), then AR over the output axis
    w_t = jnp.flip(wf.reshape(K, K, *wf.shape[1:]), axis=(0, 1))
    w_t = jnp.swapaxes(w_t.reshape(K * K, *wf.shape[1:]), 1, 2)
    dx = M.psum(_conv_partial(dy, w_t, K, 1),
                PP._logical(axes, out_shard)).astype(x.dtype)
    # dW[k] = view_k(X)^T @ dY, reduce-scattered over z
    views, Ho, Wo = _shifted_views(x, K, 1)
    B = x.shape[0]
    dyf = dy.reshape(B * Ho * Wo, -1)
    dws = [jax.lax.dot_general(
        v.reshape(B * Ho * Wo, -1), dyf, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) for v in views]
    dw = jnp.stack(dws, axis=0)
    if z_shard:
        dw = M.psum_scatter(dw, axes.z, dim=2)
    return dx, dw.astype(w.dtype)


tp_conv.defvjp(_tpc_fwd, _tpc_bwd)


def tp_conv_init(key, K: int, cin: int, cout: int, axes: M.MeshAxes, *,
                 in_shard="x", out_shard="y", dtype=jnp.float32, stack=(),
                 z_shard=True, abstract=False) -> Boxed:
    out_names = M._names(PP._logical(axes, out_shard)) \
        + (M._names(axes.z) if z_shard else ())
    spec = P(*([None] * (len(stack) + 1)),
             *axes.pspec(PP._logical(axes, in_shard),
                         out_names if out_names else None))
    shape = (*stack, K * K, cin, cout)
    if abstract:
        return Boxed(jax.ShapeDtypeStruct(shape, dtype), spec,
                     z_reduced=z_shard)
    s = 1.0 / math.sqrt(K * K * cin)
    v = (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
    return Boxed(v, spec, z_reduced=z_shard)


def group_norm_local(x, gamma, beta, n_groups_local: int, eps=1e-5):
    """GroupNorm over channel groups that never straddle shards (the
    caller aligns groups to the x-shard: G % G_x == 0 => fully local)."""
    B, H, W, C = x.shape
    g = x.reshape(B, H, W, n_groups_local, C // n_groups_local)
    mu = jnp.mean(g.astype(jnp.float32), axis=(1, 2, 4), keepdims=True)
    var = jnp.var(g.astype(jnp.float32), axis=(1, 2, 4), keepdims=True)
    gn = ((g - mu) * jax.lax.rsqrt(var + eps)).reshape(B, H, W, C)
    return (gn * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(x.dtype)
