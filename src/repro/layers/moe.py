"""Mixture-of-Experts under the 4D layout.

Expert placement exploits the paper's activation layout: the residual
stream is *replicated over y*, so sharding the expert bank over ``y`` makes
dispatch communication-free within the tensor group — every y-rank already
holds every token and simply computes its own E/G_y experts. The only
collective the MoE layer adds over a dense MLP is the final combine
all-reduce over ``y``, which *replaces* (at identical volume) the down
projection's all-reduce — plus the tiny router all-reduce over ``x``.
This is recorded in DESIGN.md as a consequence of the paper's layout, not
an extra trick: under Megatron-style 1D TP the same MoE needs either
expert all-to-alls or full activation gathers.

Dispatch is capacity-based with gather/scatter indexing (O(T*E_local)
bookkeeping memory, no (T, E, C) one-hot tensor).

Expert axis (g_expert > 1): the ``expert`` mesh axis shards the batch for
every dense layer (a second data axis) and subdivides each y-rank's
expert block — global expert ``e`` lives at y-rank ``e // (E/G_y)``,
expert-rank ``(e % (E/G_y)) // e_local`` with ``e_local =
E/(G_y*G_expert)`` (y-major, expert-inner, so the placement reduces to
today's y-only layout at g_expert = 1). Tokens reach off-rank experts in
their y block via a capacity-based dispatch buffer (g_expert, e_local,
capacity, d) exchanged with ``jax.lax.all_to_all`` over the expert axis
(combine is the reverse exchange); with ``OverlapConfig.expert_a2a`` the
round trip runs as ``collective_matmul.ring_a2a_expert`` — pairwise
ppermute exchanges interleaved with the per-source expert GEMMs, bitwise
the blocking layout with zero all-to-all HLO ops.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import collective_matmul as CMM
from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.partition import Boxed
from repro.layers.mlp import _act, mlp_apply, mlp_init


def moe_init(key, cfg, axes: M.MeshAxes, *, dtype=jnp.bfloat16, stack=(),
             abstract=False):
    mc = cfg.moe
    d, f = cfg.d_model, mc.d_expert
    if mc.n_experts % (axes.gy * axes.gexpert):
        raise ValueError(f"{mc.n_experts} experts not divisible by "
                         f"G_y*G_expert={axes.gy * axes.gexpert}")
    ks = jax.random.split(key, 4)
    gated = cfg.act != "squared_relu"
    up_n = 2 * f if gated else f
    p = {
        # router: contract x, replicated logits (E is tiny)
        "w_router": PP.tp_linear_init(ks[0], d, mc.n_experts, axes,
                                      in_shard="x", out_shard=None,
                                      dtype=jnp.float32, stack=stack,
                                      abstract=abstract),
        "w_up": PP.tp_expert_init(ks[1], mc.n_experts, d, up_n, axes,
                                  in_shard="x", out_shard=None, dtype=dtype,
                                  stack=stack, abstract=abstract),
        "w_down": PP.tp_expert_init(ks[2], mc.n_experts, f, d, axes,
                                    in_shard=None, out_shard="x",
                                    dtype=dtype, stack=stack,
                                    abstract=abstract),
    }
    if mc.n_shared:
        p["shared"] = mlp_init(ks[3], d, mc.n_shared * f, cfg.act, axes,
                               gated=gated, dtype=dtype, stack=stack,
                               abstract=abstract)
    return p


def _topk_gates(logits, mc):
    """Router scores -> (gates, indices). logits (T, E) fp32, replicated."""
    if mc.score_fn == "sigmoid":          # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
        vals, idx = jax.lax.top_k(scores, mc.top_k)
        gates = vals / (jnp.sum(vals, -1, keepdims=True) + 1e-20)
        gates = gates * mc.routed_scale
    else:                                  # softmax-topk (switch/dsv2 style)
        vals, idx = jax.lax.top_k(logits, mc.top_k)
        gates = jax.nn.softmax(vals, axis=-1)
    return gates, idx


def _aux_losses(logits, idx, mc):
    """Switch-style load-balance loss + router z-loss (replicated values)."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)                       # mean router prob
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (T, k, E)
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0) / mc.top_k  # load frac
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return mc.aux_loss_coef * lb + mc.z_loss_coef * z


def moe_apply(p, h, cfg, axes: M.MeshAxes):
    """h: (B, T, d/x) replicated over y, batch-sharded over (data, z,
    expert). Returns (out, aux_loss)."""
    mc = cfg.moe
    B, T, dx = h.shape
    n_tok = B * T
    p_ex = axes.gexpert
    e_block = mc.n_experts // axes.gy      # this y-rank's expert block
    e_local = e_block // p_ex              # experts on this (y, ex) rank
    y_start = M.axis_index(axes.y) * e_block
    gated = cfg.act != "squared_relu"

    hf = h.reshape(n_tok, dx)
    logits = PP.tp_matmul(hf, p["w_router"].astype(hf.dtype), axes,
                          "x", None).astype(jnp.float32)
    gates, idx = _topk_gates(logits, mc)               # (n_tok, k)
    aux = _aux_losses(logits, idx, mc)

    capacity = max(int(mc.capacity_factor * n_tok * mc.top_k
                       / mc.n_experts), 4)

    # ---- gather-based dispatch to the y-block's experts ----------------
    local = idx - y_start                              # (n_tok, k)
    ok = (local >= 0) & (local < e_block)
    eflat = jnp.where(ok, local, e_block)              # e_block = "drop"
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(eflat.reshape(-1), e_block + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1               # (n_tok*k, e+1)
    pos = jnp.take_along_axis(pos, eflat.reshape(-1, 1), axis=1)[:, 0]
    fits = (pos < capacity) & ok.reshape(-1)
    slot = jnp.where(fits, eflat.reshape(-1) * capacity + pos,
                     e_block * capacity)
    # token id owning each (expert, cap) slot
    tok_ids = jnp.tile(jnp.arange(n_tok)[:, None],
                       (1, mc.top_k)).reshape(-1)
    owner = jnp.zeros(e_block * capacity + 1, jnp.int32).at[slot].set(
        tok_ids, mode="drop")[:-1]
    filled = jnp.zeros(e_block * capacity + 1, jnp.bool_).at[slot].set(
        True, mode="drop")[:-1]
    gate_of_slot = jnp.zeros(e_block * capacity + 1, jnp.float32).at[
        slot].set(gates.reshape(-1), mode="drop")[:-1]

    xe = jnp.take(hf, owner, axis=0)                   # (e*cap, d/x)
    xe = jnp.where(filled[:, None], xe, 0)

    # ---- expert FFN (4D tp inside each expert) -------------------------
    def ffn(block):
        """block (e_local, C, d/x) -> (e_local, C, d/x); gates stay at
        the source rank, applied after the combine exchange."""
        u = PP.tp_batched_matmul(block, p["w_up"], axes, "x", None)
        if gated:
            g, u2 = jnp.split(u, 2, axis=-1)
            hidden = _act(cfg.act, g) * u2
        else:
            hidden = _act(cfg.act, u)
        return PP.tp_batched_matmul(hidden, p["w_down"], axes, None, "x")

    if p_ex > 1:
        # dispatch buffer, dim 0 = destination expert-rank (the queue
        # index eflat = t*e_local + local_e already orders it that way)
        buf = xe.reshape(p_ex, e_local, capacity, dx)
        if axes.overlap.expert_a2a:
            out_b = CMM.ring_a2a_expert(buf, axes.expert, ffn)
        else:
            recv = M.all_to_all(buf.reshape(p_ex * e_local, capacity, dx),
                                axes.expert, dim=0)
            recv = recv.reshape(p_ex, e_local, capacity, dx).transpose(
                1, 0, 2, 3).reshape(e_local, p_ex * capacity, dx)
            y = ffn(recv)
            y = y.reshape(e_local, p_ex, capacity, dx).transpose(
                1, 0, 2, 3).reshape(p_ex * e_local, capacity, dx)
            out_b = M.all_to_all(y, axes.expert, dim=0)
        out_e = out_b.reshape(e_block * capacity, dx)
    else:
        out_e = ffn(xe.reshape(e_block, capacity, dx))
        out_e = out_e.reshape(e_block * capacity, dx)
    out_e = out_e * gate_of_slot[:, None].astype(out_e.dtype)

    # ---- combine: scatter-add back to tokens, all-reduce over y --------
    combined = jnp.zeros((n_tok, dx), out_e.dtype).at[owner].add(
        jnp.where(filled[:, None], out_e, 0))
    combined = PP.ar_bwd_identity(combined, axes.y)
    out = combined.reshape(B, T, dx)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], h, cfg.act, axes, gated=gated)
    return out, aux
