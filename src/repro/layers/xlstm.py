"""xLSTM blocks (mLSTM and sLSTM) under the 4D layout.

Projections in/out of the cells are paper normal/transposed tp layers; the
cells themselves are per-head (heads sharded over ``y``) with exponential
gating and the xLSTM paper's max-stabilizer. The mLSTM matrix-memory
recurrence and the sLSTM scalar-memory recurrence are sequential scans over
time (per-channel / per-head local — the "embarrassingly parallel" class in
the paper's taxonomy); decode is a single-step state update.

Block shapes follow the xLSTM paper: mLSTM block = up-proj x2 (pf=2), causal
conv4, per-head q/k/v, cell, learnable skip, gated output, down-proj;
sLSTM block = conv4 on the i/f path, 4-gate cell with per-head recurrent
matrices, then a pf=4/3 gated MLP.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.partition import Boxed
from repro.layers.mamba import causal_conv1d


def _y_param(shape, axes, dtype, init_fn, stack=(), abstract=False):
    spec = P(*([None] * len(stack)), *axes.pspec(axes.y),
             *([None] * (len(shape) - 1)))
    full = (*stack, *shape)
    if abstract:
        return Boxed(jax.ShapeDtypeStruct(full, dtype), spec)
    return Boxed(init_fn(full).astype(dtype), spec)


def slstm_ff_dim(cfg) -> int:
    """pf=4/3 MLP width rounded up to a shardable multiple of 64."""
    return -(-int(cfg.xlstm.proj_factor_slstm * cfg.d_model) // 64) * 64


# ---------------------------------------------------------------------- #
# mLSTM
# ---------------------------------------------------------------------- #

def mlstm_init(key, cfg, axes: M.MeshAxes, *, dtype=jnp.bfloat16, stack=(),
               abstract=False):
    xc = cfg.xlstm
    d = cfg.d_model
    di = int(xc.proj_factor_mlstm * d)          # inner dim (pf = 2)
    nh = cfg.n_heads
    dh = di // nh
    ks = jax.random.split(key, 9)
    norm = lambda k, s: jax.random.normal(k, s) / math.sqrt(s[-1])
    return {
        # main and gate up-projections kept separate (mesh-invariant)
        "w_up": PP.tp_linear_init(ks[0], d, di, axes, dtype=dtype,
                                  stack=stack, abstract=abstract),
        "w_upg": PP.tp_linear_init(ks[8], d, di, axes, dtype=dtype,
                                   stack=stack, abstract=abstract),
        "conv_w": _y_param((di, xc.conv_kernel), axes, dtype,
                           lambda s: jax.random.normal(ks[1], s) * 0.1,
                           stack, abstract),
        "conv_b": _y_param((di,), axes, dtype, jnp.zeros, stack, abstract),
        # per-head q/k/v over the conv path (v from the pre-conv path)
        "w_q": _y_param((nh, dh, dh), axes, dtype,
                        lambda s: norm(ks[2], s), stack, abstract),
        "w_k": _y_param((nh, dh, dh), axes, dtype,
                        lambda s: norm(ks[3], s), stack, abstract),
        "w_v": _y_param((nh, dh, dh), axes, dtype,
                        lambda s: norm(ks[4], s), stack, abstract),
        # i/f gates: full contraction over the y-sharded inner dim
        "w_if": PP.tp_linear_init(ks[5], di, 2 * nh, axes, in_shard="y",
                                  out_shard=None, dtype=jnp.float32,
                                  stack=stack, abstract=abstract),
        "b_if": Boxed(jax.ShapeDtypeStruct((*stack, 2 * nh), jnp.float32)
                      if abstract else jnp.zeros((*stack, 2 * nh)),
                      P(*([None] * (len(stack) + 1)))),
        "skip": _y_param((di,), axes, dtype, jnp.ones, stack, abstract),
        "gn": _y_param((di,), axes, dtype, jnp.ones, stack, abstract),
        "w_down": PP.tp_linear_init(ks[6], di, d, axes, in_shard="y",
                                    out_shard="x", dtype=dtype, stack=stack,
                                    abstract=abstract),
    }


def _mlstm_cell_step(carry, inp):
    """One step of the stabilized mLSTM recurrence (all per-head local).

    carry: C (B,nh,dk,dv), n (B,nh,dk), m (B,nh)
    inp: q,k,v (B,nh,dk|dv), i_raw,f_raw (B,nh)
    """
    C, n, m, = carry
    q, k, v, ir, fr = inp
    logf = jax.nn.log_sigmoid(fr)
    m_new = jnp.maximum(logf + m, ir)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(ir - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] \
        * (k[..., :, None] * v[..., None, :])
    n = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return (C, n, m_new), h


def _mlstm_scan(q, k, v, ir, fr, state):
    """q,k,v: (B,T,nh,dh) fp32; ir,fr: (B,T,nh). state: (C,n,m)."""
    xs = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), (q, k, v, ir, fr))
    state, hs = jax.lax.scan(_mlstm_cell_step, state, xs)
    return jnp.moveaxis(hs, 0, 1), state


def mlstm_state_init(batch, nh_local, dh, dtype=jnp.float32):
    return (jnp.zeros((batch, nh_local, dh, dh), dtype),
            jnp.zeros((batch, nh_local, dh), dtype),
            jnp.full((batch, nh_local), -1e30, dtype))


def mlstm_apply(p, h, cfg, axes: M.MeshAxes, *, mode="train", state=None):
    xc = cfg.xlstm
    d = cfg.d_model
    di = int(xc.proj_factor_mlstm * d)
    nh_l = cfg.n_heads // axes.gy
    dh = di // cfg.n_heads
    B, T, _ = h.shape

    main = PP.tp_matmul(h, p["w_up"], axes, "x", "y")   # (B,T,di_l)
    gate = PP.tp_matmul(h, p["w_upg"], axes, "x", "y")

    if mode == "decode":
        conv_in = jnp.concatenate([state["conv"], main], axis=1)
        xconv = jnp.einsum("bkd,dk->bd", conv_in, p["conv_w"]) \
            + p["conv_b"]
        xconv = jax.nn.silu(xconv)[:, None, :]
        new_conv = conv_in[:, 1:, :]
    else:
        xconv = jax.nn.silu(causal_conv1d(main, p["conv_w"], p["conv_b"]))
        new_conv = main[:, -(xc.conv_kernel - 1):, :]

    def heads(t):
        return t.reshape(B, -1, nh_l, dh)
    q = jnp.einsum("bthd,hde->bthe", heads(xconv), p["w_q"])
    k = jnp.einsum("bthd,hde->bthe", heads(xconv), p["w_k"]) / math.sqrt(dh)
    v = jnp.einsum("bthd,hde->bthe", heads(main), p["w_v"])
    iff = PP.tp_matmul(xconv, p["w_if"].astype(xconv.dtype), axes, "y",
                       None).astype(jnp.float32) + p["b_if"]
    i_full, f_full = jnp.split(iff, 2, axis=-1)          # (B,T,nh) global nh
    hi = M.axis_index(axes.y) * nh_l
    ir = jax.lax.dynamic_slice_in_dim(i_full, hi, nh_l, axis=-1)
    fr = jax.lax.dynamic_slice_in_dim(f_full, hi, nh_l, axis=-1)

    cell_state = (state["C"], state["n"], state["m"]) if mode == "decode" \
        else mlstm_state_init(B, nh_l, dh)
    hs, (C, n, m) = _mlstm_scan(q.astype(jnp.float32),
                                k.astype(jnp.float32),
                                v.astype(jnp.float32), ir, fr, cell_state)
    hs = hs.reshape(B, -1, nh_l * dh)

    # per-head group-norm (local heads), learnable skip, output gate
    hg = hs.reshape(B, -1, nh_l, dh)
    mu = jnp.mean(hg, axis=-1, keepdims=True)
    var = jnp.var(hg, axis=-1, keepdims=True)
    hg = ((hg - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, -1, nh_l * dh)
    out = hg * p["gn"].astype(jnp.float32) \
        + p["skip"].astype(jnp.float32) * xconv.astype(jnp.float32)
    out = (out * jax.nn.silu(gate.astype(jnp.float32))).astype(h.dtype)
    o = PP.tp_matmul(out, p["w_down"], axes, "y", "x")
    new_state = {"conv": new_conv, "C": C, "n": n, "m": m}
    return o, new_state


# ---------------------------------------------------------------------- #
# sLSTM
# ---------------------------------------------------------------------- #

def slstm_init(key, cfg, axes: M.MeshAxes, *, dtype=jnp.bfloat16, stack=(),
               abstract=False):
    xc = cfg.xlstm
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    dff = slstm_ff_dim(cfg)
    ks = jax.random.split(key, 6)
    norm = lambda k, s: jax.random.normal(k, s) / math.sqrt(s[-1])
    return {
        "conv_w": _y_param((d, xc.conv_kernel), axes, dtype,
                           lambda s: jax.random.normal(ks[0], s) * 0.1,
                           stack, abstract),
        "conv_b": _y_param((d,), axes, dtype, jnp.zeros, stack, abstract),
        # W: x -> 4 gates, one weight per gate (mesh-invariant layout)
        "w_gz": PP.tp_linear_init(jax.random.fold_in(ks[1], 0), d, d, axes,
                                  dtype=dtype, stack=stack,
                                  abstract=abstract),
        "w_gi": PP.tp_linear_init(jax.random.fold_in(ks[1], 1), d, d, axes,
                                  dtype=dtype, stack=stack,
                                  abstract=abstract),
        "w_gf": PP.tp_linear_init(jax.random.fold_in(ks[1], 2), d, d, axes,
                                  dtype=dtype, stack=stack,
                                  abstract=abstract),
        "w_go": PP.tp_linear_init(jax.random.fold_in(ks[1], 3), d, d, axes,
                                  dtype=dtype, stack=stack,
                                  abstract=abstract),
        # per-head recurrent matrices h_{t-1} -> 4 gates
        "r_gates": _y_param((nh, dh, 4 * dh), axes, dtype,
                            lambda s: norm(ks[2], s), stack, abstract),
        "b_gates": _y_param((d, 4), axes, jnp.float32,
                            lambda s: jnp.zeros(s), stack, abstract),
        "gn": _y_param((d,), axes, dtype, jnp.ones, stack, abstract),
        "w_o": PP.tp_linear_init(ks[3], d, d, axes, in_shard="y",
                                 out_shard="x", dtype=dtype, stack=stack,
                                 abstract=abstract),
        "w_up": PP.tp_linear_init(ks[4], d, 2 * dff, axes, dtype=dtype,
                                  stack=stack, abstract=abstract),
        "w_down": PP.tp_linear_init(ks[5], dff, d, axes, in_shard="y",
                                    out_shard="x", dtype=dtype, stack=stack,
                                    abstract=abstract),
    }


def _slstm_cell_step(r_gates, carry, wx):
    """carry: c, n, hprev, m — each (B, nh, dh) / m (B, nh).
    wx: the W x_t + b part, (B, nh, dh, 4)."""
    c, n, hprev, m = carry
    rec = jnp.einsum("bhd,hde->bhe", hprev, r_gates)
    rec = rec.reshape(*hprev.shape[:2], -1, 4)
    zt, it, ft, ot = [wx[..., j] + rec[..., j] for j in range(4)]
    # per-head scalar stabilizer (max over the head's channels)
    m_new = jnp.maximum(jnp.max(ft, -1) + m, jnp.max(it, -1))
    ip = jnp.exp(it - m_new[..., None])
    fp = jnp.exp(ft + (m - m_new)[..., None])
    c = fp * c + ip * jnp.tanh(zt)
    n = fp * n + ip
    hnew = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
    return (c, n, hnew, m_new), hnew


def slstm_state_init(batch, nh_local, dh, dtype=jnp.float32):
    z = jnp.zeros((batch, nh_local, dh), dtype)
    return {"c": z, "n": z + 1e-6, "h": z,
            "m": jnp.zeros((batch, nh_local), dtype)}


def slstm_apply(p, h, cfg, axes: M.MeshAxes, *, mode="train", state=None):
    xc = cfg.xlstm
    d = cfg.d_model
    nh_l = cfg.n_heads // axes.gy
    dh = d // cfg.n_heads
    B, T, _ = h.shape

    gz = PP.tp_matmul(h, p["w_gz"], axes, "x", "y")      # (B,T,d_l)
    gi = PP.tp_matmul(h, p["w_gi"], axes, "x", "y")
    gf = PP.tp_matmul(h, p["w_gf"], axes, "x", "y")
    go = PP.tp_matmul(h, p["w_go"], axes, "x", "y")
    wx = jnp.stack([gz, gi, gf, go], axis=-1)
    wx = wx.reshape(B, T, nh_l, dh, 4).astype(jnp.float32)
    # conv4+silu on the i-gate pre-activations (time-local mixing)
    iwx = wx[..., 1]
    flat = lambda t: t.reshape(B, T, nh_l * dh)
    if mode == "decode":
        cin = jnp.concatenate([state["conv"], flat(iwx).astype(h.dtype)],
                              axis=1)
        iconv = jax.nn.silu(jnp.einsum("bkd,dk->bd", cin, p["conv_w"])
                            + p["conv_b"])[:, None]
        new_conv = cin[:, 1:, :]
        iwx = iconv.reshape(B, 1, nh_l, dh).astype(jnp.float32)
    else:
        iconv = jax.nn.silu(causal_conv1d(flat(iwx).astype(h.dtype),
                                          p["conv_w"], p["conv_b"]))
        new_conv = flat(iwx).astype(h.dtype)[:, -(xc.conv_kernel - 1):, :]
        iwx = iconv.reshape(B, T, nh_l, dh).astype(jnp.float32)
    wx = jnp.stack([wx[..., 0], iwx, wx[..., 2], wx[..., 3]], axis=-1)
    # b_gates is already y-sharded: local (d/gy, 4) == (nh_l*dh, 4)
    wx = wx + p["b_gates"].reshape(nh_l, dh, 4)[None, None]

    cell0 = state["cell"] if mode == "decode" \
        else slstm_state_init(B, nh_l, dh)
    carry0 = (cell0["c"], cell0["n"], cell0["h"], cell0["m"])
    step = lambda c, x: _slstm_cell_step(
        p["r_gates"].reshape(nh_l, dh, 4 * dh).astype(jnp.float32), c, x)
    carry, hs = jax.lax.scan(step, carry0, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)                          # (B,T,nh_l,dh)

    mu = jnp.mean(hs, -1, keepdims=True)
    var = jnp.var(hs, -1, keepdims=True)
    hs = ((hs - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, T, nh_l * dh)
    hs = (hs * p["gn"].astype(jnp.float32)).astype(h.dtype)
    o = PP.tp_matmul(hs, p["w_o"], axes, "y", "x")

    # post-cell gated MLP (pf = 4/3)
    u = PP.tp_matmul(o, p["w_up"], axes, "x", "y")
    g, u2 = jnp.split(u, 2, axis=-1)
    o2 = PP.tp_matmul(jax.nn.gelu(g) * u2, p["w_down"], axes, "y", "x")
    out = o + o2
    new_state = {"conv": new_conv,
                 "cell": {"c": carry[0], "n": carry[1], "h": carry[2],
                          "m": carry[3]}}
    return out, new_state


def xlstm_state_spec(cfg, axes: M.MeshAxes, batch_global, kind: str, *,
                     dtype=jnp.float32, seqshard: bool = False):
    xc = cfg.xlstm
    nh = cfg.n_heads
    d = cfg.d_model
    bspec = None if seqshard else axes.batch_axes()
    if kind == "mlstm":
        di = int(xc.proj_factor_mlstm * d)
        dh = di // nh
        return {
            "conv": (jax.ShapeDtypeStruct(
                (batch_global, xc.conv_kernel - 1, di), jnp.bfloat16),
                axes.pspec(bspec, None, axes.y)),
            "C": (jax.ShapeDtypeStruct((batch_global, nh, dh, dh), dtype),
                  axes.pspec(bspec, axes.y, None, None)),
            "n": (jax.ShapeDtypeStruct((batch_global, nh, dh), dtype),
                  axes.pspec(bspec, axes.y, None)),
            "m": (jax.ShapeDtypeStruct((batch_global, nh), dtype),
                  axes.pspec(bspec, axes.y)),
        }
    dh = d // nh
    return {
        "conv": (jax.ShapeDtypeStruct((batch_global, xc.conv_kernel - 1, d),
                                      jnp.bfloat16),
                 axes.pspec(bspec, None, axes.y)),
        "cell": {
            "c": (jax.ShapeDtypeStruct((batch_global, nh, dh), dtype),
                  axes.pspec(bspec, axes.y, None)),
            "n": (jax.ShapeDtypeStruct((batch_global, nh, dh), dtype),
                  axes.pspec(bspec, axes.y, None)),
            "h": (jax.ShapeDtypeStruct((batch_global, nh, dh), dtype),
                  axes.pspec(bspec, axes.y, None)),
            "m": (jax.ShapeDtypeStruct((batch_global, nh), dtype),
                  axes.pspec(bspec, axes.y)),
        },
    }
