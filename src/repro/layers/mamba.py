"""Mamba (S6) mixer under the 4D layout (used by jamba).

The in/out projections are paper normal/transposed tp layers (that is where
the FLOPs are); the selective scan itself is per-channel and therefore
embarrassingly parallel over the y-sharded inner dim — exactly the class of
layer the paper calls "trivial to parallelize". The scan is chunked
(sequential over chunks, associative-scan within a chunk) to bound the
(B, T, d, N) state-expansion working set; the Pallas kernel in
repro.kernels.selective_scan mirrors the chunk body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core.partition import Boxed


def _y_param(shape, axes, dtype, init_fn, stack=(), abstract=False):
    """A per-inner-channel param sharded over y on its first dim."""
    spec = P(*([None] * len(stack)), *axes.pspec(axes.y),
             *([None] * (len(shape) - 1)))
    full = (*stack, *shape)
    if abstract:
        return Boxed(jax.ShapeDtypeStruct(full, dtype), spec)
    return Boxed(init_fn(full).astype(dtype), spec)


def mamba_init(key, cfg, axes: M.MeshAxes, *, dtype=jnp.bfloat16, stack=(),
               abstract=False):
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.expand * d
    dt_rank = mc.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 7)
    p = {
        # x-path and gate-path projections kept separate (mesh-invariant
        # global layout; a fused 2*di column shard would not be)
        "w_in": PP.tp_linear_init(ks[0], d, di, axes, dtype=dtype,
                                  stack=stack, abstract=abstract),
        "w_gate": PP.tp_linear_init(ks[5], d, di, axes, dtype=dtype,
                                    stack=stack, abstract=abstract),
        "w_x": PP.tp_linear_init(ks[1], di, dt_rank + 2 * mc.d_state, axes,
                                 in_shard="y", out_shard=None, dtype=dtype,
                                 stack=stack, abstract=abstract),
        "w_dt": PP.tp_linear_init(ks[2], dt_rank, di, axes, in_shard=None,
                                  out_shard="y", dtype=dtype, stack=stack,
                                  abstract=abstract),
        "w_out": PP.tp_linear_init(ks[3], di, d, axes, in_shard="y",
                                   out_shard="x", dtype=dtype, stack=stack,
                                   abstract=abstract),
        "conv_w": _y_param((di, mc.d_conv), axes, dtype,
                           lambda s: jax.random.normal(ks[6], s) * 0.1,
                           stack, abstract),
        "conv_b": _y_param((di,), axes, dtype, lambda s: jnp.zeros(s),
                           stack, abstract),
        "b_dt": _y_param((di,), axes, jnp.float32,
                         lambda s: jnp.full(s, -4.6),  # softplus^-1(0.01)
                         stack, abstract),
        "A_log": _y_param((di, mc.d_state), axes, jnp.float32,
                          lambda s: jnp.log(jnp.broadcast_to(
                              jnp.arange(1, mc.d_state + 1, dtype=jnp.float32),
                              s)), stack, abstract),
        "D": _y_param((di,), axes, jnp.float32, lambda s: jnp.ones(s),
                      stack, abstract),
    }
    return p


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B, T, d); w: (d, K)."""
    K = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, k:k + x.shape[1], :] * w[:, k] for k in range(K))
    return out + b


def ssm_scan_chunked(x, dt, A, Bc, Cc, *, chunk: int = 128, s0=None):
    """Selective scan s_t = exp(dt_t A) s_{t-1} + dt_t B_t x_t; y = C_t s_t.

    x, dt: (B, T, d); A: (d, N); Bc, Cc: (B, T, N).
    Returns (y (B, T, d), final_state (B, d, N)).
    """
    B, T, d = x.shape
    N = A.shape[-1]
    nc = max(T // chunk, 1)
    ck = T // nc
    xs = (x.reshape(B, nc, ck, d), dt.reshape(B, nc, ck, d),
          Bc.reshape(B, nc, ck, N), Cc.reshape(B, nc, ck, N))
    xs = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), xs)
    s_init = jnp.zeros((B, d, N), jnp.float32) if s0 is None else s0

    def body(s, inp):
        xc, dtc, bc, cc = inp
        dtf = dtc.astype(jnp.float32)
        dA = jnp.exp(dtf[..., None] * A)                     # (B,ck,d,N)
        dBx = (dtf * xc.astype(jnp.float32))[..., None] \
            * bc.astype(jnp.float32)[:, :, None, :]
        pA, pb = jax.lax.associative_scan(
            lambda a, b: (a[0] * b[0], a[1] * b[0] + b[1]),
            (dA, dBx), axis=1)
        states = pb + pA * s[:, None]                        # (B,ck,d,N)
        y = jnp.einsum("btdn,btn->btd", states,
                       cc.astype(jnp.float32))
        return states[:, -1], y.astype(x.dtype)

    s_fin, ys = jax.lax.scan(body, s_init, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, d)
    return y, s_fin


def mamba_apply(p, h, cfg, axes: M.MeshAxes, *, mode="train", state=None,
                chunk: int = 128):
    """h: (B, T, d/x) replicated over y -> (out, new_state).

    state (decode): {"conv": (B, K-1, di_l), "ssm": (B, di_l, N)}."""
    mc = cfg.mamba
    d = cfg.d_model
    di_l = mc.expand * d // axes.gy
    B, T, _ = h.shape

    xs = PP.tp_matmul(h, p["w_in"], axes, "x", "y")      # (B,T,di_l)
    zgate = PP.tp_matmul(h, p["w_gate"], axes, "x", "y")

    new_state = state
    if mode in ("train", "prefill"):
        xc = causal_conv1d(xs, p["conv_w"], p["conv_b"])
        xc = jax.nn.silu(xc)
        xdbc = PP.tp_matmul(xc, p["w_x"], axes, "y", None)
        dt_rank = mc.dt_rank or -(-d // 16)
        dt_low, bc, cc = jnp.split(xdbc, [dt_rank, dt_rank + mc.d_state],
                                   axis=-1)
        dt = jax.nn.softplus(
            PP.tp_matmul(dt_low, p["w_dt"], axes, None, "y")
            + p["b_dt"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"])
        y, s_fin = ssm_scan_chunked(xc, dt, A, bc, cc, chunk=chunk)
        if mode == "prefill":
            new_state = {"conv": xs[:, -(mc.d_conv - 1):, :],
                         "ssm": s_fin}
    elif mode == "decode":
        conv_st = jnp.concatenate([state["conv"], xs], axis=1)  # (B,K,di_l)
        xc = jnp.einsum("bkd,dk->bd", conv_st, p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)[:, None, :]                 # (B,1,di_l)
        xdbc = PP.tp_matmul(xc, p["w_x"], axes, "y", None)
        dt_rank = mc.dt_rank or -(-d // 16)
        dt_low, bc, cc = jnp.split(xdbc, [dt_rank, dt_rank + mc.d_state],
                                   axis=-1)
        dt = jax.nn.softplus(
            PP.tp_matmul(dt_low, p["w_dt"], axes, None, "y")
            + p["b_dt"].astype(jnp.float32))             # (B,1,di_l)
        dA = jnp.exp(dt[:, 0].astype(jnp.float32)[..., None]
                     * (-jnp.exp(p["A_log"])))           # (B,di_l,N)
        dBx = (dt[:, 0].astype(jnp.float32)
               * xc[:, 0].astype(jnp.float32))[..., None] \
            * bc[:, 0].astype(jnp.float32)[:, None, :]
        s = state["ssm"] * dA + dBx
        y = jnp.einsum("bdn,bn->bd", s,
                       cc[:, 0].astype(jnp.float32))[:, None, :]
        y = y.astype(h.dtype)
        new_state = {"conv": conv_st[:, 1:, :], "ssm": s}
    else:
        raise ValueError(mode)

    y = y.astype(jnp.float32) + p["D"].astype(jnp.float32) \
        * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(zgate.astype(jnp.float32))).astype(h.dtype)
    out = PP.tp_matmul(y, p["w_out"], axes, "y", "x")
    return out, new_state


def mamba_state_spec(cfg, axes: M.MeshAxes, batch_global, *,
                     dtype=jnp.bfloat16, seqshard: bool = False):
    mc = cfg.mamba
    di = mc.expand * cfg.d_model
    bax = None if seqshard else axes.batch_axes()  # batch=1: replicate
    bspec3 = axes.pspec(bax, None, axes.y)
    bspec3n = axes.pspec(bax, axes.y, None)
    return {
        "conv": (jax.ShapeDtypeStruct((batch_global, mc.d_conv - 1, di),
                                      dtype), bspec3),
        "ssm": (jax.ShapeDtypeStruct((batch_global, di, mc.d_state),
                                     jnp.float32), bspec3n),
    }
