"""Attention mixers under the 4D layout.

Heads are sharded over ``y`` (the output axis of the fused QKV projection,
a paper "normal" layer); the output projection is a paper "transposed"
layer (contract over ``y``, all-reduce over ``y``), returning the residual
to its x-sharded layout with zero boundary communication (§4.1).

Variants: MHA/GQA (optionally sliding-window and/or qk-norm), cross
attention (whisper), and DeepSeek MLA (low-rank latent KV, with the
absorbed-matmul decode path).

Decode supports two cache layouts:
  * batch-sharded (default): cache (B_local, S, kv_local, hd)
  * sequence-sharded over ``data`` (long-context, global_batch=1): partial
    attention per shard merged with a log-sum-exp psum — a beyond-paper
    extension recorded in DESIGN.md.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import mesh as M
from repro.core import parallel as PP
from repro.core import trace
from repro.core.partition import Boxed
from repro.layers.rotary import apply_rope, apply_rope_interleaved_neox

NEG_INF = -1e30


# ---------------------------------------------------------------------- #
# plain (replicated-param) per-head RMSNorm, used for qk-norm and MLA
# latent norms — head_dim / latent dims are never sharded.
# ---------------------------------------------------------------------- #

def _plain_rms(x, gamma, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)
            ).astype(x.dtype)


def _softmax_fp32(scores):
    """Softmax accumulated in fp32 regardless of the activation dtype.

    Every attention path routes through this (or the fp32 (m, l, acc)
    online-softmax carries): a reduced-precision exp/sum would break the
    cross-hop rescaling parity the ring-attention schedule relies on —
    tests/test_ring_attention.py pins the bf16-vs-fp32 tolerance."""
    return jax.nn.softmax(scores.astype(jnp.float32), axis=-1)


# ---------------------------------------------------------------------- #
# attention core (pure jnp oracle; the Pallas flash kernel in
# repro.kernels mirrors this and is validated against it)
# ---------------------------------------------------------------------- #

def attn_core(q, k, v, *, causal: bool = True, window: int = 0,
              q_pos0=0, scale: Optional[float] = None,
              chunked_threshold: int = 2048):
    """q: (B, Tq, nq, d); k/v: (B, Tk, nkv, d); GQA via head grouping.

    ``q_pos0`` is the absolute position of q[:, 0] (for cached decode).
    ``window`` > 0 enables sliding-window attention (mistral-style).
    Long sequences route to the chunked online-softmax path (flash-style
    O(T*chunk) memory — the jnp analogue of kernels/flash_attention)."""
    B, Tq, nq, d = q.shape
    Tk, nkv = k.shape[1], k.shape[2]
    if max(Tq, Tk) > chunked_threshold:
        return attn_core_chunked(q, k, v, causal=causal, window=window,
                                 q_pos0=q_pos0, scale=scale)
    g = nq // nkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(B, Tq, nkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * scale
    iq = (jnp.arange(Tq) + q_pos0)[:, None]
    jk = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= iq >= jk
    if window > 0:
        mask &= (iq - jk) < window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = _softmax_fp32(scores)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Tq, nq, v.shape[-1]).astype(q.dtype)  # dv may != dq (MLA)


def attn_core_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                      q_pos0=0, scale: Optional[float] = None,
                      bq: int = 512, bk: int = 1024):
    """Flash-style online-softmax attention in pure jnp: nested scans over
    q and kv chunks with fp32 (m, l, acc) carries. This is what the Pallas
    kernel does on TPU; the jnp version keeps the dry-run HLO honest about
    memory (no (T, S) score materialization) and compiles fast."""
    B, Tq, nq, d = q.shape
    Tk, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    # pad to chunk multiples
    pq = (-Tq) % bq
    pk = (-Tk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nQ, nK = qp.shape[1] // bq, kp.shape[1] // bk

    qc = jnp.moveaxis(qp.reshape(B, nQ, bq, nkv, g, d), 1, 0)
    kc = jnp.moveaxis(kp.reshape(B, nK, bk, nkv, k.shape[-1]), 1, 0)
    vc = jnp.moveaxis(vp.reshape(B, nK, bk, nkv, v.shape[-1]), 1, 0)

    def q_step(_, qi_and_block):
        qi, qb = qi_and_block                       # qb (B, bq, nkv, g, d)
        qb = qb.astype(jnp.float32)

        def kv_step(carry, ki_and_kv):
            m, l, acc = carry
            ki, kb, vb = ki_and_kv
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb,
                           kb.astype(jnp.float32)) * scale
            iq = q_pos0 + qi * bq + jnp.arange(bq)[:, None]
            jk = ki * bk + jnp.arange(bk)[None, :]
            mask = jk < Tk
            if causal:
                mask &= iq >= jk
            if window > 0:
                mask &= (iq - jk) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1)
            acc = alpha[..., None] * acc + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), 0

        m0 = jnp.full((B, nkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, nkv, g, bq), jnp.float32)
        a0 = jnp.zeros((B, nkv, g, bq, v.shape[-1]), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nK), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,h,g,bq,d)
        return 0, jnp.moveaxis(out, 3, 1)             # (B,bq,h,g,d)

    _, outs = jax.lax.scan(q_step, 0, (jnp.arange(nQ), qc))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nQ * bq, nq, v.shape[-1])
    return out[:, :Tq].astype(q.dtype)


def attn_partial_init(B, Tq, nkv, g, dv):
    """Fresh fp32 online-softmax carry (m, l, acc) for
    :func:`attn_core_partial` — the 'nothing attended yet' state."""
    return (jnp.full((B, nkv, g, Tq), NEG_INF, jnp.float32),
            jnp.zeros((B, nkv, g, Tq), jnp.float32),
            jnp.zeros((B, nkv, g, Tq, dv), jnp.float32))


def attn_core_partial(q, k, v, carry, *, q_pos, k_pos,
                      causal: bool = True, window: int = 0,
                      scale: Optional[float] = None,
                      bq: int = 512, bk: int = 1024):
    """One *partial* online-softmax pass over a single KV block, carrying
    (m, l, acc) across calls — the jnp oracle of
    ``kernels.flash_attention_partial`` and the per-hop core of
    :func:`seq_attn`'s ring schedule.

    q: (B, Tq, nq, d) local queries; k/v: (B, Tk, nkv, dv) one KV block;
    ``q_pos``/``k_pos``: (Tq,)/(Tk,) *global* token positions of each
    local index (striped context parallelism hands in stride-g_seq
    vectors; they may be non-monotone). The carry is the fp32
    (m, l, acc) of :func:`attn_partial_init`; chain blocks then finalize
    with :func:`attn_partial_finalize`. Internally chunked like
    :func:`attn_core_chunked`, so no (Tq, Tk) score ever materializes.
    A query row whose keys are all masked passes its carry through
    unchanged (p is zeroed under the mask — a NEG_INF running max never
    leaks exp(0) mass into l)."""
    B, Tq, nq, d = q.shape
    Tk, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    m, l, acc = carry
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    pq = (-Tq) % bq
    pk = (-Tk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos.astype(jnp.int32), (0, pq))
    kpos = jnp.pad(k_pos.astype(jnp.int32), (0, pk))
    kvalid = jnp.pad(jnp.ones((Tk,), bool), (0, pk))
    nQ, nK = qp.shape[1] // bq, kp.shape[1] // bk

    qc = jnp.moveaxis(qp.reshape(B, nQ, bq, nkv, g, d), 1, 0)
    kc = jnp.moveaxis(kp.reshape(B, nK, bk, nkv, d), 1, 0)
    vc = jnp.moveaxis(vp.reshape(B, nK, bk, nkv, dv), 1, 0)
    mq = jnp.moveaxis(jnp.pad(m, ((0, 0),) * 3 + ((0, pq),),
                              constant_values=NEG_INF
                              ).reshape(B, nkv, g, nQ, bq), 3, 0)
    lq = jnp.moveaxis(jnp.pad(l, ((0, 0),) * 3 + ((0, pq),)
                              ).reshape(B, nkv, g, nQ, bq), 3, 0)
    aq = jnp.moveaxis(jnp.pad(acc, ((0, 0),) * 3 + ((0, pq), (0, 0))
                              ).reshape(B, nkv, g, nQ, bq, dv), 3, 0)

    def q_step(_, xs):
        qb, qpb, m0, l0, a0 = xs                # qb (B, bq, nkv, g, d)
        qb = qb.astype(jnp.float32)

        def kv_step(cr, ys):
            mc, lc, ac = cr
            kb, vb, kpb, kvb = ys
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb,
                           kb.astype(jnp.float32),
                           preferred_element_type=jnp.float32) * scale
            mask = kvb[None, :]
            iq = qpb[:, None]
            jk = kpb[None, :]
            if causal:
                mask &= iq >= jk
            if window > 0:
                mask &= (iq - jk) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(mc, jnp.max(s, axis=-1))
            # the explicit mask keeps exp(0) out of l when a row is still
            # fully masked (m_new == NEG_INF, s - m_new == 0)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - m_new[..., None]), 0.0)
            alpha = jnp.exp(mc - m_new)
            lc = alpha * lc + jnp.sum(p, axis=-1)
            ac = alpha[..., None] * ac + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32),
                preferred_element_type=jnp.float32)
            return (m_new, lc, ac), 0

        (m1, l1, a1), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                       (kc, vc, kpos.reshape(nK, bk),
                                        kvalid.reshape(nK, bk)))
        return 0, (m1, l1, a1)

    _, (mo, lo, ao) = jax.lax.scan(
        q_step, 0, (qc, qpos.reshape(nQ, bq), mq, lq, aq))
    m = jnp.moveaxis(mo, 0, 3).reshape(B, nkv, g, nQ * bq)[..., :Tq]
    l = jnp.moveaxis(lo, 0, 3).reshape(B, nkv, g, nQ * bq)[..., :Tq]
    acc = jnp.moveaxis(ao, 0, 3).reshape(B, nkv, g, nQ * bq, dv
                                         )[..., :Tq, :]
    return m, l, acc


def attn_partial_finalize(carry, dtype):
    """Normalize a chained (m, l, acc) carry into the (B, Tq, nq, dv)
    attention output."""
    m, l, acc = carry
    out = acc / jnp.maximum(l, 1e-30)[..., None]    # (B, nkv, g, Tq, dv)
    B, nkv, g, Tq, dv = out.shape
    return jnp.moveaxis(out, 3, 1).reshape(B, Tq, nkv * g, dv
                                           ).astype(dtype)


def paged_attn_core(q, k, v, *, q_pos, q_len, window: int = 0,
                    scale: Optional[float] = None):
    """Variable-length attention over per-slot KV gathered from page pools.

    q: (R, T, nq, d) — R request slots, T rows (1 for pure decode, the
    chunk length for chunked prefill); k/v: (R, S, nkv, dv) — slot r's
    pages gathered in page-table order, so key index j IS global position
    j; q_pos: (R, T) int32 global query positions; q_len: (R,) int32
    valid query rows per slot (rows >= q_len[r] are chunk padding or idle
    slots and are fully masked).

    This is the jnp oracle the model calls in ``mode='paged'``;
    ``kernels.flash_attention_paged`` mirrors it page-by-page and is
    validated against it. Two properties the serving tests pin:

      * masked scores contribute *exactly* zero (explicit ``where`` on p),
        so stale data in freed/reused pages and the reserved null page
        never leak probability mass into live rows;
      * the reduction runs over the FIXED gathered length S in one fp32
        softmax, so every chunking of the same prompt reduces the same
        score vector per row — chunked prefill equals one-shot prefill
        bitwise (tests/test_serving.py).

    A fully-masked row (idle slot) yields a finite garbage output that the
    engine discards via q_len."""
    R, T, nq, d = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(R, T, nkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    iq = q_pos.astype(jnp.int32)[:, :, None]            # (R, T, 1)
    jk = jnp.arange(S, dtype=jnp.int32)[None, None, :]  # (1, 1, S)
    row = jnp.arange(T, dtype=jnp.int32)[None, :, None]
    mask = (row < q_len.astype(jnp.int32)[:, None, None]) & (iq >= jk)
    if window > 0:
        mask &= (iq - jk) < window
    mask = mask[:, None, None]                          # (R, 1, 1, T, S)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(o, 3, 1).reshape(R, T, nq, v.shape[-1]
                                         ).astype(q.dtype)


def seq_attn(q, k, v, axes: M.MeshAxes, *, causal: bool = True,
             window: int = 0):
    """Context-parallel causal attention over the ``seq`` mesh axis.

    Runs inside shard_map on the striped layout (seq-rank r holds global
    positions r, r + p, r + 2p, ... — ``mesh.stripe_seq``; each rank's
    causal work is balanced because its stripe spans the whole sequence).
    Two schedules, identical results up to fp32 reassociation:

      * blocking (``overlap.ring_attention`` off): one KV all-gather
        over ``seq``, one partial pass with the gathered (non-monotone)
        position vector;
      * ring (on): p-1 ``ppermute`` hops circulate the KV shards —
        after s hops this rank holds seq-rank (r - s) mod p's block
        (``mesh.ring_perm``) — with hop s+1's permute issued BEFORE hop
        s's partial attention, so the exchange hides under attention
        compute exactly like the PR-1/2 ring-GEMM schedule.

    Cross-hop accumulation is the fp32 (m, l, acc) online-softmax carry
    of :func:`attn_core_partial`. p == 1 degenerates to the plain
    :func:`attn_core` call, bit for bit."""
    p = axes.gseq
    if p <= 1:
        return attn_core(q, k, v, causal=causal, window=window)
    B, C, nq, d = q.shape
    nkv, dv = k.shape[2], v.shape[-1]
    r = M.axis_index(axes.seq)
    q_pos = jnp.arange(C, dtype=jnp.int32) * p + r
    carry = attn_partial_init(B, C, nkv, nq // nkv, dv)
    if not axes.overlap.ring_attention:
        kg = M.all_gather(k, axes.seq, dim=1)
        vg = M.all_gather(v, axes.seq, dim=1)
        # gathered index rho*C + j holds global position j*p + rho
        i = jnp.arange(p * C, dtype=jnp.int32)
        k_pos = (i % C) * p + i // C
        carry = attn_core_partial(q, kg, vg, carry, q_pos=q_pos,
                                  k_pos=k_pos, causal=causal,
                                  window=window)
        return attn_partial_finalize(carry, q.dtype)
    cur_k, cur_v = k, v
    local = jnp.arange(C, dtype=jnp.int32) * p
    for s in range(p):
        with trace.scope("ring_exchange", axes.seq, f"hop{s}"):
            if s < p - 1:
                # prefetch: hop s+1's KV permutes while hop s computes
                # (the permute has no data dependency on this hop's
                # partials, so the latency-hiding scheduler overlaps them)
                nxt_k = M.ppermute_ring(cur_k, axes.seq)
                nxt_v = M.ppermute_ring(cur_v, axes.seq)
            owner = (r - s) % p
            carry = attn_core_partial(q, cur_k, cur_v, carry, q_pos=q_pos,
                                      k_pos=local + owner, causal=causal,
                                      window=window)
            if s < p - 1:
                cur_k, cur_v = nxt_k, nxt_v
    return attn_partial_finalize(carry, q.dtype)


def decode_core_seqsharded(q, k, v, pos, axes, *, window: int = 0,
                           scale: Optional[float] = None):
    """Single-token decode against a KV cache whose *sequence* dim is
    sharded over the data axis. Partial softmax per shard, merged with a
    log-sum-exp psum over ``data``.

    q: (B, 1, nq, d); k/v: (B, S_local, nkv, d); pos: scalar absolute
    position of the query token (cache entries > pos are masked)."""
    B, _, nq, d = q.shape
    S_local, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    shard = M.axis_index(axes.data)
    jk = shard * S_local + jnp.arange(S_local)  # global cache positions
    qg = q.reshape(B, nkv, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    ok = jk <= pos
    if window > 0:
        ok &= (pos - jk) < window
    scores = jnp.where(ok[None, None, None, :], scores, NEG_INF)
    m_local = jnp.max(scores, axis=-1)
    m = M.pmax(m_local, axes.data)
    e = jnp.exp(scores - m[..., None])
    num = jnp.einsum("bhgk,bkhd->bhgd", e, v.astype(jnp.float32))
    den = jnp.sum(e, axis=-1)
    num = M.psum(num, axes.data)
    den = M.psum(den, axes.data)
    out = num / den[..., None]
    return out.reshape(B, 1, nq, d).astype(q.dtype)


# ---------------------------------------------------------------------- #
# GQA attention layer
# ---------------------------------------------------------------------- #

def kv_layout(cfg, axes: M.MeshAxes):
    """(nq_local, nkv_local, duplicated?). When G_y > n_kv_heads (e.g. the
    16-way 1D baseline on a kv=8 GQA arch), KV heads are *duplicated*
    across y ranks — Megatron's standard GQA-under-wide-TP treatment."""
    nq_l = cfg.n_heads // axes.gy
    if cfg.n_kv_heads % axes.gy == 0:
        return nq_l, cfg.n_kv_heads // axes.gy, False
    if axes.gy % cfg.n_kv_heads or cfg.n_heads % axes.gy:
        raise ValueError(f"{cfg.name}: cannot lay out {cfg.n_kv_heads} kv "
                         f"heads on G_y={axes.gy}")
    return nq_l, 1, True


def attn_init(key, cfg, axes: M.MeshAxes, *, dtype=jnp.bfloat16,
              stack=(), abstract=False, cross: bool = False):
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    _, _, dup = kv_layout(cfg, axes)
    keys = jax.random.split(key, 4)
    p = {}
    if dup and not cross:
        assert not getattr(cfg, "attn_bias", False), \
            "bias unsupported in duplicated-KV layout"
        p["wq"] = PP.tp_linear_init(keys[0], cfg.d_model, nq * hd, axes,
                                    dtype=dtype, stack=stack,
                                    abstract=abstract)
        # full (small) kv projection, replicated over y; each rank slices
        # its duplicated head. Grads need a y psum (y_reduce).
        wkv = PP.tp_linear_init(keys[1], cfg.d_model, 2 * nkv * hd, axes,
                                in_shard="x", out_shard=None, dtype=dtype,
                                stack=stack, abstract=abstract)
        wkv.y_reduce = True
        p["wkv_dup"] = wkv
        p["wo"] = PP.tp_linear_init(keys[2], nq * hd, cfg.d_model, axes,
                                    in_shard="y", out_shard="x",
                                    dtype=dtype, stack=stack,
                                    abstract=abstract)
        if getattr(cfg, "qk_norm", False):
            spec = P(*([None] * (len(stack) + 1)))
            def mk():
                if abstract:
                    return Boxed(jax.ShapeDtypeStruct((*stack, hd), dtype),
                                 spec)
                return Boxed(jnp.ones((*stack, hd), dtype), spec)
            p["q_norm"], p["k_norm"] = mk(), mk()
        return p
    if cross:
        # q from decoder stream; kv from encoder states
        p["wq"] = PP.tp_linear_init(keys[0], cfg.d_model, nq * hd, axes,
                                    dtype=dtype, stack=stack,
                                    abstract=abstract)
        p["wk"] = PP.tp_linear_init(keys[1], cfg.d_model, nkv * hd,
                                     axes, dtype=dtype, stack=stack,
                                     abstract=abstract)
        p["wv"] = PP.tp_linear_init(keys[3], cfg.d_model, nkv * hd,
                                    axes, dtype=dtype, stack=stack,
                                    abstract=abstract)
    else:
        # separate q/k/v weights: a fused (nq+2nkv)*hd matrix column-
        # sharded over y would change its *global* layout meaning with
        # G_y (per-shard [q|k|v] chunks) — mesh-dependent semantics.
        p["wq"] = PP.tp_linear_init(keys[0], cfg.d_model, nq * hd, axes,
                                    dtype=dtype, stack=stack,
                                    abstract=abstract)
        p["wk"] = PP.tp_linear_init(keys[1], cfg.d_model, nkv * hd, axes,
                                    dtype=dtype, stack=stack,
                                    abstract=abstract)
        p["wv"] = PP.tp_linear_init(keys[3], cfg.d_model, nkv * hd, axes,
                                    dtype=dtype, stack=stack,
                                    abstract=abstract)
    p["wo"] = PP.tp_linear_init(keys[2], nq * hd, cfg.d_model, axes,
                                in_shard="y", out_shard="x", dtype=dtype,
                                stack=stack, abstract=abstract)
    if getattr(cfg, "attn_bias", False):
        p["bq"] = PP.tp_bias_init(nq * hd, axes, dtype=dtype,
                                  stack=stack, abstract=abstract)
        if not cross:
            p["bk"] = PP.tp_bias_init(nkv * hd, axes, dtype=dtype,
                                      stack=stack, abstract=abstract)
            p["bv"] = PP.tp_bias_init(nkv * hd, axes, dtype=dtype,
                                      stack=stack, abstract=abstract)
        p["bo"] = PP.tp_bias_init(cfg.d_model, axes, out_shard="x",
                                  dtype=dtype, stack=stack,
                                  abstract=abstract)
    if getattr(cfg, "qk_norm", False):
        spec = P(*([None] * (len(stack) + 1)))
        def mk():
            if abstract:
                return Boxed(jax.ShapeDtypeStruct((*stack, hd), dtype), spec)
            return Boxed(jnp.ones((*stack, hd), dtype), spec)
        p["q_norm"], p["k_norm"] = mk(), mk()
    return p


def _split_qkv(qkv, nq_l, nkv_l, hd):
    B, T = qkv.shape[:2]
    q, k, v = jnp.split(qkv, [nq_l * hd, (nq_l + nkv_l) * hd], axis=-1)
    return (q.reshape(B, T, nq_l, hd), k.reshape(B, T, nkv_l, hd),
            v.reshape(B, T, nkv_l, hd))


def attn_apply(p, h, cfg, axes: M.MeshAxes, *, positions, mode="train",
               cache=None, window: int = 0, causal: bool = True,
               paged=None):
    """Returns (out, new_cache).

    mode: 'train' (no cache), 'prefill' (build cache), 'decode' (T==1,
    read+update cache), 'decode_seqshard' (cache seq-sharded over data),
    'paged' (continuous-batching serving: per-slot rows at per-slot
    positions against a pooled paged KV cache; ``paged`` carries
    ``{"table": (R, max_pages) int32, "q_len": (R,) int32}``, see
    docs/serving.md).
    """
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    nq_l, nkv_l, dup = kv_layout(cfg, axes)
    if dup:
        B, T = h.shape[:2]
        q = PP.tp_matmul(h, p["wq"], axes, "x", "y")
        q = q.reshape(B, T, nq_l, hd)
        kv = PP.tp_matmul(h, p["wkv_dup"], axes, "x", None)
        kv = kv.reshape(B, T, 2, cfg.n_kv_heads, hd)
        # this rank's duplicated head: kv head j serves q heads [j*g, ...)
        head = (M.axis_index(axes.y) * cfg.n_kv_heads) // axes.gy
        kv = jax.lax.dynamic_slice_in_dim(kv, head, 1, axis=3)
        k, v = kv[:, :, 0], kv[:, :, 1]        # (B, T, 1, hd)
    else:
        B, T = h.shape[:2]
        q = PP.tp_matmul(h, p["wq"], axes, "x", "y")
        k = PP.tp_matmul(h, p["wk"], axes, "x", "y")
        v = PP.tp_matmul(h, p["wv"], axes, "x", "y")
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = q.reshape(B, T, nq_l, hd)
        k = k.reshape(B, T, nkv_l, hd)
        v = v.reshape(B, T, nkv_l, hd)
    if "q_norm" in p:
        q = _plain_rms(q, p["q_norm"])
        k = _plain_rms(k, p["k_norm"])
    if cfg.rotary_pct > 0:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k_pos = positions
        k = apply_rope(k, k_pos, cfg.rope_theta, cfg.rotary_pct)

    new_cache = cache
    if mode in ("train", "prefill"):
        if mode == "train" and axes.gseq > 1:
            # context parallelism: ring/blocking partial attention over
            # the striped seq shards (positions already carry the stripe)
            out = seq_attn(q, k, v, axes, causal=causal, window=window)
        else:
            out = attn_core(q, k, v, causal=causal, window=window)
        if mode == "prefill":
            kc, vc = cache["k"], cache["v"]
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                              (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                              (0, 0, 0, 0))
            new_cache = {"k": kc, "v": vc}
    elif mode == "decode":
        pos = positions[:, 0]  # (B,)
        kc, vc = cache["k"], cache["v"]
        idx = pos[0]  # uniform position across batch (standard batch decode)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, idx, 0, 0))
        new_cache = {"k": kc, "v": vc}
        S = kc.shape[1]
        jk = jnp.arange(S)
        ok = jk <= idx
        if window > 0:
            ok &= (idx - jk) < window
        out = _decode_attn(q, kc, vc, ok)
    elif mode == "paged":
        # continuous-batching serving (docs/serving.md): the cache is a
        # physical page pool (P_local, page, H_local, hd); each slot's
        # logical sequence lives wherever its page table says. Rows are
        # per-slot chunk tokens (prefill) or single decode tokens at
        # per-slot global positions — no uniform-position assumption.
        kp, vp = cache["k"], cache["v"]
        page = kp.shape[1]
        table = paged["table"].astype(jnp.int32)        # (R, max_pages)
        q_len = paged["q_len"].astype(jnp.int32)        # (R,)
        R, Tr = positions.shape
        valid = jnp.arange(Tr, dtype=jnp.int32)[None, :] < q_len[:, None]
        slot_pages = jnp.clip(positions.astype(jnp.int32) // page, 0,
                              table.shape[1] - 1)
        pid = jnp.take_along_axis(table, slot_pages, axis=1)
        # invalid rows (chunk padding / idle slots) collapse onto the
        # reserved null page 0 at offset 0 — written, never read (the
        # allocator never hands out page 0 and masked rows zero p)
        pid = jnp.where(valid, pid, 0)
        off = jnp.where(valid, positions.astype(jnp.int32) % page, 0)
        kp = kp.at[pid, off].set(k.astype(kp.dtype))
        vp = vp.at[pid, off].set(v.astype(vp.dtype))
        new_cache = {"k": kp, "v": vp}
        # gather each slot's pages in table order: key index j of the
        # gathered (R, S_max, ...) view IS global position j
        kc = kp[table].reshape(R, -1, *kp.shape[2:])
        vc = vp[table].reshape(R, -1, *vp.shape[2:])
        out = paged_attn_core(q, kc, vc, q_pos=positions, q_len=q_len,
                              window=window)
    elif mode == "decode_seqshard":
        # global_batch=1 long-context: cache seq dim sharded over data; the
        # fresh token's kv is written by the owning shard only.
        pos = positions[0, 0]
        kc, vc = cache["k"], cache["v"]
        S_local = kc.shape[1]
        shard = M.axis_index(axes.data)
        local_idx = pos - shard * S_local
        owns = (local_idx >= 0) & (local_idx < S_local)
        safe = jnp.clip(local_idx, 0, S_local - 1)
        kw = jnp.where(owns, k.astype(kc.dtype),
                       jax.lax.dynamic_slice(kc, (0, safe, 0, 0),
                                             k.shape))
        vw = jnp.where(owns, v.astype(vc.dtype),
                       jax.lax.dynamic_slice(vc, (0, safe, 0, 0), v.shape))
        kc = jax.lax.dynamic_update_slice(kc, kw, (0, safe, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, vw, (0, safe, 0, 0))
        new_cache = {"k": kc, "v": vc}
        out = decode_core_seqsharded(q, kc, vc, pos, axes, window=window)
    else:
        raise ValueError(mode)

    B, T = out.shape[:2]
    o = PP.tp_matmul(out.reshape(B, T, nq_l * hd), p["wo"], axes, "y", "x")
    if "bo" in p:
        o = o + p["bo"]
    return o, new_cache


def _decode_attn(q, kc, vc, ok):
    B, _, nq, d = q.shape
    nkv = kc.shape[2]
    g = nq // nkv
    scores = jnp.einsum("bhgd,bkhd->bhgk",
                        q.reshape(B, nkv, g, d).astype(jnp.float32),
                        kc.astype(jnp.float32)) / math.sqrt(d)
    scores = jnp.where(ok[None, None, None, :], scores, NEG_INF)
    probs = _softmax_fp32(scores)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, vc.astype(jnp.float32))
    return out.reshape(B, 1, nq, d).astype(q.dtype)


def attn_cache_spec(cfg, axes: M.MeshAxes, batch_global, seq, *,
                    dtype=jnp.bfloat16, seqshard: bool = False):
    """GLOBAL ShapeDtypeStructs + PartitionSpecs for this layer's KV cache.

    In the duplicated-KV layout the cache's global head dim is G_y (one
    duplicated head per y rank)."""
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    _, nkv_l, dup = kv_layout(cfg, axes)
    heads_global = axes.gy if dup else cfg.n_kv_heads
    if seqshard:
        spec = axes.pspec(None, axes.data, axes.y, None)
    else:
        spec = axes.pspec(axes.batch_axes(), None, axes.y, None)
    shape = (batch_global, seq, heads_global, hd)
    return {"k": (jax.ShapeDtypeStruct(shape, dtype), spec),
            "v": (jax.ShapeDtypeStruct(shape, dtype), spec)}


def paged_attn_cache_spec(cfg, axes: M.MeshAxes, n_pages_global, page_size,
                          *, dtype=jnp.bfloat16):
    """GLOBAL (struct, spec) for this layer's paged KV pool.

    Shape (n_pages_global, page_size, heads_global, hd): physical pages
    shard over the batch axes (data x z, the same rule as the dense decode
    cache — z co-shards batch storage per the paper), KV heads over y,
    replicated over x (x shards the residual stream, not the cache). Each
    batch shard owns n_pages_global / (g_data*g_z) contiguous pages whose
    page tables hold shard-LOCAL ids; page 0 of every shard is the
    reserved null page (docs/serving.md)."""
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    _, _, dup = kv_layout(cfg, axes)
    heads_global = axes.gy if dup else cfg.n_kv_heads
    spec = axes.pspec(axes.batch_axes(), None, axes.y, None)
    shape = (n_pages_global, page_size, heads_global, hd)
    return {"k": (jax.ShapeDtypeStruct(shape, dtype), spec),
            "v": (jax.ShapeDtypeStruct(shape, dtype), spec)}


# ---------------------------------------------------------------------- #
# cross attention (whisper decoder)
# ---------------------------------------------------------------------- #

def cross_attn_apply(p, h, enc_kv, cfg, axes: M.MeshAxes):
    """enc_kv: precomputed (k, v) from encoder states, (B, S_enc, nkv_l, hd)."""
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    nq_l = cfg.n_heads // axes.gy
    B, T = h.shape[:2]
    q = PP.tp_matmul(h, p["wq"], axes, "x", "y").reshape(B, T, nq_l, hd)
    k, v = enc_kv
    out = attn_core(q, k, v, causal=False)
    o = PP.tp_matmul(out.reshape(B, T, nq_l * hd), p["wo"], axes, "y", "x")
    if "bo" in p:
        o = o + p["bo"]
    return o


def cross_attn_kv(p, enc_states, cfg, axes: M.MeshAxes):
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    nkv_l = cfg.n_kv_heads // axes.gy
    B, S = enc_states.shape[:2]
    k = PP.tp_matmul(enc_states, p["wk"], axes, "x", "y")
    v = PP.tp_matmul(enc_states, p["wv"], axes, "x", "y")
    return (k.reshape(B, S, nkv_l, hd), v.reshape(B, S, nkv_l, hd))


# ---------------------------------------------------------------------- #
# DeepSeek Multi-head Latent Attention (MLA)
# ---------------------------------------------------------------------- #

def mla_init(key, cfg, axes: M.MeshAxes, *, dtype=jnp.bfloat16, stack=(),
             abstract=False):
    m = cfg.mla
    nq = cfg.n_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 8)
    from jax.sharding import PartitionSpec as P

    def rep_norm(dim):
        spec = P(*([None] * (len(stack) + 1)))
        if abstract:
            return Boxed(jax.ShapeDtypeStruct((*stack, dim), dtype), spec)
        return Boxed(jnp.ones((*stack, dim), dtype), spec)

    p = {}
    if m.q_lora_rank:
        p["w_dq"] = PP.tp_linear_init(ks[0], cfg.d_model, m.q_lora_rank,
                                      axes, in_shard="x", out_shard=None,
                                      dtype=dtype, stack=stack,
                                      abstract=abstract)
        p["q_norm"] = rep_norm(m.q_lora_rank)
        p["w_uq"] = PP.tp_linear_init(ks[1], m.q_lora_rank, nq * qk_dim,
                                      axes, in_shard=None, out_shard="y",
                                      dtype=dtype, stack=stack,
                                      abstract=abstract)
    else:
        p["w_q"] = PP.tp_linear_init(ks[1], cfg.d_model, nq * qk_dim, axes,
                                     dtype=dtype, stack=stack,
                                     abstract=abstract)
    p["w_dkv"] = PP.tp_linear_init(
        ks[2], cfg.d_model, m.kv_lora_rank + m.qk_rope_dim, axes,
        in_shard="x", out_shard=None, dtype=dtype, stack=stack,
        abstract=abstract)
    p["kv_norm"] = rep_norm(m.kv_lora_rank)
    p["w_uk"] = PP.tp_linear_init(ks[3], m.kv_lora_rank, nq * m.qk_nope_dim,
                                  axes, in_shard=None, out_shard="y",
                                  dtype=dtype, stack=stack,
                                  abstract=abstract)
    p["w_uv"] = PP.tp_linear_init(ks[4], m.kv_lora_rank, nq * m.v_dim, axes,
                                  in_shard=None, out_shard="y", dtype=dtype,
                                  stack=stack, abstract=abstract)
    p["wo"] = PP.tp_linear_init(ks[5], nq * m.v_dim, cfg.d_model, axes,
                                in_shard="y", out_shard="x", dtype=dtype,
                                stack=stack, abstract=abstract)
    return p


def _mla_q(p, h, cfg, axes, positions):
    m = cfg.mla
    nq_l = cfg.n_heads // axes.gy
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    B, T = h.shape[:2]
    if "w_dq" in p:
        cq = PP.tp_matmul(h, p["w_dq"], axes, "x", None)
        cq = _plain_rms(cq, p["q_norm"])
        q = PP.tp_matmul(cq, p["w_uq"], axes, None, "y")
    else:
        q = PP.tp_matmul(h, p["w_q"], axes, "x", "y")
    q = q.reshape(B, T, nq_l, qk_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope_interleaved_neox(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(p, h, cfg, axes: M.MeshAxes, *, positions, mode="train",
              cache=None):
    """MLA forward. train/prefill: materialized per-head K/V; decode:
    absorbed matmuls against the compressed (c_kv, k_rope) cache."""
    m = cfg.mla
    nq_l = cfg.n_heads // axes.gy
    B, T = h.shape[:2]
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)

    dkv = PP.tp_matmul(h, p["w_dkv"], axes, "x", None)
    ckv, k_rope = dkv[..., :m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    ckv = _plain_rms(ckv, p["kv_norm"])
    k_rope = apply_rope_interleaved_neox(k_rope[:, :, None, :], positions,
                                         cfg.rope_theta)  # (B,T,1,rope)
    q_nope, q_rope = _mla_q(p, h, cfg, axes, positions)

    new_cache = cache
    if mode in ("train", "prefill"):
        k_nope = PP.tp_matmul(ckv, p["w_uk"], axes, None, "y")
        k_nope = k_nope.reshape(B, T, nq_l, m.qk_nope_dim)
        v = PP.tp_matmul(ckv, p["w_uv"], axes, None, "y")
        v = v.reshape(B, T, nq_l, m.v_dim)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, T, nq_l, m.qk_rope_dim))],
            axis=-1)
        out = attn_core(q, k, v, causal=True, scale=scale)
        if mode == "prefill":
            cc = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
            rc = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope[:, :, 0, :].astype(
                    cache["k_rope"].dtype), (0, 0, 0))
            new_cache = {"ckv": cc, "k_rope": rc}
    elif mode == "decode":
        idx = positions[0, 0]
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, idx, 0))
        rc = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0, :].astype(
                cache["k_rope"].dtype), (0, idx, 0))
        new_cache = {"ckv": cc, "k_rope": rc}
        # absorbed: q_eff = q_nope @ W_uk  -> score against compressed cache
        wuk = M.all_gather(p["w_uk"], axes.z, dim=1)
        wuk = wuk.reshape(m.kv_lora_rank, nq_l, m.qk_nope_dim)
        q_eff = jnp.einsum("bthd,rhd->bthr", q_nope.astype(jnp.float32),
                           wuk.astype(jnp.float32))  # (B,1,nq_l,rank)
        S = cc.shape[1]
        scores = (jnp.einsum("bthr,bsr->bths", q_eff,
                             cc.astype(jnp.float32))
                  + jnp.einsum("bthd,bsd->bths",
                               q_rope.astype(jnp.float32),
                               rc.astype(jnp.float32))) * scale
        ok = jnp.arange(S) <= idx
        scores = jnp.where(ok[None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bths,bsr->bthr", probs, cc.astype(jnp.float32))
        wuv = M.all_gather(p["w_uv"], axes.z, dim=1)
        wuv = wuv.reshape(m.kv_lora_rank, nq_l, m.v_dim)
        out = jnp.einsum("bthr,rhd->bthd", ctx, wuv.astype(jnp.float32)
                         ).astype(h.dtype)
    else:
        raise ValueError(mode)

    o = PP.tp_matmul(out.reshape(B, T, nq_l * m.v_dim), p["wo"], axes,
                     "y", "x")
    return o, new_cache


def mla_cache_spec(cfg, axes: M.MeshAxes, batch_global, seq, *,
                   dtype=jnp.bfloat16):
    m = cfg.mla
    bspec = axes.pspec(axes.batch_axes(), None, None)
    return {
        "ckv": (jax.ShapeDtypeStruct((batch_global, seq, m.kv_lora_rank),
                                     dtype), bspec),
        "k_rope": (jax.ShapeDtypeStruct((batch_global, seq, m.qk_rope_dim),
                                        dtype), bspec),
    }
