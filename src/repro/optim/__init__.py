"""Optimizers (mixed-precision AdamW with 4D-sharded state)."""
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
