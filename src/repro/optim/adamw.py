"""Mixed-precision AdamW for the 4D layout.

Paper setup (§6.1): mixed precision + AdamW. Parameters live in bf16,
sharded by the 4D layout; master weights and Adam moments are fp32 with the
*same* PartitionSpec as the parameter — so tp-weight optimizer state is
sharded over (x, y, z): the depth axis ``z`` cuts optimizer memory by
1/G_z, which is the 4D paper's memory story (a ZeRO-1-like win realized
through the tensor layout itself rather than a separate mechanism —
recorded in DESIGN.md §7).

Gradients arrive at ``apply_updates`` already reduced over ``data`` (and
``z`` where required) by the train step.

:func:`apply_updates_sharded` is the ZeRO-1 variant on top of
:mod:`repro.core.gradsync`: gradients arrive as data-axis-scattered fp32
bucket shards, each rank updates only its ``1/G_data`` slice of the fp32
state, and the caller rebroadcasts the updated params with a ring
all-gather — the same per-element math, so the two paths agree bitwise on
exactly-summable values (tests/test_gradsync.py pins this).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import gradsync as GS
from repro.core import mesh as M
from repro.core.partition import ParamSpec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# ---------------------------------------------------------------------- #
# state
# ---------------------------------------------------------------------- #

def init_state(params, *, abstract: bool = False):
    """m / v / fp32 master per leaf, same shape & sharding as the leaf."""
    def one(p):
        if abstract or isinstance(p, jax.ShapeDtypeStruct):
            z = jax.ShapeDtypeStruct(p.shape, jnp.float32)
            return {"m": z, "v": z, "master": z}
        # copy=True: with fp32 params astype would alias the param buffer,
        # which breaks donation in the jitted step
        f32 = jnp.array(p, dtype=jnp.float32, copy=True)
        return {"m": jnp.zeros_like(f32), "v": jnp.zeros_like(f32),
                "master": f32}
    return {"opt": jax.tree.map(one, params),
            "step": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                     else jnp.zeros((), jnp.int32))}


def state_pspecs(param_pspecs):
    """PartitionSpec tree for the state (mirrors the params)."""
    from jax.sharding import PartitionSpec as P
    return {"opt": jax.tree.map(lambda s: {"m": s, "v": s, "master": s},
                                param_pspecs,
                                is_leaf=lambda x: isinstance(
                                    x, jax.sharding.PartitionSpec)),
            "step": P()}


# ---------------------------------------------------------------------- #
# update
# ---------------------------------------------------------------------- #

def _no_decay(path) -> bool:
    keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    s = "/".join(str(k) for k in keys)
    for tag in ("norm", "gn", "bias", "b_if", "b_gates", "b_dt", "bqkv",
                "bo", "bi", "bq", "skip", "conv_b", "A_log", "D", "pos"):
        if tag in s:
            return True
    return False


def global_grad_norm(grads, specs, axes: M.MeshAxes):
    """L2 norm of the *global* gradient: per-leaf local sum of squares is
    psum'd over exactly the mesh axes the leaf is sharded over."""
    gl = jax.tree.leaves(grads)
    sl = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, ParamSpec))
    total = jnp.zeros((), jnp.float32)
    for g, s in zip(gl, sl):
        loc = jnp.sum(jnp.square(g.astype(jnp.float32)))
        names = tuple(n for entry in s.spec if entry is not None
                      for n in (entry if isinstance(entry, tuple)
                                else (entry,)))
        total = total + (M.psum(loc, names) if names else loc)
    return jnp.sqrt(total)


def apply_updates(params, grads, state, specs, axes: M.MeshAxes,
                  cfg: AdamWConfig):
    """One AdamW step on local shards (grads pre-reduced over data/z).

    Returns (new_params, new_state, metrics)."""
    step = state["step"]
    lr = lr_at(cfg, step)
    gnorm = global_grad_norm(grads, specs, axes)
    scale = (jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
             if cfg.grad_clip else jnp.float32(1.0))

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = treedef.flatten_up_to(state["opt"])
    t = step.astype(jnp.float32) + 1

    new_p, new_s = [], []
    for (path, p), g, st in zip(flat_p, flat_g, flat_s):
        gf = g.astype(jnp.float32) * scale
        m = cfg.b1 * st["m"] + (1 - cfg.b1) * gf
        v = cfg.b2 * st["v"] + (1 - cfg.b2) * gf * gf
        mhat = m / (1 - cfg.b1 ** t)
        vhat = v / (1 - cfg.b2 ** t)
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and not _no_decay(path):
            upd = upd + cfg.weight_decay * st["master"]
        master = st["master"] - lr * upd
        new_p.append(master.astype(p.dtype))
        new_s.append({"m": m, "v": v, "master": master})

    params = jax.tree.unflatten(treedef, new_p)
    opt = jax.tree.unflatten(treedef, new_s)
    return params, {"opt": opt, "step": step + 1}, \
        {"grad_norm": gnorm, "lr": lr}


def apply_updates_sharded(shards, state, plan, axes: M.MeshAxes,
                          cfg: AdamWConfig, *, ring: bool = True,
                          rebuild: bool = True):
    """One ZeRO-1/3 AdamW step on data-axis-scattered gradient shards.

    ``shards`` are the per-bucket fp32 gradients (already reduced over
    data/z/y, scaled by 1/microbatches); ``state`` holds m/v/master only
    for this rank's shard of each bucket (``gradsync.init_sharded_state``).
    Element-wise math is identical to :func:`apply_updates`; weight decay
    uses the plan's per-element group-id masks in place of the per-leaf
    path check. Returns (new_params, new_state, metrics); with
    ``rebuild`` the new params are rebuilt wholesale from the updated
    master shards by the ring all-gather (ZeRO-1 — the old params are
    not read, their buffers stay donatable); without it (ZeRO-3,
    ``gradsync.zero3``) the new params ARE the cast master shards
    (``gradsync.shards_to_tree``) — no collective at all, the per-layer
    streaming gathers re-assemble working copies next step."""
    step = state["step"]
    lr = lr_at(cfg, step)
    gnorm = GS.sharded_grad_norm(shards, plan, axes)
    scale = (jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
             if cfg.grad_clip else jnp.float32(1.0))
    t = step.astype(jnp.float32) + 1

    new_buckets, masters = [], []
    for b, g, st in zip(plan.buckets, shards, state["buckets"]):
        gf = g.astype(jnp.float32) * scale
        m = cfg.b1 * st["m"] + (1 - cfg.b1) * gf
        v = cfg.b2 * st["v"] + (1 - cfg.b2) * gf * gf
        mhat = m / (1 - cfg.b1 ** t)
        vhat = v / (1 - cfg.b2 ** t)
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            mask = GS.decay_mask(b, GS.gid_shard(plan, b, axes))
            upd = upd + cfg.weight_decay * st["master"] * mask
        master = st["master"] - lr * upd
        masters.append(master)
        new_buckets.append({"m": m, "v": v, "master": master})

    params = (GS.rebuild_params(masters, plan, axes, ring=ring)
              if rebuild else GS.shards_to_tree(masters, plan))
    return params, {"buckets": new_buckets, "step": step + 1}, \
        {"grad_norm": gnorm, "lr": lr}
