"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family] — qk-norm, GQA kv=8, head_dim 128,
tied embeddings, rope theta 1e6."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", arch_type="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab_size=151936, head_dim=128,
    norm="rmsnorm", act="silu", gated_mlp=True,
    qk_norm=True, tie_embeddings=True, rope_theta=1_000_000.0,
    source="[hf:Qwen/Qwen3-1.7B]",
)
