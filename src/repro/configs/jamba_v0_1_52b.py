"""Jamba-v0.1-52B [arXiv:2403.19887] — hybrid Mamba+attention 1:7
interleave (attention at position 4 of each 8-layer block), MoE every
other layer: 16 experts top-2 of width 14336."""
from repro.models.base import ArchConfig, MambaCfg, MoECfg

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", arch_type="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, head_dim=128,
    norm="rmsnorm", act="silu", gated_mlp=True,
    rotary_pct=0.0,  # jamba uses no positional encoding
    mixer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    moe=MoECfg(n_experts=16, top_k=2, d_expert=14336, n_shared=0,
               first_dense=1, period=2),
    source="Jamba [arXiv:2403.19887]",
)
