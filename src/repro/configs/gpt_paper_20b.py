"""GPT-20B — the paper's own weak-scaling model (Table 3: 24 layers,
hidden 8192, 64 heads, batch 1024 x seq 2048, G_tensor=16 on 128 GPUs).
Used by the paper-reproduction benchmarks (Figs. 5/8, Table 5)."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="gpt-paper-20b", arch_type="dense",
    n_layers=24, d_model=8192, n_heads=64, n_kv_heads=64, d_ff=32768,
    vocab_size=51200, head_dim=128,
    norm="layernorm", act="gelu", gated_mlp=False,
    source="paper Table 3 / GPT-3 family [arXiv:2005.14165]",
)
