"""xLSTM-350M [arXiv:2405.04517] — 24 blocks, 7:1 mLSTM:sLSTM, 4 heads,
self-contained blocks (d_ff=0; mLSTM pf=2, sLSTM post-MLP pf=4/3)."""
from repro.models.base import ArchConfig, XLSTMCfg

CONFIG = ArchConfig(
    name="xlstm-350m", arch_type="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    norm="layernorm", act="gelu", gated_mlp=False,
    rotary_pct=0.0,
    xlstm=XLSTMCfg(proj_factor_mlstm=2.0, proj_factor_slstm=4.0 / 3.0,
                   conv_kernel=4),
    source="xLSTM [arXiv:2405.04517]",
)
