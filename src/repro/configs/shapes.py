"""Assigned input shapes (arch-independent)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    seqshard: bool = False   # shard the KV cache sequence dim over `data`


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    # context-parallel target: 128k tokens/sequence only fits when the
    # seq dim shards over the seq mesh axis (dryrun --seq-parallel)
    "train_128k": InputShape("train_128k", 131072, 32, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode",
                            seqshard=True),
}
