"""DeepSeek-V3-671B [arXiv:2412.19437] — MLA (kv_lora 512, q_lora 1536,
rope 64), 3 dense layers then MoE: 1 shared + 256 routed top-8 experts of
width 2048 (sigmoid scores, routed scale 2.5), MTP. Dense-layer ff=18432.
The assignment's "d_ff=2048" is the per-expert width."""
from repro.models.base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v3-671b", arch_type="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280,
    norm="rmsnorm", act="silu", gated_mlp=True,
    rope_theta=10000.0,
    mla=MLACfg(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
               qk_rope_dim=64, v_dim=128),
    moe=MoECfg(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
               first_dense=3, score_fn="sigmoid", routed_scale=2.5),
    mtp_depth=1,
    source="DeepSeek-V3 [arXiv:2412.19437]",
)
