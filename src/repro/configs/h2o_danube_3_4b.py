"""H2O-Danube3-4B [arXiv:2401.16818 lineage] — llama+mistral mix with
sliding-window attention (window 4096), GQA kv=8."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", arch_type="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, head_dim=120,
    norm="rmsnorm", act="silu", gated_mlp=True,
    sliding_window=4096, rope_theta=10000.0,
    source="H2O-Danube [arXiv:2401.16818]",
)
