"""Architecture config registry: one module per assigned architecture
(+ the paper's own models). ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.base import ArchConfig
from repro.configs.shapes import SHAPES, InputShape

_MODULES = {
    "internvl2-26b": "internvl2_26b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "whisper-small": "whisper_small",
    "nemotron-4-15b": "nemotron_4_15b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "stablelm-1.6b": "stablelm_1_6b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen3-1.7b": "qwen3_1_7b",
    "xlstm-350m": "xlstm_350m",
    "gpt-paper-20b": "gpt_paper_20b",
}

ASSIGNED = tuple(k for k in _MODULES if k != "gpt-paper-20b")


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {k: get_config(k) for k in _MODULES}


# which architectures run long_500k (sub-quadratic only, see DESIGN.md)
LONG_CONTEXT_OK = ("h2o-danube-3-4b", "jamba-v0.1-52b", "xlstm-350m")


def skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return ("full-attention arch: long_500k requires sub-quadratic "
                "attention (DESIGN.md §Decode-shape skips)")
    if shape == "train_128k":
        cfg = get_config(arch)
        if cfg.arch_type in ("vlm", "audio") or set(cfg.mixers()) != {"attn"}:
            return ("train_128k targets context-parallel ring attention "
                    "(softmax-attention decoder archs only)")
    return None
