"""DeepSeek-V2-Lite-16B [arXiv:2405.04434] — MLA kv_lora=512 (no q-lora),
1 dense layer then MoE: 2 shared + 64 routed top-6 experts of width 1408.
(The assignment header says 64 experts; its prose "160 routed" matches
DSv2-full — we follow the 64e header, noted in DESIGN.md.)"""
from repro.models.base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", arch_type="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab_size=102400,
    norm="rmsnorm", act="silu", gated_mlp=True,
    rope_theta=10000.0,
    mla=MLACfg(kv_lora_rank=512, q_lora_rank=0, qk_nope_dim=128,
               qk_rope_dim=64, v_dim=128),
    moe=MoECfg(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
               first_dense=1),
    source="DeepSeek-V2 [arXiv:2405.04434]",
)
