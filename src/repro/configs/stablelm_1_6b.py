"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b] — LayerNorm, partial
rotary (25%), gated SiLU MLP, full MHA (kv=32)."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", arch_type="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100352, head_dim=64,
    norm="layernorm", act="silu", gated_mlp=True,
    rotary_pct=0.25, rope_theta=10000.0,
    source="[hf:stabilityai/stablelm-2-1_6b]",
)
