"""Nemotron-4-15B [arXiv:2402.16819] — GQA kv=8, squared-ReLU MLP (no
gate), partial rotary (50%), LayerNorm."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", arch_type="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab_size=256000, head_dim=128,
    norm="layernorm", act="squared_relu", gated_mlp=False,
    rotary_pct=0.5, rope_theta=10000.0,
    source="Nemotron-4 [arXiv:2402.16819]",
)
