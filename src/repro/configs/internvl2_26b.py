"""InternVL2-26B [arXiv:2404.16821] — InternViT-6B vision encoder (STUB,
per assignment) + InternLM2-20B language backbone. The config below is the
transformer backbone; input_specs feeds precomputed ViT patch embeddings
(dim 3200) through the 2-layer MLP projector."""
from repro.models.base import ArchConfig, EncoderCfg

CONFIG = ArchConfig(
    name="internvl2-26b", arch_type="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=92553, head_dim=128,
    norm="rmsnorm", act="silu", gated_mlp=True,
    rope_theta=1_000_000.0,
    encoder=EncoderCfg(n_layers=0, n_ctx=1024, input_dim=3200),
    source="InternVL2 [arXiv:2404.16821]; InternLM2 backbone",
)
