"""Whisper-small [arXiv:2212.04356] — enc-dec; mel+conv frontend is a STUB
per assignment (input_specs feeds post-conv frame embeddings (B,1500,768)).
Absolute positions (sinusoid enc / learned dec), biases, GeLU, LayerNorm,
tied decoder embedding."""
from repro.models.base import ArchConfig, EncoderCfg

CONFIG = ArchConfig(
    name="whisper-small", arch_type="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=51865, head_dim=64,
    norm="layernorm", act="gelu", gated_mlp=False,
    attn_bias=True, mlp_bias=True, rotary_pct=0.0,
    tie_embeddings=True, max_seq=32768,
    encoder=EncoderCfg(n_layers=12, n_ctx=1500, input_dim=0),
    source="Whisper [arXiv:2212.04356]",
)
